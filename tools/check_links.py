#!/usr/bin/env python3
"""Markdown link checker for the repo's documentation set.

Checks that every relative link target in the given markdown files
exists on disk (anchors within a file are checked against its headings).
External (http/https/mailto) links are not fetched — CI must stay
hermetic — only their syntax is accepted.

Usage: python3 tools/check_links.py README.md docs/*.md
Exits non-zero when any link is broken.
"""

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def headings_of(path):
    """GitHub-style anchor slugs for every heading in a markdown file."""
    slugs = set()
    try:
        with open(path, encoding="utf-8") as fh:
            for line in fh:
                if line.startswith("#"):
                    text = line.lstrip("#").strip().lower()
                    slug = re.sub(r"[^\w\- ]", "", text).replace(" ", "-")
                    slugs.add(slug)
    except OSError:
        pass
    return slugs


def check_file(md_path):
    errors = []
    base = os.path.dirname(os.path.abspath(md_path))
    with open(md_path, encoding="utf-8") as fh:
        content = fh.read()
    # Strip fenced code blocks: examples may contain bracketed text
    # that is not a link.
    content = re.sub(r"```.*?```", "", content, flags=re.S)
    for target in LINK_RE.findall(content):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if not path_part:  # same-file anchor
            if anchor and anchor not in headings_of(md_path):
                errors.append(f"{md_path}: broken anchor #{anchor}")
            continue
        resolved = os.path.normpath(os.path.join(base, path_part))
        if not os.path.exists(resolved):
            errors.append(f"{md_path}: broken link {target} -> {resolved}")
        elif anchor and resolved.endswith(".md") and anchor not in headings_of(resolved):
            errors.append(f"{md_path}: broken anchor {target}")
    return errors


def main(argv):
    if len(argv) < 2:
        print(__doc__)
        return 2
    all_errors = []
    for md in argv[1:]:
        if not os.path.exists(md):
            all_errors.append(f"no such file: {md}")
            continue
        all_errors.extend(check_file(md))
    for err in all_errors:
        print(f"BROKEN: {err}")
    if not all_errors:
        print(f"ok: {len(argv) - 1} file(s), all links resolve")
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
