//! Stencil autotuning (the refs-[1][2] GPU-paper analog): sweep the 2-D
//! tile space per grid size and show how the best tile shifts with the
//! working set — the platform-specialization effect the paper motivates.
//!
//! Run: `cargo run --release --example tune_stencil [-- --quick]`

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::search::Exhaustive;
use portatune::coordinator::tuner::Tuner;
use portatune::report::Table;
use portatune::runtime::{Registry, Runtime};
use portatune::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let quick = args.get_bool("quick");
    args.finish()?;

    let runtime = Runtime::cpu()?;
    let registry = Registry::open(runtime, "artifacts")?;
    let mut tuner = Tuner::new(&registry);
    if quick {
        tuner.measure_cfg = MeasureConfig::quick();
    }

    let entry = registry.manifest().kernel("stencil2d").unwrap().clone();
    let mut t = Table::new(&[
        "grid", "default (tm32,tn32)", "autotuned", "best tile", "speedup",
        "xla-ref", "GFLOP/s",
    ]);
    for w in &entry.workloads {
        let mut strategy = Exhaustive::new();
        let outcome = tuner.tune("stencil2d", &w.tag, &mut strategy, usize::MAX)?;
        let best = outcome.best.as_ref().unwrap();
        t.row(vec![
            w.tag.clone(),
            format!("{:.3} ms", outcome.baseline_time() * 1e3),
            format!("{:.3} ms", outcome.best_time() * 1e3),
            best.config_id.clone(),
            format!("{:.2}x", outcome.speedup()),
            format!("{:.3} ms", outcome.reference.cost() * 1e3),
            format!(
                "{:.2}",
                best.measurement.as_ref().map(|m| m.gflops(outcome.flops)).unwrap_or(0.0)
            ),
        ]);
        eprint!(".");
    }
    eprintln!();
    println!("stencil2d tile autotuning (5-point Jacobi sweep)\n");
    print!("{}", t.render());
    println!("\nnote how the winning tile changes with the grid size: the");
    println!("platform-dependent optimum is the paper's core observation.");
    Ok(())
}
