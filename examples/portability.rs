//! Performance portability (experiment A3): the perf DB transfers tuned
//! configurations across platforms, so a *new* platform reaches
//! near-optimal performance in a handful of evaluations instead of a
//! full sweep — the paper's "sustainable" claim, measured.
//!
//! Protocol (single-host simulation of a two-platform fleet):
//!   1. exhaustively tune axpy on every workload; record the winners
//!      under a synthetic "platform A" key,
//!   2. pretend this host is "platform B": warm-start each tune from
//!      A's records with a tiny budget,
//!   3. compare evaluations-to-within-5%-of-optimum: cold random search
//!      vs warm start.
//!
//! Run: `cargo run --release --example portability [-- --quick]`

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::perfdb::{unix_now, DbEntry, PerfDb};
use portatune::coordinator::search::{Exhaustive, RandomSearch};
use portatune::coordinator::tuner::Tuner;
use portatune::report::Table;
use portatune::runtime::{Registry, Runtime};
use portatune::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let quick = args.get_bool("quick");
    args.finish()?;

    let runtime = Runtime::cpu()?;
    let registry = Registry::open(runtime, "artifacts")?;
    let mut tuner = Tuner::new(&registry);
    tuner.measure_cfg = if quick { MeasureConfig::quick() } else { MeasureConfig::default() };

    let workloads = ["n16384", "n65536", "n262144"];
    let db_path = std::env::temp_dir().join("portatune-portability-db.json");
    let _ = std::fs::remove_file(&db_path);
    let mut db = PerfDb::open(&db_path)?;

    // Phase 1: platform A tunes exhaustively (ground truth optima).
    println!("[phase 1] exhaustive tuning on 'platform A'...");
    let mut optima = Vec::new();
    for tag in &workloads {
        let mut strategy = Exhaustive::new();
        let outcome = tuner.tune("axpy", tag, &mut strategy, usize::MAX)?;
        let best = outcome.best.as_ref().unwrap();
        db.record(DbEntry {
            platform_key: "platform-A-xeon-avx512".into(),
            kernel: "axpy".into(),
            tag: tag.to_string(),
            best_params: best.config.clone(),
            best_config_id: best.config_id.clone(),
            best_time_s: best.cost,
            baseline_time_s: outcome.baseline_time(),
            reference_time_s: outcome.reference.cost(),
            evaluations: outcome.evaluations() as u64,
            strategy: "exhaustive".into(),
            recorded_at: unix_now(),
        });
        optima.push((tag.to_string(), best.cost, outcome.evaluations()));
        eprint!(".");
    }
    eprintln!();
    db.save()?;

    // Phase 2: "platform B" (this host under its real key) warm-starts.
    println!("[phase 2] warm-started tuning on 'platform B'...\n");
    let mut t = Table::new(&[
        "workload", "optimum", "cold evals to 5%", "warm evals to 5%", "transfer hit",
    ]);
    for (tag, opt_cost, _) in &optima {
        let target = opt_cost * 1.05;

        // Cold: random search, count evaluations until within 5%.
        let mut cold_evals = 0usize;
        {
            let mut strategy = RandomSearch::new(2026);
            let outcome = tuner.tune("axpy", tag, &mut strategy, usize::MAX)?;
            let mut best = f64::INFINITY;
            for (i, v) in outcome.evaluated.iter().enumerate() {
                if v.cost < best {
                    best = v.cost;
                }
                if best <= target {
                    cold_evals = i + 1;
                    break;
                }
            }
            if cold_evals == 0 {
                cold_evals = outcome.evaluations();
            }
        }

        // Warm: DB transfer from platform A, budget 0 (transfer only).
        let candidates = db.warm_start("axpy", tag, "this-host");
        let warm_tuner = Tuner::new(&registry)
            .with_measure_cfg(tuner.measure_cfg.clone())
            .with_warm_start(candidates);
        let mut strategy = Exhaustive::new();
        let outcome = warm_tuner.tune("axpy", tag, &mut strategy, 0)?;
        let warm_best = outcome
            .evaluated
            .iter()
            .map(|v| v.cost)
            .fold(f64::INFINITY, f64::min);
        let hit = warm_best <= target;
        let warm_evals = outcome.evaluations();

        t.row(vec![
            tag.clone(),
            format!("{:.3} ms", opt_cost * 1e3),
            cold_evals.to_string(),
            warm_evals.to_string(),
            if hit { "yes".into() } else { format!("{:.2}x off", warm_best / opt_cost) },
        ]);
        eprint!(".");
    }
    eprintln!();
    print!("{}", t.render());
    println!("\nwarm start reaches within 5% of the optimum using DB transfer");
    println!("instead of a fresh search — tuning effort is amortized across");
    println!("the fleet, which is the paper's sustainability argument.");
    Ok(())
}
