//! Regenerate the paper's Figure 1: auto-vectorized (un-annotated
//! baseline) vs autotuned kernel across input vector sizes, with the
//! XLA reference as the vendor comparator column.
//!
//! Run: `cargo run --release --example fig1 [-- --quick] [-- --kernels axpy]`
//! Writes `fig1.csv` with the plotted series.

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::search::Exhaustive;
use portatune::coordinator::tuner::Tuner;
use portatune::report::{Fig1Report, Fig1Row};
use portatune::runtime::{Registry, Runtime};
use portatune::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let kernels = args.get_or("kernels", "axpy,dot,triad");
    let quick = args.get_bool("quick");
    args.finish()?;

    let runtime = Runtime::cpu()?;
    let registry = Registry::open(runtime, "artifacts")?;
    let mut tuner = Tuner::new(&registry);
    if quick {
        tuner.measure_cfg = MeasureConfig::quick();
    }

    let mut csv = String::new();
    for kname in kernels.split(',').filter(|s| !s.is_empty()) {
        let entry = registry
            .manifest()
            .kernel(kname)
            .ok_or_else(|| anyhow::anyhow!("unknown kernel {kname}"))?
            .clone();
        let mut report = Fig1Report::new(kname);
        for w in &entry.workloads {
            let mut strategy = Exhaustive::new();
            let outcome = tuner.tune(kname, &w.tag, &mut strategy, usize::MAX)?;
            report.push(Fig1Row {
                size: w.tag.clone(),
                baseline_s: outcome.baseline_time(),
                reference_s: outcome.reference.cost(),
                tuned_s: outcome.best_time(),
                best_id: outcome
                    .best
                    .as_ref()
                    .map(|b| b.config_id.clone())
                    .unwrap_or_else(|| "baseline".into()),
                evaluations: outcome.evaluations(),
            });
            eprint!(".");
        }
        eprintln!();
        println!("{}", report.render());
        csv.push_str(&report.to_csv());
    }
    std::fs::write("fig1.csv", &csv)?;
    println!("series written to fig1.csv");
    Ok(())
}
