//! End-to-end validation driver: a 2-D heat-diffusion (Jacobi) solver
//! built on the autotuning system, proving all three layers compose:
//!
//!   1. tune the `jacobi` sweep artifact (L1 Pallas schedule space,
//!      lowered AOT by L2, searched by the L3 coordinator),
//!   2. persist the winner to the performance DB,
//!   3. run the *deployed* solver — hundreds of sweeps through the PJRT
//!      runtime with zero Python — with the un-annotated default
//!      schedule vs the autotuned one, and report wall-clock + physics
//!      (mean distance to the analytic steady state must shrink, and
//!      both schedules must agree bitwise-tolerably).
//!
//! Run: `cargo run --release --example jacobi_e2e [-- --sweeps 500] [-- --quick]`

use std::time::Instant;

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::perfdb::PerfDb;
use portatune::coordinator::search::Exhaustive;
use portatune::coordinator::tuner::Tuner;
use portatune::runtime::registry::untupled_path;
use portatune::runtime::{Registry, Runtime, TensorData};
use portatune::util::cli::Args;
use portatune::workload::stencil;

const M: usize = 256;
const N: usize = 256;

/// Run `sweeps` Jacobi iterations from the hot-boundary start state.
fn solve(
    exe: &portatune::runtime::Executable,
    sweeps: usize,
) -> anyhow::Result<(Vec<f32>, f64)> {
    let mut grid = stencil::hot_boundary_grid(M, N, 1.0);
    let t0 = Instant::now();
    for _ in 0..sweeps {
        let out = exe.run(&[grid])?;
        grid = TensorData::f32(vec![M + 2, N + 2], out);
    }
    let dt = t0.elapsed().as_secs_f64();
    Ok((grid.as_f32().unwrap().to_vec(), dt))
}

/// Device-resident solve: upload once, feed the output buffer back as
/// the next input, download once at the end.  Requires the untupled
/// (`.nt.hlo.txt`) artifact.  This is the optimized hot path recorded in
/// EXPERIMENTS.md §Perf.
fn solve_device_resident(
    registry: &Registry,
    exe: &portatune::runtime::Executable,
    sweeps: usize,
) -> anyhow::Result<(Vec<f32>, f64)> {
    let grid = stencil::hot_boundary_grid(M, N, 1.0);
    let t0 = Instant::now();
    let mut buf = registry
        .runtime()
        .buffer_from_f32(grid.as_f32().unwrap(), &[M + 2, N + 2])?;
    for _ in 0..sweeps {
        buf = exe.run_buffers(&[&buf])?;
    }
    let lit = buf.to_literal_sync()?;
    let out = lit.to_vec::<f32>()?;
    let dt = t0.elapsed().as_secs_f64();
    Ok((out, dt))
}

fn mean_dist(g: &[f32]) -> f64 {
    let cols = N + 2;
    let mut acc = 0.0f64;
    for i in 1..=M {
        for j in 1..=N {
            acc += (g[i * cols + j] - 1.0).abs() as f64;
        }
    }
    acc / (M * N) as f64
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let sweeps = args.get_parsed::<usize>("sweeps", 500)?;
    let quick = args.get_bool("quick");
    args.finish()?;

    let runtime = Runtime::cpu()?;
    let registry = Registry::open(runtime, "artifacts")?;

    // --- Phase 1: tune ---------------------------------------------------
    let mut tuner = Tuner::new(&registry);
    if quick {
        tuner.measure_cfg = MeasureConfig::quick();
    }
    let mut strategy = Exhaustive::new();
    println!("[tune] searching the jacobi tile space (m256_n256)...");
    let outcome = tuner.tune("jacobi", "m256_n256", &mut strategy, usize::MAX)?;
    let best = outcome.best.as_ref().expect("a correct variant");
    println!(
        "[tune] best tile {} ({:.3} ms/sweep) vs default {:.3} ms/sweep -> {:.2}x",
        best.config_id,
        outcome.best_time() * 1e3,
        outcome.baseline_time() * 1e3,
        outcome.speedup()
    );

    // --- Phase 2: persist + deploy ---------------------------------------
    let db_path = std::env::temp_dir().join("portatune-e2e-db.json");
    let mut db = PerfDb::open(&db_path)?;
    tuner.record(&mut db, &outcome);
    db.save()?;
    let deployed_path = tuner.deployed_artifact(&db, "jacobi", "m256_n256")?;
    println!("[deploy] platform {} runs {}", outcome.platform.key(), deployed_path);

    // --- Phase 3: run the solver end to end ------------------------------
    let (_, wl) = registry.find("jacobi", "m256_n256")?;
    let default_id = wl.default.clone().expect("default schedule");
    let default_exe = registry.load(&wl.variant(&default_id).unwrap().path)?;
    let tuned_exe = registry.load(&deployed_path)?;

    println!("[solve] {sweeps} sweeps on a {M}x{N} grid, hot Dirichlet boundary");
    let (g_default, t_default) = solve(&default_exe, sweeps)?;
    let (g_tuned, t_tuned) = solve(&tuned_exe, sweeps)?;

    // Optimized path: untupled artifact + device-resident iteration
    // (no host<->device transfer per sweep).
    let tuned_nt_exe = registry.load(&untupled_path(&deployed_path))?;
    let (g_fast, t_fast) = solve_device_resident(&registry, &tuned_nt_exe, sweeps)?;

    // Physics check: diffusion progressed toward the steady state.
    let d_start = 1.0; // cold interior, all-hot steady state
    let d_end = mean_dist(&g_tuned);
    anyhow::ensure!(d_end < d_start * 0.9, "no diffusion progress: {d_end}");

    // Semantics check: all three paths computed the same field.
    let max_dev = g_default
        .iter()
        .zip(&g_tuned)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    anyhow::ensure!(max_dev < 1e-4, "schedules disagree by {max_dev}");
    let max_dev_fast = g_tuned
        .iter()
        .zip(&g_fast)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    anyhow::ensure!(max_dev_fast < 1e-5, "device-resident path diverged by {max_dev_fast}");

    println!("\n== end-to-end result ==");
    println!(
        "  default schedule ({default_id}): {:.3} s  ({:.3} ms/sweep)",
        t_default,
        t_default / sweeps as f64 * 1e3
    );
    println!(
        "  autotuned        ({}): {:.3} s  ({:.3} ms/sweep)",
        best.config_id,
        t_tuned,
        t_tuned / sweeps as f64 * 1e3
    );
    println!(
        "  autotuned + device-resident loop:   {:.3} s  ({:.3} ms/sweep)",
        t_fast,
        t_fast / sweeps as f64 * 1e3
    );
    println!(
        "  end-to-end speedup: {:.2}x tuned, {:.2}x tuned+resident   (outputs agree, max dev {max_dev:.1e})",
        t_default / t_tuned,
        t_default / t_fast
    );
    println!(
        "  physics: mean distance to steady state {d_start:.3} -> {d_end:.3} after {sweeps} sweeps"
    );
    Ok(())
}
