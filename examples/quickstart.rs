//! Quickstart: tune one kernel on one workload and print the paper's
//! three series (baseline schedule / autotuned / XLA reference).
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::search::Exhaustive;
use portatune::coordinator::tuner::Tuner;
use portatune::runtime::{Registry, Runtime};

fn main() -> anyhow::Result<()> {
    let runtime = Runtime::cpu()?;
    println!("platform: {}", runtime.platform_name());
    let registry = Registry::open(runtime, "artifacts")?;

    let tuner = Tuner::new(&registry).with_measure_cfg(MeasureConfig::default());
    let mut strategy = Exhaustive::new();
    let outcome = tuner.tune("axpy", "n65536", &mut strategy, usize::MAX)?;

    println!(
        "kernel axpy/n65536 — {} variants evaluated with {}",
        outcome.evaluations(),
        outcome.strategy
    );
    println!(
        "  baseline (default schedule b1024_u1): {:8.3} ms",
        outcome.baseline_time() * 1e3
    );
    if let Some(best) = &outcome.best {
        println!(
            "  autotuned ({:>12}):               {:8.3} ms",
            best.config_id,
            best.cost * 1e3
        );
    }
    println!(
        "  xla reference:                         {:8.3} ms",
        outcome.reference.cost() * 1e3
    );
    println!(
        "\nspeedup over un-annotated baseline: {:.2}x ({:.1}% time reduction)",
        outcome.speedup(),
        outcome.time_reduction_pct()
    );
    println!(
        "autotuned vs vendor-grade XLA path: {:.2}x of reference time",
        outcome.vs_reference()
    );
    println!("\nplatform fingerprint: {}", outcome.platform.key());
    Ok(())
}
