# Shared pytest fixtures: deterministic RNG and hypothesis profile tuned
# for CI (kernel lowering is the slow part, keep example counts modest).
import numpy as np
import pytest
from hypothesis import HealthCheck, settings

settings.register_profile(
    "kernels",
    max_examples=12,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("kernels")


@pytest.fixture
def rng():
    return np.random.default_rng(0xA07)
