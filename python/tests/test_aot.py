# AOT pipeline tests: --quick generation into a tmpdir, manifest schema
# validation, incremental skip behavior, and artifact HLO parseability.
import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.generate(out, families=["axpy", "jacobi"], quick=True)
    return out, manifest


def test_manifest_schema(quick_artifacts):
    out, manifest = quick_artifacts
    assert manifest["version"] == 1
    names = [k["name"] for k in manifest["kernels"]]
    assert names == ["axpy", "jacobi"]
    for kern in manifest["kernels"]:
        assert kern["params"], kern["name"]
        for p in kern["params"]:
            assert set(p) == {"name", "abbrev", "values"}
        for w in kern["workloads"]:
            assert set(w) >= {
                "tag", "dims", "inputs", "output", "flops", "bytes",
                "baseline", "variants",
            }
            assert w["flops"] > 0 and w["bytes"] > 0
            for inp in w["inputs"]:
                assert inp["dtype"] in ("f32", "i32")
                assert all(d > 0 for d in inp["shape"])


def test_artifact_files_exist_and_parse(quick_artifacts):
    out, manifest = quick_artifacts
    for kern in manifest["kernels"]:
        for w in kern["workloads"]:
            paths = [w["baseline"]] + [v["path"] for v in w["variants"]]
            for rel in paths:
                path = os.path.join(out, rel)
                assert os.path.exists(path), rel
                with open(path) as f:
                    head = f.read(4096)
                assert "HloModule" in head, rel


def test_quick_mode_prunes_grid(quick_artifacts):
    out, manifest = quick_artifacts
    axpy = manifest["kernels"][0]
    fam = model.get_family("axpy")
    for w in axpy["workloads"]:
        full = len(fam.grid(w["dims"]))
        # 3 pruning corners + (possibly) the default schedule.
        assert 1 <= len(w["variants"]) <= min(4, full)


def test_default_variant_present(quick_artifacts):
    # The un-annotated (default-schedule) variant must always have an
    # artifact — it is Figure 1's baseline series.
    out, manifest = quick_artifacts
    for kern in manifest["kernels"]:
        fam = model.get_family(kern["name"])
        for w in kern["workloads"]:
            assert w["default"] == fam.variant_id(fam.default_params(w["dims"]))
            ids = [v["id"] for v in w["variants"]]
            assert w["default"] in ids, (kern["name"], w["tag"])


def test_default_params_valid_everywhere():
    for fam in model.FAMILIES.values():
        for dims in fam.workloads:
            dp = fam.default_params(dims)
            assert fam.check(dp, dims)
            assert dp in fam.grid(dims)


def test_variant_params_valid(quick_artifacts):
    out, manifest = quick_artifacts
    for kern in manifest["kernels"]:
        fam = model.get_family(kern["name"])
        for w in kern["workloads"]:
            for v in w["variants"]:
                assert fam.check(v["params"], w["dims"]), v
                assert v["id"] == fam.variant_id(v["params"])


def test_incremental_skips_existing(quick_artifacts, capsys):
    out, manifest = quick_artifacts
    rel = manifest["kernels"][0]["workloads"][0]["baseline"]
    path = os.path.join(out, rel)
    mtime = os.path.getmtime(path)
    aot.generate(out, families=["axpy"], quick=True)  # no --force
    assert os.path.getmtime(path) == mtime


def test_manifest_json_round_trips(quick_artifacts):
    out, manifest = quick_artifacts
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded["kernels"][0]["name"] == "axpy"
    assert loaded["version"] == manifest["version"]
