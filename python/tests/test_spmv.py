# ELLPACK SpMV kernel vs oracle, with synthetic banded and random
# matrices matching the rust workload generator's construction.
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import make_spmv_ell, ref


def banded_ell(rng, nrows, k, bandwidth=None):
    """ELL arrays for a banded matrix (diagonal +/- bandwidth/2)."""
    bw = bandwidth if bandwidth is not None else k
    cols = np.zeros((nrows, k), np.int32)
    vals = np.zeros((nrows, k), np.float32)
    for i in range(nrows):
        lo = max(0, i - bw // 2)
        hi = min(nrows, lo + k)
        width = hi - lo
        cols[i, :width] = np.arange(lo, hi)
        vals[i, :width] = rng.standard_normal(width).astype(np.float32)
        # padding: value 0.0, column 0 (contributes nothing)
    return jnp.asarray(vals), jnp.asarray(cols)


def random_ell(rng, nrows, k):
    cols = rng.integers(0, nrows, size=(nrows, k)).astype(np.int32)
    vals = rng.standard_normal((nrows, k)).astype(np.float32)
    return jnp.asarray(vals), jnp.asarray(cols)


POINTS = [(64, 8), (256, 16), (512, 32), (1024, 32)]


@pytest.mark.parametrize("row_block,col_chunk", POINTS)
def test_spmv_banded_matches_ref(rng, row_block, col_chunk):
    nrows, k = 1024, 32
    v, ci = banded_ell(rng, nrows, k)
    x = jnp.asarray(rng.standard_normal(nrows, dtype=np.float32))
    out = make_spmv_ell(nrows, k, row_block, col_chunk)(v, x[ci])
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.spmv_ell(v, ci, x)), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("row_block,col_chunk", POINTS)
def test_spmv_random_matches_ref(rng, row_block, col_chunk):
    nrows, k = 1024, 32
    v, ci = random_ell(rng, nrows, k)
    x = jnp.asarray(rng.standard_normal(nrows, dtype=np.float32))
    out = make_spmv_ell(nrows, k, row_block, col_chunk)(v, x[ci])
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.spmv_ell(v, ci, x)), rtol=1e-4, atol=1e-5
    )


def test_identity_matrix(rng):
    # ELL encoding of I: one 1.0 per row at its own column.
    nrows, k = 256, 8
    vals = np.zeros((nrows, k), np.float32)
    cols = np.zeros((nrows, k), np.int32)
    vals[:, 0] = 1.0
    cols[:, 0] = np.arange(nrows)
    x = jnp.asarray(rng.standard_normal(nrows, dtype=np.float32))
    v, ci = jnp.asarray(vals), jnp.asarray(cols)
    out = make_spmv_ell(nrows, k, 64, 8)(v, x[ci])
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)


def test_invalid_blocking_rejected():
    with pytest.raises(ValueError):
        make_spmv_ell(1000, 32, 64, 8)  # nrows not divisible by row_block
    with pytest.raises(ValueError):
        make_spmv_ell(1024, 30, 64, 8)  # k not divisible by col_chunk


@given(
    rblocks=st.integers(1, 4),
    row_block=st.sampled_from([16, 32, 64]),
    kchunks=st.integers(1, 4),
    col_chunk=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_spmv_hypothesis(rblocks, row_block, kchunks, col_chunk, seed):
    nrows, k = rblocks * row_block, kchunks * col_chunk
    r = np.random.default_rng(seed)
    v, ci = random_ell(r, nrows, k)
    x = jnp.asarray(r.standard_normal(nrows, dtype=np.float32))
    out = make_spmv_ell(nrows, k, row_block, col_chunk)(v, x[ci])
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.spmv_ell(v, ci, x)), rtol=1e-4, atol=1e-5
    )
