# Blocked GEMM kernel vs oracle: tile corners, k-axis accumulation
# (multiple sequential k steps), identity cases, hypothesis sweep.
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import make_matmul, ref

TILES = [
    (16, 16, 16),
    (32, 16, 64),   # k split into multiple accumulation steps
    (16, 32, 16),
    (64, 64, 32),
]


def _ops(rng, m, n, k):
    a = jnp.asarray(rng.standard_normal((m, k), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((k, n), dtype=np.float32))
    return a, b


@pytest.mark.parametrize("tm,tn,tk", TILES)
def test_matmul_matches_ref(rng, tm, tn, tk):
    m, n, k = 64, 64, 128
    a, b = _ops(rng, m, n, k)
    out = make_matmul(m, n, k, tm, tn, tk)(a, b)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.matmul(a, b)), rtol=2e-4, atol=1e-3
    )


def test_identity_right(rng):
    m = n = k = 32
    a, _ = _ops(rng, m, n, k)
    eye = jnp.eye(k, dtype=jnp.float32)
    out = make_matmul(m, n, k, 16, 16, 16)(a, eye)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a), rtol=1e-6)


def test_k_accumulation_order_insensitive(rng):
    # Same product with tk=k (single step) vs tk=k/4 (four accumulation
    # steps) must agree to fp tolerance.
    m, n, k = 32, 32, 64
    a, b = _ops(rng, m, n, k)
    one = make_matmul(m, n, k, 16, 16, 64)(a, b)
    four = make_matmul(m, n, k, 16, 16, 16)(a, b)
    np.testing.assert_allclose(np.asarray(one), np.asarray(four), rtol=1e-4, atol=1e-4)


def test_invalid_tiles_rejected():
    with pytest.raises(ValueError):
        make_matmul(100, 64, 64, 16, 16, 16)
    with pytest.raises(ValueError):
        make_matmul(64, 100, 64, 16, 16, 16)
    with pytest.raises(ValueError):
        make_matmul(64, 64, 100, 16, 16, 16)


@given(
    bm=st.integers(1, 3),
    bn=st.integers(1, 3),
    bk=st.integers(1, 3),
    tile=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_matmul_hypothesis(bm, bn, bk, tile, seed):
    m, n, k = bm * tile, bn * tile, bk * tile
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.standard_normal((m, k), dtype=np.float32))
    b = jnp.asarray(r.standard_normal((k, n), dtype=np.float32))
    out = make_matmul(m, n, k, tile, tile, tile)(a, b)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(a @ b), rtol=2e-4, atol=1e-3
    )
