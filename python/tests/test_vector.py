# Kernel-vs-oracle tests for the vector (Figure-1) family: axpy, triad,
# dot.  Fixed-point checks on representative parameter points plus
# hypothesis sweeps over (n, block_size, unroll).
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import make_axpy, make_dot, make_triad, ref

# (block_size, unroll) corners exercised by the fixed tests.
POINTS = [(64, 1), (64, 4), (256, 2), (1024, 4), (4096, 1)]


def _vecs(rng, n):
    x = rng.standard_normal(n, dtype=np.float32)
    y = rng.standard_normal(n, dtype=np.float32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("block,unroll", POINTS)
def test_axpy_matches_ref(rng, block, unroll):
    n = 4096
    x, y = _vecs(rng, n)
    a = jnp.array([1.7], jnp.float32)
    out = make_axpy(n, block, unroll)(a, x, y)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.axpy(a, x, y)), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("block,unroll", POINTS)
def test_triad_matches_ref(rng, block, unroll):
    n = 4096
    x, y = _vecs(rng, n)
    a = jnp.array([0.3], jnp.float32)
    b = jnp.array([-2.5], jnp.float32)
    out = make_triad(n, block, unroll)(a, b, x, y)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.triad(a, b, x, y)), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("block,unroll", POINTS)
def test_dot_partials_match_ref(rng, block, unroll):
    n = 4096
    x, y = _vecs(rng, n)
    partials = make_dot(n, block, unroll)(x, y)
    expect = ref.dot_partials(x, y, block)
    np.testing.assert_allclose(np.asarray(partials), np.asarray(expect), rtol=1e-4)


@pytest.mark.parametrize("block,unroll", POINTS)
def test_dot_total_matches_ref(rng, block, unroll):
    n = 4096
    x, y = _vecs(rng, n)
    total = jnp.sum(make_dot(n, block, unroll)(x, y))
    np.testing.assert_allclose(
        float(total), float(ref.dot(x, y)[0]), rtol=1e-4
    )


def test_axpy_identity_scale(rng):
    # a == 0 must return y exactly (bitwise: 0*x+y).
    n = 512
    x, y = _vecs(rng, n)
    out = make_axpy(n, 128, 2)(jnp.array([0.0], jnp.float32), x, y)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(y))


def test_invalid_block_rejected():
    with pytest.raises(ValueError):
        make_axpy(1000, 256, 1)  # n not divisible by block
    with pytest.raises(ValueError):
        make_axpy(1024, 256, 3)  # block not divisible by unroll
    with pytest.raises(ValueError):
        make_dot(1024, 256, 3)
    with pytest.raises(ValueError):
        make_triad(100, 64, 1)


# Hypothesis sweep: any (nblocks, block=chunk*unroll) combination agrees
# with the oracle.  Sizes stay small — interpret-mode execution is the
# cost, the schedule space is what we want covered.
@given(
    nblocks=st.integers(1, 6),
    chunk=st.sampled_from([8, 16, 32, 64]),
    unroll=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**16),
)
def test_axpy_hypothesis(nblocks, chunk, unroll, seed):
    block = chunk * unroll
    n = nblocks * block
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    y = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    a = jnp.array([float(r.standard_normal())], jnp.float32)
    out = make_axpy(n, block, unroll)(a, x, y)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.axpy(a, x, y)), rtol=1e-5, atol=1e-6
    )


@given(
    nblocks=st.integers(1, 6),
    chunk=st.sampled_from([8, 32, 64]),
    unroll=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**16),
)
def test_dot_hypothesis(nblocks, chunk, unroll, seed):
    block = chunk * unroll
    n = nblocks * block
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    y = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    total = float(jnp.sum(make_dot(n, block, unroll)(x, y)))
    np.testing.assert_allclose(total, float(np.dot(x, y)), rtol=1e-3, atol=1e-4)
