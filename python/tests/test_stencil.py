# Stencil kernel vs oracle: fixed tile corners + hypothesis sweep over
# grid/tile shapes, plus analytic cases (constant field is a fixed point
# of the interior Jacobi sweep).
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import make_stencil2d, ref

TILES = [(8, 32), (16, 16), (32, 64), (64, 128)]


def _padded(rng, m, n):
    return jnp.asarray(rng.standard_normal((m + 2, n + 2), dtype=np.float32))


def _shifts(g):
    return g[:-2, 1:-1], g[2:, 1:-1], g[1:-1, :-2], g[1:-1, 2:]


@pytest.mark.parametrize("tm,tn", TILES)
def test_stencil_matches_ref(rng, tm, tn):
    m, n = 64, 128
    g = _padded(rng, m, n)
    out = make_stencil2d(m, n, tm, tn)(*_shifts(g))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.stencil2d(g)), rtol=1e-5, atol=1e-6
    )


def test_constant_field_fixed_point():
    m = n = 32
    g = jnp.full((m + 2, n + 2), 3.25, jnp.float32)
    out = make_stencil2d(m, n, 8, 32)(*_shifts(g))
    np.testing.assert_array_equal(np.asarray(out), np.full((m, n), 3.25, np.float32))


def test_linear_field_preserved(rng):
    # The 4-neighbor average of a linear field equals the field itself
    # (harmonic), so out[i,j] == g[i+1,j+1] on the interior.
    m = n = 16
    ii = np.arange(m + 2, dtype=np.float32)[:, None]
    jj = np.arange(n + 2, dtype=np.float32)[None, :]
    g = jnp.asarray(2.0 * ii + 0.5 * jj)
    out = make_stencil2d(m, n, 8, 8)(*_shifts(g))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(g[1:-1, 1:-1]), rtol=1e-6
    )


def test_invalid_tile_rejected():
    with pytest.raises(ValueError):
        make_stencil2d(100, 128, 16, 32)  # m not divisible
    with pytest.raises(ValueError):
        make_stencil2d(128, 100, 16, 32)  # n not divisible


@given(
    bm=st.integers(1, 4),
    bn=st.integers(1, 4),
    tm=st.sampled_from([4, 8, 16]),
    tn=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_stencil_hypothesis(bm, bn, tm, tn, seed):
    m, n = bm * tm, bn * tn
    g = jnp.asarray(
        np.random.default_rng(seed).standard_normal((m + 2, n + 2), dtype=np.float32)
    )
    out = make_stencil2d(m, n, tm, tn)(*_shifts(g))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.stencil2d(g)), rtol=1e-5, atol=1e-6
    )
