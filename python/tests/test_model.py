# L2 family tests: constraint evaluation, grid expansion, variant ids,
# baseline<->tuned semantic equality for every family (small workloads),
# and lowering to parseable HLO text.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


ALL_FAMILIES = sorted(model.FAMILIES)


def test_family_registry_complete():
    assert ALL_FAMILIES == [
        "axpy",
        "dot",
        "jacobi",
        "matmul",
        "spmv_ell",
        "stencil2d",
        "triad",
    ]


@pytest.mark.parametrize("name", ALL_FAMILIES)
def test_grid_points_satisfy_constraints(name):
    fam = model.get_family(name)
    for dims in fam.workloads:
        grid = fam.grid(dims)
        assert grid, f"empty grid for {name}/{fam.tag(dims)}"
        for pt in grid:
            assert fam.check(pt, dims)
        # ids are unique within a workload
        ids = [fam.variant_id(pt) for pt in grid]
        assert len(set(ids)) == len(ids)


def test_constraint_rejects_oversized_block():
    fam = model.get_family("axpy")
    assert not fam.check({"block_size": 16384, "unroll": 1}, {"n": 4096})
    assert fam.check({"block_size": 4096, "unroll": 4}, {"n": 4096})
    assert not fam.check({"block_size": 256, "unroll": 3}, {"n": 4096})


def test_tag_and_variant_id_format():
    fam = model.get_family("matmul")
    assert fam.tag({"m": 256, "n": 256, "k": 512}) == "k512_m256_n256"
    vid = fam.variant_id({"tile_m": 32, "tile_n": 64, "tile_k": 128})
    assert vid == "tm32_tn64_tk128"


def _small_dims(name):
    # Small shapes (not in the AOT workload list) for fast equality runs.
    return {
        "axpy": {"n": 2048},
        "triad": {"n": 2048},
        "dot": {"n": 2048},
        "stencil2d": {"m": 32, "n": 64},
        "jacobi": {"m": 32, "n": 64},
        "spmv_ell": {"nrows": 256, "k": 16},
        "matmul": {"m": 64, "n": 64, "k": 64},
    }[name]


def _random_inputs(fam, dims, seed=7):
    r = np.random.default_rng(seed)
    out = []
    for name, spec in fam.input_specs(dims):
        if spec.dtype == jnp.int32:
            hi = dims.get("nrows", dims.get("n", 16))
            out.append(jnp.asarray(r.integers(0, hi, spec.shape).astype(np.int32)))
        else:
            out.append(jnp.asarray(r.standard_normal(spec.shape, dtype=np.float32)))
    return out


@pytest.mark.parametrize("name", ALL_FAMILIES)
def test_tuned_equals_baseline(name):
    fam = model.get_family(name)
    dims = _small_dims(name)
    inputs = _random_inputs(fam, dims)
    base = fam.baseline(dims)(*inputs)[0]
    # Exercise two parameter points: first and last of the valid grid.
    grid = fam.grid(dims)
    for pt in (grid[0], grid[-1]):
        tuned = fam.tuned(dims, pt)(*inputs)[0]
        np.testing.assert_allclose(
            np.asarray(tuned), np.asarray(base), rtol=2e-4, atol=1e-3
        )


@pytest.mark.parametrize("name", ALL_FAMILIES)
def test_output_shape_consistency(name):
    fam = model.get_family(name)
    dims = _small_dims(name)
    specs = [s for _, s in fam.input_specs(dims)]
    base_shape = jax.eval_shape(fam.baseline(dims), *specs)[0]
    pt = fam.grid(dims)[0]
    tuned_shape = jax.eval_shape(fam.tuned(dims, pt), *specs)[0]
    assert base_shape.shape == tuned_shape.shape
    assert base_shape.dtype == tuned_shape.dtype


def test_jacobi_preserves_boundary():
    fam = model.get_family("jacobi")
    dims = {"m": 32, "n": 64}
    (g,) = _random_inputs(fam, dims)
    out = fam.baseline(dims)(g)[0]
    np.testing.assert_array_equal(np.asarray(out[0, :]), np.asarray(g[0, :]))
    np.testing.assert_array_equal(np.asarray(out[-1, :]), np.asarray(g[-1, :]))
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(g[:, 0]))
    np.testing.assert_array_equal(np.asarray(out[:, -1]), np.asarray(g[:, -1]))
    pt = fam.grid(dims)[0]
    out_t = fam.tuned(dims, pt)(g)[0]
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(out), rtol=1e-6)


def test_lower_to_hlo_text_is_parseable_hlo():
    fam = model.get_family("axpy")
    dims = {"n": 2048}
    specs = [s for _, s in fam.input_specs(dims)]
    text = model.lower_to_hlo_text(fam.baseline(dims), specs)
    assert "HloModule" in text
    assert "ENTRY" in text
    # tuple return convention for rust's to_tuple1
    assert "tuple" in text.lower()


def test_lowered_tuned_contains_loop_schedule():
    # A blocked kernel with >1 grid steps must lower to a while loop (the
    # schedule is in the artifact, which is the whole point of AOT
    # variant generation).
    fam = model.get_family("axpy")
    dims = {"n": 2048}
    specs = [s for _, s in fam.input_specs(dims)]
    text = model.lower_to_hlo_text(fam.tuned(dims, {"block_size": 256, "unroll": 2}), specs)
    assert "while" in text
