"""Blocked GEMM kernel — the dense MXU-mapping study.

Classic three-level tiling: grid (m/tm, n/tn, k/tk); the k axis is the
innermost (sequential) grid dimension and the output block accumulates
across k steps (the output index_map ignores the k index, so Pallas keeps
the block resident — the TPU VMEM accumulation idiom replacing the GPU
papers' shared-memory tiles).

On a real TPU tm = tn = tk = 128 matches the MXU systolic array exactly;
the tuner discovers the best CPU tiling empirically, which is the paper's
point — the optimum is platform-dependent.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def make_matmul(m: int, n: int, k: int, tile_m: int, tile_n: int, tile_k: int):
    """C = A @ B with A f32[m,k], B f32[k,n]."""
    if m % tile_m != 0:
        raise ValueError(f"m {m} not divisible by tile_m {tile_m}")
    if n % tile_n != 0:
        raise ValueError(f"n {n} not divisible by tile_n {tile_n}")
    if k % tile_k != 0:
        raise ValueError(f"k {k} not divisible by tile_k {tile_k}")
    grid = (m // tile_m, n // tile_n, k // tile_k)

    def kernel(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            a_ref[...], b_ref[...], preferred_element_type=jnp.float32
        )

    a_spec = pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((tile_k, tile_n), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j))

    def run(a, b):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[a_spec, b_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(a, b)

    return run
