"""ELLPACK SpMV kernel (the ref-[1] cuSPARSE/CUSP comparison workload).

ELL stores a sparse matrix as two dense (nrows, k) arrays — values and
column indices — padding short rows; its regular layout is what made it
the GPU format of choice in the CUSP comparison, and the same regularity
maps onto Pallas block tiles.

The irregular gather ``x[col_idx]`` is performed in the L2 graph (XLA
gather); the tuned region is the dense rowwise multiply-reduce over the
gathered operand, blocked by

  * ``row_block`` — rows per grid step (the VMEM-resident row tile), and
  * ``col_chunk`` — the padded width is consumed in chunks of this size
    with independent accumulators (ILP over the reduction, the analog of
    the GPU papers' per-thread accumulate unrolling).

Requires nrows % row_block == 0 and k % col_chunk == 0 (the L2 wrapper
pads; the manifest declares the constraints for the tuner).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def make_spmv_ell(nrows: int, k: int, row_block: int, col_chunk: int):
    """y[i] = sum_j values[i, j] * xg[i, j] over f32[nrows, k] operands."""
    if nrows % row_block != 0:
        raise ValueError(f"nrows {nrows} not divisible by row_block {row_block}")
    if k % col_chunk != 0:
        raise ValueError(f"k {k} not divisible by col_chunk {col_chunk}")
    grid = (nrows // row_block,)
    nchunks = k // col_chunk

    def kernel(v_ref, xg_ref, o_ref):
        if nchunks == 1:
            o_ref[...] = jnp.sum(v_ref[...] * xg_ref[...], axis=1)
            return
        acc = []
        for c in range(nchunks):
            sl = pl.dslice(c * col_chunk, col_chunk)
            acc.append(jnp.sum(v_ref[:, sl] * xg_ref[:, sl], axis=1))
        total = acc[0]
        for a in acc[1:]:
            total = total + a
        o_ref[...] = total

    blk2 = pl.BlockSpec((row_block, k), lambda i: (i, 0))
    out = pl.BlockSpec((row_block,), lambda i: (i,))

    def run(values, x_gathered):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[blk2, blk2],
            out_specs=out,
            out_shape=jax.ShapeDtypeStruct((nrows,), jnp.float32),
            interpret=True,
        )(values, x_gathered)

    return run
