"""5-point Jacobi stencil kernel (the refs-[1][2] stencil workload).

The GPU papers tiled the grid into threadblocks; the TPU/Pallas analog is
a 2-D BlockSpec tile (``tile_m`` x ``tile_n``) — the HBM<->VMEM schedule.
Halo handling: pallas BlockSpec blocks cannot overlap, so the L2 wrapper
materializes the four shifted neighbor views (north/south/west/east) with
XLA slices and the kernel consumes five aligned refs.  The shifts are
identical work in the baseline, so the tuned-vs-baseline comparison is
apples-to-apples on the weighted-sum hot loop.

out = 0.25 * (north + south + west + east)   (interior Jacobi sweep)
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def make_stencil2d(m: int, n: int, tile_m: int, tile_n: int):
    """Jacobi weighted sum over five aligned f32[m, n] operands."""
    if m % tile_m != 0:
        raise ValueError(f"m {m} not divisible by tile_m {tile_m}")
    if n % tile_n != 0:
        raise ValueError(f"n {n} not divisible by tile_n {tile_n}")
    grid = (m // tile_m, n // tile_n)

    def kernel(nn_ref, ss_ref, ww_ref, ee_ref, o_ref):
        o_ref[...] = 0.25 * (nn_ref[...] + ss_ref[...] + ww_ref[...] + ee_ref[...])

    blk = pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j))

    def run(north, south, west, east):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[blk, blk, blk, blk],
            out_specs=blk,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(north, south, west, east)

    return run
