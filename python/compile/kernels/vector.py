"""SIMD vector-loop kernel family (the Figure-1 workload class).

The paper's current-work experiment autotunes SIMD pragma variants of
vectorizable loops under ICC.  Here the analogous schedule space is:

  * ``block_size`` — elements processed per grid step (the Pallas
    BlockSpec block; on TPU this is the VMEM-resident tile, on the
    XLA:CPU backend we measure on it controls cache blocking and the
    LLVM vectorizer's trip count).
  * ``unroll`` — the block is split into ``unroll`` straight-line
    sub-chunks inside the kernel body (register-level ILP; the analog of
    ``#pragma unroll(k)``).

All kernels require ``n % block_size == 0`` and
``block_size % unroll == 0`` — the L2 wrapper (model.py) pads inputs so
any logical size is accepted; the constraint set is still declared in the
manifest so the rust tuner prunes invalid points.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unrolled_map(body, block_size: int, unroll: int, o_ref, *in_refs):
    """Apply ``body`` over ``unroll`` equal sub-chunks of the block.

    ``body`` maps a tuple of input sub-arrays to the output sub-array.
    With unroll == 1 this is a single full-block statement; otherwise the
    python loop emits straight-line code for each chunk (distinct HLO per
    unroll factor — exactly how a pragma-unrolled C loop differs).
    """
    if block_size % unroll != 0:
        raise ValueError(f"block_size {block_size} not divisible by unroll {unroll}")
    chunk = block_size // unroll
    if unroll == 1:
        o_ref[...] = body(*(r[...] for r in in_refs))
        return
    for u in range(unroll):
        sl = pl.dslice(u * chunk, chunk)
        o_ref[sl] = body(*(r[sl] for r in in_refs))


def make_axpy(n: int, block_size: int, unroll: int):
    """y_out = a * x + y over f32[n]; a is a rank-1 broadcast scalar."""
    if n % block_size != 0:
        raise ValueError(f"n {n} not divisible by block_size {block_size}")
    if block_size % unroll != 0:
        raise ValueError(f"block_size {block_size} not divisible by unroll {unroll}")
    grid = (n // block_size,)

    def kernel(a_ref, x_ref, y_ref, o_ref):
        a = a_ref[0]
        _unrolled_map(lambda x, y: a * x + y, block_size, unroll, o_ref, x_ref, y_ref)

    blk = pl.BlockSpec((block_size,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))

    @functools.wraps(kernel)
    def run(a, x, y):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[scalar, blk, blk],
            out_specs=blk,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )(a, x, y)

    return run


def make_triad(n: int, block_size: int, unroll: int):
    """z = a * x + b * y over f32[n] (STREAM triad with two scales)."""
    if n % block_size != 0:
        raise ValueError(f"n {n} not divisible by block_size {block_size}")
    if block_size % unroll != 0:
        raise ValueError(f"block_size {block_size} not divisible by unroll {unroll}")
    grid = (n // block_size,)

    def kernel(a_ref, b_ref, x_ref, y_ref, o_ref):
        a = a_ref[0]
        b = b_ref[0]
        _unrolled_map(
            lambda x, y: a * x + b * y, block_size, unroll, o_ref, x_ref, y_ref
        )

    blk = pl.BlockSpec((block_size,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))

    def run(a, b, x, y):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[scalar, scalar, blk, blk],
            out_specs=blk,
            out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
            interpret=True,
        )(a, b, x, y)

    return run


def make_dot(n: int, block_size: int, unroll: int):
    """Blocked reduction: returns per-block partial sums f32[n//block_size].

    The final (short) reduction over partials happens in the L2 graph —
    the tuned region is the streaming multiply-accumulate.  ``unroll``
    keeps independent accumulators per sub-chunk and combines them at the
    end of the block (breaking the reduction dependence chain, the SIMD
    reduction idiom the paper's pragma search targets).
    """
    if n % block_size != 0:
        raise ValueError(f"n {n} not divisible by block_size {block_size}")
    if block_size % unroll != 0:
        raise ValueError(f"block_size {block_size} not divisible by unroll {unroll}")
    nblocks = n // block_size
    chunk = block_size // unroll

    def kernel(x_ref, y_ref, o_ref):
        if unroll == 1:
            o_ref[0] = jnp.sum(x_ref[...] * y_ref[...])
            return
        acc = []
        for u in range(unroll):
            sl = pl.dslice(u * chunk, chunk)
            acc.append(jnp.sum(x_ref[sl] * y_ref[sl]))
        total = acc[0]
        for a in acc[1:]:
            total = total + a
        o_ref[0] = total

    blk = pl.BlockSpec((block_size,), lambda i: (i,))
    out = pl.BlockSpec((1,), lambda i: (i,))

    def run(x, y):
        return pl.pallas_call(
            kernel,
            grid=(nblocks,),
            in_specs=[blk, blk],
            out_specs=out,
            out_shape=jax.ShapeDtypeStruct((nblocks,), jnp.float32),
            interpret=True,
        )(x, y)

    return run
