# L1: parameterized Pallas kernels (build-time only; lowered AOT to HLO).
#
# Every kernel family exposes
#   make_<name>(params, dims) -> a jax-traceable callable over concrete
#   shapes, whose hot loop is a pallas_call specialized to `params`.
# The pure-jnp oracles live in ref.py; python/tests/ asserts allclose.
#
# Pallas is always invoked with interpret=True: the CPU PJRT plugin cannot
# execute Mosaic custom-calls, and interpret mode lowers the *schedule*
# (grid, blocking, unrolled straight-line bodies) into plain HLO, which
# XLA:CPU then compiles to native code — so per-variant performance
# differences measured by the rust tuner are real compiled-code
# differences.

from .vector import make_axpy, make_dot, make_triad
from .stencil import make_stencil2d
from .spmv import make_spmv_ell
from .matmul import make_matmul

__all__ = [
    "make_axpy",
    "make_dot",
    "make_triad",
    "make_stencil2d",
    "make_spmv_ell",
    "make_matmul",
]
