# Pure-jnp correctness oracles for every kernel family.
#
# These are the paper's "reference implementation": the un-annotated
# program whose outputs every tuned variant must reproduce.  They are
# also the "auto-vectorized -O3 baseline" — model.py lowers exactly these
# expressions (no Pallas schedule imposed) as the baseline artifacts, so
# correctness oracle and performance baseline are the same code, as in
# the paper.

import jax.numpy as jnp


def axpy(a, x, y):
    """y_out = a * x + y; a is f32[1] (broadcast scalar)."""
    return a[0] * x + y


def triad(a, b, x, y):
    """z = a * x + b * y."""
    return a[0] * x + b[0] * y


def dot(x, y):
    """Scalar dot product as f32[1] (rank-1 so tuple layouts match)."""
    return jnp.sum(x * y).reshape((1,))


def dot_partials(x, y, block_size):
    """Per-block partial sums — oracle for the kernel's raw output."""
    n = x.shape[0]
    assert n % block_size == 0
    prod = (x * y).reshape((n // block_size, block_size))
    return jnp.sum(prod, axis=1)


def stencil2d(grid):
    """One interior Jacobi sweep over f32[m+2, n+2]; returns f32[m, n].

    out[i, j] = 0.25 * (g[i-1,j] + g[i+1,j] + g[i,j-1] + g[i,j+1])
    for the interior (1..m, 1..n) of the padded grid.
    """
    north = grid[:-2, 1:-1]
    south = grid[2:, 1:-1]
    west = grid[1:-1, :-2]
    east = grid[1:-1, 2:]
    return 0.25 * (north + south + west + east)


def spmv_ell(values, col_idx, x):
    """ELLPACK SpMV: y[i] = sum_j values[i, j] * x[col_idx[i, j]].

    Padding entries carry value 0.0 (their column index is arbitrary but
    in-range), so they contribute nothing.
    """
    return jnp.sum(values * x[col_idx], axis=1)


def matmul(a, b):
    """Dense C = A @ B in f32."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)
