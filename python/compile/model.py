"""L2: the tunable compute graphs (kernel families).

A *family* bundles everything the autotuner needs to know about one
tunable computation:

  * ``baseline(dims)``   — the pure-jnp reference program (the paper's
    un-annotated, `icc -O3`-autovectorized analog),
  * ``tuned(dims, params)`` — the same computation with its hot loop
    routed through the parameterized Pallas kernel,
  * the parameter space and constraint strings (the machine-readable
    form of the paper's annotation directives),
  * the AOT workload list (concrete shapes) and per-workload flops/bytes
    for roofline reporting.

Both callables return a 1-tuple (lowered with ``return_tuple=True``) so
the rust runtime unwraps uniformly with ``to_tuple1``.

The constraint grammar is shared with the rust evaluator
(rust/src/coordinator/constraint.rs): integer arithmetic
(+ - * / %), comparisons (== != <= >= < >), && and ||, parentheses;
identifiers resolve to dims or params.  Python evaluates the same
strings here (with &&/|| rewritten) so the two layers can never skew.
"""

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import (
    make_axpy,
    make_dot,
    make_matmul,
    make_spmv_ell,
    make_stencil2d,
    make_triad,
)
from .kernels import ref

f32 = jnp.float32
i32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class Param:
    """One tuning knob: a name, its abbreviation (variant ids), domain."""

    name: str
    abbrev: str
    values: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Family:
    """A tunable kernel family (see module docstring).

    ``default_params(dims)`` is the **un-annotated schedule**: the tile /
    unroll choice a programmer writes down without tuning (the paper's
    pragma-free baseline).  ``baseline(dims)`` is the pure-jnp *reference
    program* — the semantics oracle and the vendor-library-grade
    comparator (XLA's own fused lowering; the cuSPARSE/CUSP analog of
    the paper's refs [1][2]).
    """

    name: str
    params: Tuple[Param, ...]
    constraints: Tuple[str, ...]
    workloads: Tuple[Dict[str, int], ...]
    input_specs: Callable[[Dict[str, int]], List[Tuple[str, jax.ShapeDtypeStruct]]]
    baseline: Callable[[Dict[str, int]], Callable]
    tuned: Callable[[Dict[str, int], Dict[str, int]], Callable]
    flops: Callable[[Dict[str, int]], int]
    bytes_moved: Callable[[Dict[str, int]], int]
    default_params: Callable[[Dict[str, int]], Dict[str, int]] = None

    def tag(self, dims: Dict[str, int]) -> str:
        return "_".join(f"{k}{v}" for k, v in sorted(dims.items()))

    def variant_id(self, params: Dict[str, int]) -> str:
        return "_".join(f"{p.abbrev}{params[p.name]}" for p in self.params)

    def check(self, params: Dict[str, int], dims: Dict[str, int]) -> bool:
        """Evaluate the constraint strings over dims+params (build-time)."""
        env = dict(dims)
        env.update(params)
        for c in self.constraints:
            expr = c.replace("&&", " and ").replace("||", " or ")
            if not eval(expr, {"__builtins__": {}}, env):  # noqa: S307
                return False
        return True

    def grid(self, dims: Dict[str, int]):
        """All valid parameter points for a workload, in declaration order."""
        points = [{}]
        for p in self.params:
            points = [{**pt, p.name: v} for pt in points for v in p.values]
        return [pt for pt in points if self.check(pt, dims)]


# ---------------------------------------------------------------------------
# Vector family (Figure 1 workload class): axpy / triad / dot
# ---------------------------------------------------------------------------

_VEC_SIZES = (4096, 16384, 65536, 262144, 1048576, 4194304)
_VEC_PARAMS = (
    Param("block_size", "b", (256, 1024, 4096, 16384)),
    Param("unroll", "u", (1, 2, 4)),
)
_VEC_CONSTRAINTS = ("block_size <= n", "block_size % unroll == 0")


def _vec_dims(n: int) -> Dict[str, int]:
    return {"n": n}


def _axpy_specs(dims):
    n = dims["n"]
    return [
        ("a", jax.ShapeDtypeStruct((1,), f32)),
        ("x", jax.ShapeDtypeStruct((n,), f32)),
        ("y", jax.ShapeDtypeStruct((n,), f32)),
    ]


def _axpy_baseline(dims):
    return lambda a, x, y: (ref.axpy(a, x, y),)


def _axpy_tuned(dims, params):
    fn = make_axpy(dims["n"], params["block_size"], params["unroll"])
    return lambda a, x, y: (fn(a, x, y),)


AXPY = Family(
    name="axpy",
    params=_VEC_PARAMS,
    constraints=_VEC_CONSTRAINTS,
    workloads=tuple(_vec_dims(n) for n in _VEC_SIZES),
    input_specs=_axpy_specs,
    baseline=_axpy_baseline,
    tuned=_axpy_tuned,
    flops=lambda d: 2 * d["n"],
    bytes_moved=lambda d: 12 * d["n"],
    default_params=lambda d: {"block_size": 1024 if d["n"] >= 1024 else 256, "unroll": 1},
)


def _triad_specs(dims):
    n = dims["n"]
    return [
        ("a", jax.ShapeDtypeStruct((1,), f32)),
        ("b", jax.ShapeDtypeStruct((1,), f32)),
        ("x", jax.ShapeDtypeStruct((n,), f32)),
        ("y", jax.ShapeDtypeStruct((n,), f32)),
    ]


def _triad_baseline(dims):
    return lambda a, b, x, y: (ref.triad(a, b, x, y),)


def _triad_tuned(dims, params):
    fn = make_triad(dims["n"], params["block_size"], params["unroll"])
    return lambda a, b, x, y: (fn(a, b, x, y),)


TRIAD = Family(
    name="triad",
    params=_VEC_PARAMS,
    constraints=_VEC_CONSTRAINTS,
    workloads=tuple(_vec_dims(n) for n in _VEC_SIZES),
    input_specs=_triad_specs,
    baseline=_triad_baseline,
    tuned=_triad_tuned,
    flops=lambda d: 3 * d["n"],
    bytes_moved=lambda d: 16 * d["n"],
    default_params=lambda d: {"block_size": 1024 if d["n"] >= 1024 else 256, "unroll": 1},
)


def _dot_specs(dims):
    n = dims["n"]
    return [
        ("x", jax.ShapeDtypeStruct((n,), f32)),
        ("y", jax.ShapeDtypeStruct((n,), f32)),
    ]


def _dot_baseline(dims):
    return lambda x, y: (ref.dot(x, y),)


def _dot_tuned(dims, params):
    fn = make_dot(dims["n"], params["block_size"], params["unroll"])
    # Final short reduction over per-block partials stays in the graph.
    return lambda x, y: (jnp.sum(fn(x, y)).reshape((1,)),)


DOT = Family(
    name="dot",
    params=_VEC_PARAMS,
    constraints=_VEC_CONSTRAINTS,
    workloads=tuple(_vec_dims(n) for n in _VEC_SIZES),
    input_specs=_dot_specs,
    baseline=_dot_baseline,
    tuned=_dot_tuned,
    flops=lambda d: 2 * d["n"],
    bytes_moved=lambda d: 8 * d["n"],
    default_params=lambda d: {"block_size": 1024 if d["n"] >= 1024 else 256, "unroll": 1},
)


# ---------------------------------------------------------------------------
# Stencil family (refs [1][2] analog): 5-point Jacobi sweep
# ---------------------------------------------------------------------------

_STENCIL_PARAMS = (
    Param("tile_m", "tm", (8, 16, 32, 64, 128)),
    Param("tile_n", "tn", (32, 64, 128, 256)),
)
_STENCIL_CONSTRAINTS = (
    "tile_m <= m",
    "tile_n <= n",
    "m % tile_m == 0",
    "n % tile_n == 0",
)
_STENCIL_SIZES = ((128, 128), (256, 256), (512, 512), (1024, 1024))


def _stencil_specs(dims):
    m, n = dims["m"], dims["n"]
    return [("grid", jax.ShapeDtypeStruct((m + 2, n + 2), f32))]


def _shifts(g):
    return g[:-2, 1:-1], g[2:, 1:-1], g[1:-1, :-2], g[1:-1, 2:]


def _stencil_baseline(dims):
    return lambda g: (ref.stencil2d(g),)


def _stencil_tuned(dims, params):
    fn = make_stencil2d(dims["m"], dims["n"], params["tile_m"], params["tile_n"])

    def run(g):
        nn, ss, ww, ee = _shifts(g)
        return (fn(nn, ss, ww, ee),)

    return run


STENCIL2D = Family(
    name="stencil2d",
    params=_STENCIL_PARAMS,
    constraints=_STENCIL_CONSTRAINTS,
    workloads=tuple({"m": m, "n": n} for m, n in _STENCIL_SIZES),
    input_specs=_stencil_specs,
    baseline=_stencil_baseline,
    tuned=_stencil_tuned,
    flops=lambda d: 4 * d["m"] * d["n"],
    bytes_moved=lambda d: 8 * d["m"] * d["n"],
    default_params=lambda d: {"tile_m": 32, "tile_n": 32},
)


# ---------------------------------------------------------------------------
# Jacobi step family — the end-to-end driver's inner loop.  Same schedule
# space as stencil2d but the artifact maps padded grid -> padded grid
# (boundary preserved), so the rust solver can iterate it directly.
# ---------------------------------------------------------------------------


def _jacobi_specs(dims):
    m, n = dims["m"], dims["n"]
    return [("grid", jax.ShapeDtypeStruct((m + 2, n + 2), f32))]


def _jacobi_baseline(dims):
    def run(g):
        return (g.at[1:-1, 1:-1].set(ref.stencil2d(g)),)

    return run


def _jacobi_tuned(dims, params):
    fn = make_stencil2d(dims["m"], dims["n"], params["tile_m"], params["tile_n"])

    def run(g):
        nn, ss, ww, ee = _shifts(g)
        return (g.at[1:-1, 1:-1].set(fn(nn, ss, ww, ee)),)

    return run


JACOBI = Family(
    name="jacobi",
    params=_STENCIL_PARAMS,
    constraints=_STENCIL_CONSTRAINTS,
    workloads=({"m": 256, "n": 256},),
    input_specs=_jacobi_specs,
    baseline=_jacobi_baseline,
    tuned=_jacobi_tuned,
    flops=lambda d: 4 * d["m"] * d["n"],
    bytes_moved=lambda d: 8 * (d["m"] + 2) * (d["n"] + 2),
    default_params=lambda d: {"tile_m": 32, "tile_n": 32},
)


# ---------------------------------------------------------------------------
# SpMV family (ref [1] analog): ELLPACK with graph-side gather
# ---------------------------------------------------------------------------

_SPMV_PARAMS = (
    Param("row_block", "rb", (64, 256, 1024, 4096)),
    Param("col_chunk", "cc", (8, 16, 32)),
)
_SPMV_CONSTRAINTS = (
    "row_block <= nrows",
    "col_chunk <= k",
    "nrows % row_block == 0",
    "k % col_chunk == 0",
)
_SPMV_SIZES = ((4096, 32), (16384, 32), (65536, 32))


def _spmv_specs(dims):
    r, k = dims["nrows"], dims["k"]
    return [
        ("values", jax.ShapeDtypeStruct((r, k), f32)),
        ("col_idx", jax.ShapeDtypeStruct((r, k), i32)),
        ("x", jax.ShapeDtypeStruct((r,), f32)),
    ]


def _spmv_baseline(dims):
    return lambda v, ci, x: (ref.spmv_ell(v, ci, x),)


def _spmv_tuned(dims, params):
    fn = make_spmv_ell(
        dims["nrows"], dims["k"], params["row_block"], params["col_chunk"]
    )

    def run(v, ci, x):
        return (fn(v, x[ci]),)

    return run


SPMV_ELL = Family(
    name="spmv_ell",
    params=_SPMV_PARAMS,
    constraints=_SPMV_CONSTRAINTS,
    workloads=tuple({"nrows": r, "k": k} for r, k in _SPMV_SIZES),
    input_specs=_spmv_specs,
    baseline=_spmv_baseline,
    tuned=_spmv_tuned,
    flops=lambda d: 2 * d["nrows"] * d["k"],
    bytes_moved=lambda d: 8 * d["nrows"] * d["k"] + 8 * d["nrows"],
    default_params=lambda d: {"row_block": 256, "col_chunk": 32},
)


# ---------------------------------------------------------------------------
# Matmul family: blocked GEMM (MXU-mapping study)
# ---------------------------------------------------------------------------

_MM_PARAMS = (
    Param("tile_m", "tm", (32, 64, 128)),
    Param("tile_n", "tn", (32, 64, 128)),
    Param("tile_k", "tk", (32, 64, 128, 256)),
)
_MM_CONSTRAINTS = (
    "tile_m <= m",
    "tile_n <= n",
    "tile_k <= k",
    "m % tile_m == 0",
    "n % tile_n == 0",
    "k % tile_k == 0",
)
_MM_SIZES = ((256, 256, 256), (512, 512, 512))


def _mm_specs(dims):
    m, n, k = dims["m"], dims["n"], dims["k"]
    return [
        ("a", jax.ShapeDtypeStruct((m, k), f32)),
        ("b", jax.ShapeDtypeStruct((k, n), f32)),
    ]


def _mm_baseline(dims):
    return lambda a, b: (ref.matmul(a, b),)


def _mm_tuned(dims, params):
    fn = make_matmul(
        dims["m"], dims["n"], dims["k"],
        params["tile_m"], params["tile_n"], params["tile_k"],
    )
    return lambda a, b: (fn(a, b),)


MATMUL = Family(
    name="matmul",
    params=_MM_PARAMS,
    constraints=_MM_CONSTRAINTS,
    workloads=tuple({"m": m, "n": n, "k": k} for m, n, k in _MM_SIZES),
    input_specs=_mm_specs,
    baseline=_mm_baseline,
    tuned=_mm_tuned,
    flops=lambda d: 2 * d["m"] * d["n"] * d["k"],
    bytes_moved=lambda d: 4 * (d["m"] * d["k"] + d["k"] * d["n"] + d["m"] * d["n"]),
    default_params=lambda d: {"tile_m": 64, "tile_n": 64, "tile_k": 64},
)


FAMILIES: Dict[str, Family] = {
    f.name: f for f in (AXPY, TRIAD, DOT, STENCIL2D, JACOBI, SPMV_ELL, MATMUL)
}


def get_family(name: str) -> Family:
    return FAMILIES[name]


def lower_to_hlo_text(fn, specs: Sequence[jax.ShapeDtypeStruct], return_tuple: bool = True) -> str:
    """Lower a jax callable to HLO *text* — the rust-side interchange.

    Text, not ``HloModuleProto.serialize()``: jax >= 0.5 emits protos with
    64-bit instruction ids which xla_extension 0.5.1 (the version the
    published ``xla`` crate binds) rejects; the text parser reassigns ids.

    ``return_tuple=False`` produces an *untupled* single-output entry:
    PJRT then returns a plain array buffer that can be fed straight back
    as the next call's input — the device-resident iteration path the
    Jacobi solver uses (no host transfer per sweep).
    """
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()
