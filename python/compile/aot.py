"""AOT driver: expand every kernel family's variant grid to HLO artifacts.

This is the Orio "code transformation" stage of the paper's pipeline: for
each (family, workload) it lowers

  * one **baseline** artifact — the pure-jnp reference program, XLA's
    default auto-vectorization (the paper's un-annotated `icc -O3` code),
  * one artifact **per valid parameter point** — the Pallas-scheduled
    specialization (the paper's pragma-expanded variants),

into ``artifacts/<family>/<workload>/<variant>.hlo.txt``, plus a
``manifest.json`` the rust coordinator consumes.  HLO *text* is the
interchange format (xla_extension 0.5.1 rejects jax>=0.5 serialized
protos).

Incremental: an artifact whose file already exists is skipped unless
``--force``; the manifest is always rewritten (it is cheap and must stay
in sync with the variant grids defined in model.py).

Usage:  cd python && python -m compile.aot --out ../artifacts
        [--families axpy,dot] [--quick] [--force]
"""

import argparse
import json
import os
import sys
import time

from . import model


def _dtype_str(dt) -> str:
    import jax.numpy as jnp

    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[jnp.dtype(dt)]


def _workload_entry(fam: model.Family, dims, out_root: str, force: bool, quick: bool):
    """Lower baseline + all variants for one workload; return manifest node."""
    tag = fam.tag(dims)
    wdir = os.path.join(out_root, fam.name, tag)
    os.makedirs(wdir, exist_ok=True)

    specs = fam.input_specs(dims)
    shape_specs = [s for _, s in specs]

    # Families whose artifacts are iterated output-as-next-input get a
    # second, *untupled* lowering per variant (suffix .nt.hlo.txt): PJRT
    # then yields a plain array buffer the rust solver feeds straight
    # back without a host round-trip per step.
    untupled = fam.name in ("jacobi",)

    def emit(rel: str, make_fn, return_tuple: bool = True) -> str:
        path = os.path.join(out_root, rel)
        if force or not os.path.exists(path):
            text = model.lower_to_hlo_text(make_fn(), shape_specs, return_tuple)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                f.write(text)
            os.replace(tmp, path)
        return rel

    base_rel = emit(f"{fam.name}/{tag}/base.hlo.txt", lambda: fam.baseline(dims))
    if untupled:
        emit(
            f"{fam.name}/{tag}/base.nt.hlo.txt",
            lambda: fam.baseline(dims),
            return_tuple=False,
        )

    default = fam.default_params(dims)
    default_id = fam.variant_id(default)

    grid = fam.grid(dims)
    if quick:
        # --quick keeps the extreme corners + one mid point per workload so
        # tests exercise the full pipeline without the full expansion.
        # The default (un-annotated) schedule always survives pruning.
        keep = {0, len(grid) // 2, len(grid) - 1}
        grid = [g for i, g in enumerate(grid) if i in keep or g == default]

    variants = []
    for params in grid:
        vid = fam.variant_id(params)
        rel = emit(
            f"{fam.name}/{tag}/{vid}.hlo.txt",
            lambda params=params: fam.tuned(dims, params),
        )
        if untupled:
            emit(
                f"{fam.name}/{tag}/{vid}.nt.hlo.txt",
                lambda params=params: fam.tuned(dims, params),
                return_tuple=False,
            )
        variants.append({"id": vid, "params": params, "path": rel})

    # Compute the output spec by tracing the baseline's avals.
    import jax

    out_aval = jax.eval_shape(fam.baseline(dims), *shape_specs)[0]

    return {
        "tag": tag,
        "dims": dims,
        "inputs": [
            {"name": name, "dtype": _dtype_str(s.dtype), "shape": list(s.shape)}
            for name, s in specs
        ],
        "output": {
            "dtype": _dtype_str(out_aval.dtype),
            "shape": list(out_aval.shape),
        },
        "flops": fam.flops(dims),
        "bytes": fam.bytes_moved(dims),
        "baseline": base_rel,
        "default": default_id,
        "untupled": untupled,
        "variants": variants,
    }


def generate(out_root: str, families=None, quick: bool = False, force: bool = False):
    """Generate artifacts + manifest; returns the manifest dict."""
    selected = families or sorted(model.FAMILIES)
    manifest = {"version": 1, "generated_by": "compile.aot", "kernels": []}
    t0 = time.time()
    count = 0
    for name in selected:
        fam = model.get_family(name)
        workloads = []
        for dims in fam.workloads:
            entry = _workload_entry(fam, dims, out_root, force, quick)
            workloads.append(entry)
            count += 1 + len(entry["variants"])
            print(
                f"[aot] {fam.name}/{entry['tag']}: "
                f"{len(entry['variants'])} variants + baseline",
                flush=True,
            )
        manifest["kernels"].append(
            {
                "name": fam.name,
                "params": [
                    {"name": p.name, "abbrev": p.abbrev, "values": list(p.values)}
                    for p in fam.params
                ],
                "constraints": list(fam.constraints),
                "workloads": workloads,
            }
        )
    mpath = os.path.join(out_root, "manifest.json")
    tmp = mpath + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    os.replace(tmp, mpath)
    print(f"[aot] {count} artifacts in {time.time() - t0:.1f}s -> {mpath}")
    return manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output root")
    ap.add_argument(
        "--families",
        default="",
        help="comma-separated family subset (default: all)",
    )
    ap.add_argument(
        "--quick", action="store_true", help="corner variants only (for tests)"
    )
    ap.add_argument("--force", action="store_true", help="re-lower existing files")
    args = ap.parse_args(argv)
    fams = [f for f in args.families.split(",") if f] or None
    os.makedirs(args.out, exist_ok=True)
    generate(args.out, families=fams, quick=args.quick, force=args.force)
    return 0


if __name__ == "__main__":
    sys.exit(main())
