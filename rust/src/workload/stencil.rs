//! Grid inputs for the stencil / Jacobi kernel families.
//!
//! Grids are stored padded: shape (m+2, n+2) whose outer ring is the
//! Dirichlet boundary and whose interior the sweep updates.

use crate::runtime::TensorData;
use crate::util::rng::Rng;

/// Random padded grid (tuning workloads — exercises all value paths).
pub fn random_padded_grid(rng: &mut Rng, m: usize, n: usize) -> TensorData {
    TensorData::f32(vec![m + 2, n + 2], rng.gauss_vec_f32((m + 2) * (n + 2)))
}

/// Hot-boundary/cold-interior grid: boundary = `boundary_temp`,
/// interior = 0.  The heat-diffusion start state of the E2E solver.
pub fn hot_boundary_grid(m: usize, n: usize, boundary_temp: f32) -> TensorData {
    let (rows, cols) = (m + 2, n + 2);
    let mut data = vec![0.0f32; rows * cols];
    for j in 0..cols {
        data[j] = boundary_temp;
        data[(rows - 1) * cols + j] = boundary_temp;
    }
    for i in 0..rows {
        data[i * cols] = boundary_temp;
        data[i * cols + cols - 1] = boundary_temp;
    }
    TensorData::f32(vec![rows, cols], data)
}

/// Residual between two padded grids (max-abs over the interior) — the
/// solver's convergence metric, computed host-side.
pub fn interior_residual(a: &[f32], b: &[f32], m: usize, n: usize) -> f32 {
    let cols = n + 2;
    let mut worst = 0.0f32;
    for i in 1..=m {
        for j in 1..=n {
            let d = (a[i * cols + j] - b[i * cols + j]).abs();
            if d > worst {
                worst = d;
            }
        }
    }
    worst
}

/// Analytic steady state of the hot-boundary problem is uniform
/// `boundary_temp`; distance from it measures solver progress.
pub fn distance_from_steady_state(grid: &[f32], m: usize, n: usize, temp: f32) -> f32 {
    let cols = n + 2;
    let mut worst = 0.0f32;
    for i in 1..=m {
        for j in 1..=n {
            let d = (grid[i * cols + j] - temp).abs();
            if d > worst {
                worst = d;
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_boundary_layout() {
        let t = hot_boundary_grid(3, 4, 2.0);
        assert_eq!(t.shape(), &[5, 6]);
        let g = t.as_f32().unwrap();
        // Boundary ring all 2.0.
        for j in 0..6 {
            assert_eq!(g[j], 2.0);
            assert_eq!(g[4 * 6 + j], 2.0);
        }
        for i in 0..5 {
            assert_eq!(g[i * 6], 2.0);
            assert_eq!(g[i * 6 + 5], 2.0);
        }
        // Interior all 0.
        for i in 1..4 {
            for j in 1..5 {
                assert_eq!(g[i * 6 + j], 0.0);
            }
        }
    }

    #[test]
    fn residual_detects_interior_change_only() {
        let a = hot_boundary_grid(3, 3, 1.0);
        let mut b_data = a.as_f32().unwrap().to_vec();
        b_data[0] = 99.0; // boundary corner — must be ignored
        assert_eq!(interior_residual(a.as_f32().unwrap(), &b_data, 3, 3), 0.0);
        b_data[1 * 5 + 2] += 0.25; // interior cell
        assert_eq!(interior_residual(a.as_f32().unwrap(), &b_data, 3, 3), 0.25);
    }

    #[test]
    fn steady_state_distance() {
        let t = hot_boundary_grid(2, 2, 1.0);
        // Cold interior is distance 1.0 from the all-1.0 steady state.
        assert_eq!(distance_from_steady_state(t.as_f32().unwrap(), 2, 2, 1.0), 1.0);
    }

    #[test]
    fn random_grid_shape() {
        let mut rng = Rng::new(2);
        let t = random_padded_grid(&mut rng, 8, 16);
        assert_eq!(t.shape(), &[10, 18]);
    }
}
