//! Workload generators: deterministic inputs for every kernel family.
//!
//! The paper's evaluation sweeps input sizes for fixed synthetic
//! workloads; these generators are the rust-side source of those inputs
//! (the python hypothesis tests mirror the same constructions).  All
//! generation is seeded (xorshift) so tuning, tests, and benches see
//! identical data run-to-run.

pub mod gemm;
pub mod spmv;
pub mod stencil;
pub mod vectors;

use anyhow::Result;

use crate::runtime::registry::Workload;
use crate::runtime::TensorData;
use crate::util::rng::Rng;

/// Generate the input tensors for a (kernel, workload) pair, in the
/// manifest's declared order, validated against the declared specs.
pub fn inputs_for(kernel: &str, wl: &Workload, seed: u64) -> Result<Vec<TensorData>> {
    let mut rng = Rng::new(seed ^ fxhash(kernel) ^ fxhash(&wl.tag));
    let dim = |name: &str| -> Result<usize> {
        wl.dims
            .get(name)
            .map(|&v| v as usize)
            .ok_or_else(|| anyhow::anyhow!("workload {} missing dim {name}", wl.tag))
    };

    let inputs: Vec<TensorData> = match kernel {
        "axpy" => {
            let n = dim("n")?;
            vec![
                TensorData::scalar_f32(1.0 + rng.gauss() as f32 * 0.5),
                vectors::gauss(&mut rng, n),
                vectors::gauss(&mut rng, n),
            ]
        }
        "triad" => {
            let n = dim("n")?;
            vec![
                TensorData::scalar_f32(1.0 + rng.gauss() as f32 * 0.5),
                TensorData::scalar_f32(-0.5 + rng.gauss() as f32 * 0.5),
                vectors::gauss(&mut rng, n),
                vectors::gauss(&mut rng, n),
            ]
        }
        "dot" => {
            let n = dim("n")?;
            vec![vectors::gauss(&mut rng, n), vectors::gauss(&mut rng, n)]
        }
        "stencil2d" => {
            let (m, n) = (dim("m")?, dim("n")?);
            vec![stencil::random_padded_grid(&mut rng, m, n)]
        }
        "jacobi" => {
            let (m, n) = (dim("m")?, dim("n")?);
            // Physically meaningful start: hot Dirichlet boundary, cold
            // interior — the E2E solver diffuses heat inward.
            vec![stencil::hot_boundary_grid(m, n, 1.0)]
        }
        "spmv_ell" => {
            let (nrows, k) = (dim("nrows")?, dim("k")?);
            let (values, col_idx) = spmv::banded_ell(&mut rng, nrows, k);
            let x = vectors::gauss(&mut rng, nrows);
            vec![values, col_idx, x]
        }
        // The native GEMM family (workload::gemm) shares the matmul
        // input signature; accepting both names here lets artifact-
        // backed pipelines address the same (kernel, workload) keys the
        // native sweep records.
        "matmul" | "gemm" => {
            let (m, n, k) = (dim("m")?, dim("n")?, dim("k")?);
            vec![
                TensorData::f32(vec![m, k], rng.gauss_vec_f32(m * k)),
                TensorData::f32(vec![k, n], rng.gauss_vec_f32(k * n)),
            ]
        }
        other => return Err(anyhow::anyhow!("no workload generator for kernel {other}")),
    };

    // Validate against the manifest's declared signature.
    if inputs.len() != wl.inputs.len() {
        return Err(anyhow::anyhow!(
            "{kernel}/{}: generated {} inputs, manifest declares {}",
            wl.tag,
            inputs.len(),
            wl.inputs.len()
        ));
    }
    for (t, spec) in inputs.iter().zip(&wl.inputs) {
        if !t.matches(spec) {
            return Err(anyhow::anyhow!(
                "{kernel}/{}: input `{}` mismatch: generated {:?}/{:?}, declared {:?}/{}",
                wl.tag,
                spec.name,
                t.dtype(),
                t.shape(),
                spec.shape,
                spec.dtype.as_str(),
            ));
        }
    }
    Ok(inputs)
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::literal::{DType, TensorSpec};
    use std::collections::BTreeMap;

    fn wl(kernel: &str) -> Workload {
        let (dims, inputs): (Vec<(&str, i64)>, Vec<(&str, DType, Vec<usize>)>) = match kernel {
            "axpy" => (
                vec![("n", 64)],
                vec![
                    ("a", DType::F32, vec![1]),
                    ("x", DType::F32, vec![64]),
                    ("y", DType::F32, vec![64]),
                ],
            ),
            "dot" => (
                vec![("n", 64)],
                vec![("x", DType::F32, vec![64]), ("y", DType::F32, vec![64])],
            ),
            "spmv_ell" => (
                vec![("nrows", 32), ("k", 8)],
                vec![
                    ("values", DType::F32, vec![32, 8]),
                    ("col_idx", DType::I32, vec![32, 8]),
                    ("x", DType::F32, vec![32]),
                ],
            ),
            "jacobi" => (
                vec![("m", 8), ("n", 16)],
                vec![("grid", DType::F32, vec![10, 18])],
            ),
            _ => panic!("unsupported test kernel"),
        };
        Workload {
            tag: "test".into(),
            dims: dims.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>(),
            inputs: inputs
                .into_iter()
                .map(|(n, d, s)| TensorSpec { name: n.into(), dtype: d, shape: s })
                .collect(),
            output: TensorSpec { name: "out".into(), dtype: DType::F32, shape: vec![1] },
            flops: 1,
            bytes: 1,
            baseline: "x".into(),
            default: None,
            untupled: false,
            variants: vec![],
        }
    }

    #[test]
    fn generates_matching_signatures() {
        for kernel in ["axpy", "dot", "spmv_ell", "jacobi"] {
            let w = wl(kernel);
            let inputs = inputs_for(kernel, &w, 1).unwrap();
            assert_eq!(inputs.len(), w.inputs.len(), "{kernel}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let w = wl("axpy");
        let a = inputs_for("axpy", &w, 7).unwrap();
        let b = inputs_for("axpy", &w, 7).unwrap();
        assert_eq!(a, b);
        let c = inputs_for("axpy", &w, 8).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn spmv_col_indices_in_range() {
        let w = wl("spmv_ell");
        let inputs = inputs_for("spmv_ell", &w, 3).unwrap();
        let ci = inputs[1].as_i32().unwrap();
        assert!(ci.iter().all(|&c| (0..32).contains(&c)));
    }

    #[test]
    fn unknown_kernel_errors() {
        let w = wl("axpy");
        assert!(inputs_for("nonesuch", &w, 1).is_err());
    }

    #[test]
    fn jacobi_grid_has_hot_boundary_cold_interior() {
        let w = wl("jacobi");
        let inputs = inputs_for("jacobi", &w, 1).unwrap();
        let g = inputs[0].as_f32().unwrap();
        let (rows, cols) = (10, 18);
        assert_eq!(g[0], 1.0); // corner
        assert_eq!(g[cols - 1], 1.0);
        assert_eq!(g[(rows - 1) * cols], 1.0);
        assert_eq!(g[cols + 1], 0.0); // first interior cell
    }
}
