//! ELLPACK sparse-matrix inputs (the ref-[1] SpMV study).
//!
//! Two generators matching the python test suite's constructions:
//! banded (the well-conditioned, cache-friendly case — stencil-like
//! matrices from PDE discretizations) and uniform-random columns (the
//! gather-hostile case).  Padding entries carry value 0.0 and column 0,
//! so they contribute nothing regardless of x.

use crate::runtime::TensorData;
use crate::util::rng::Rng;

/// Banded ELL matrix: row i has up to `k` entries centered on the
/// diagonal.  Returns (values f32[nrows,k], col_idx i32[nrows,k]).
pub fn banded_ell(rng: &mut Rng, nrows: usize, k: usize) -> (TensorData, TensorData) {
    let mut values = vec![0.0f32; nrows * k];
    let mut cols = vec![0i32; nrows * k];
    for i in 0..nrows {
        let lo = i.saturating_sub(k / 2);
        let hi = (lo + k).min(nrows);
        let width = hi - lo;
        for (slot, col) in (lo..hi).enumerate() {
            values[i * k + slot] = rng.gauss() as f32;
            cols[i * k + slot] = col as i32;
        }
        debug_assert!(width <= k);
    }
    (
        TensorData::f32(vec![nrows, k], values),
        TensorData::i32(vec![nrows, k], cols),
    )
}

/// Uniform-random-column ELL matrix (every slot filled).
pub fn random_ell(rng: &mut Rng, nrows: usize, k: usize) -> (TensorData, TensorData) {
    let values: Vec<f32> = (0..nrows * k).map(|_| rng.gauss() as f32).collect();
    let cols: Vec<i32> = (0..nrows * k)
        .map(|_| rng.gen_range(nrows) as i32)
        .collect();
    (
        TensorData::f32(vec![nrows, k], values),
        TensorData::i32(vec![nrows, k], cols),
    )
}

/// Identity matrix in ELL form (analytic checks: y == x).
pub fn identity_ell(nrows: usize, k: usize) -> (TensorData, TensorData) {
    assert!(k >= 1);
    let mut values = vec![0.0f32; nrows * k];
    let mut cols = vec![0i32; nrows * k];
    for i in 0..nrows {
        values[i * k] = 1.0;
        cols[i * k] = i as i32;
    }
    (
        TensorData::f32(vec![nrows, k], values),
        TensorData::i32(vec![nrows, k], cols),
    )
}

/// Host-side ELL SpMV oracle (validates artifacts in integration tests).
pub fn spmv_reference(values: &[f32], col_idx: &[i32], x: &[f32], nrows: usize, k: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; nrows];
    for i in 0..nrows {
        let mut acc = 0.0f32;
        for j in 0..k {
            acc += values[i * k + j] * x[col_idx[i * k + j] as usize];
        }
        y[i] = acc;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banded_indices_in_range_and_sorted_per_row() {
        let mut rng = Rng::new(6);
        let (_, ci) = banded_ell(&mut rng, 64, 8);
        let cols = ci.as_i32().unwrap();
        assert!(cols.iter().all(|&c| (0..64).contains(&c)));
        // Filled prefix of each row is strictly increasing.
        for i in 0..64 {
            let row = &cols[i * 8..(i + 1) * 8];
            for w in row.windows(2) {
                if w[1] != 0 {
                    // within the filled prefix
                    assert!(w[1] > w[0] || w[1] == 0);
                }
            }
        }
    }

    #[test]
    fn random_indices_in_range() {
        let mut rng = Rng::new(8);
        let (_, ci) = random_ell(&mut rng, 32, 4);
        assert!(ci.as_i32().unwrap().iter().all(|&c| (0..32).contains(&c)));
    }

    #[test]
    fn identity_spmv_is_identity() {
        let (v, ci) = identity_ell(16, 4);
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.5).collect();
        let y = spmv_reference(
            v.as_f32().unwrap(),
            ci.as_i32().unwrap(),
            &x,
            16,
            4,
        );
        assert_eq!(y, x);
    }

    #[test]
    fn padding_contributes_nothing() {
        // Row with a single entry: the k-1 padding slots (value 0, col 0)
        // must not perturb the result even when x[0] is huge.
        let mut values = vec![0.0f32; 4];
        let mut cols = vec![0i32; 4];
        values[0] = 2.0;
        cols[0] = 1;
        let x = vec![1e9, 3.0];
        let y = spmv_reference(&values, &cols, &x, 1, 4);
        assert_eq!(y, vec![6.0]);
    }
}
