//! Dense vector inputs for the Figure-1 (SIMD loop) kernel family.

use crate::runtime::TensorData;
use crate::util::rng::Rng;

/// Standard-normal f32 vector.
pub fn gauss(rng: &mut Rng, n: usize) -> TensorData {
    TensorData::f32(vec![n], rng.gauss_vec_f32(n))
}

/// Linearly spaced vector in [lo, hi] (analytic-check workloads).
pub fn linspace(lo: f32, hi: f32, n: usize) -> TensorData {
    assert!(n >= 2, "linspace needs n >= 2");
    let step = (hi - lo) / (n - 1) as f32;
    TensorData::f32(vec![n], (0..n).map(|i| lo + step * i as f32).collect())
}

/// Constant vector.
pub fn constant(v: f32, n: usize) -> TensorData {
    TensorData::f32(vec![n], vec![v; n])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauss_shape_and_determinism() {
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        let a = gauss(&mut r1, 128);
        let b = gauss(&mut r2, 128);
        assert_eq!(a.shape(), &[128]);
        assert_eq!(a, b);
    }

    #[test]
    fn linspace_endpoints() {
        let t = linspace(-1.0, 1.0, 5);
        let d = t.as_f32().unwrap();
        assert_eq!(d[0], -1.0);
        assert_eq!(d[4], 1.0);
        assert_eq!(d[2], 0.0);
    }

    #[test]
    #[should_panic]
    fn linspace_n1_panics() {
        linspace(0.0, 1.0, 1);
    }

    #[test]
    fn constant_fill() {
        let t = constant(2.5, 16);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 2.5));
    }
}
