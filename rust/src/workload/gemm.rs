//! Dense GEMM (C = A·B) — the canonical autotuning stress test.
//!
//! The Kernel Tuning Toolkit benchmark paper (Petrovič et al. 2019)
//! uses dense matrix multiply as its reference workload because its
//! schedule space (tiling, loop order, unrolling) exposes every cache
//! and ILP effect an autotuner must navigate.  This module is the
//! *native* GEMM family: the blocked/tiled kernel runs host-side in
//! Rust, so the whole sweep → portfolio → serve story is hermetic — no
//! pre-lowered artifacts or PJRT runtime required.  A naive
//! triple-loop reference provides the correctness oracle, exactly as
//! the artifact-backed families gate against their XLA baseline.
//!
//! Tuning dimensions (see [`space`]):
//!
//! * `loop_order` — ijk (dot-product form), ikj (row-streaming, the
//!   cache-friendly order for row-major operands), jki (column-walking);
//! * `tile_m` / `tile_n` — the i/j blocking factors (tiles clamp at
//!   matrix edges, so every config is valid for every shape);
//! * `unroll` — manual unroll factor of the innermost loop.

use std::collections::BTreeMap;

use crate::coordinator::spec::{Config, TuningSpec};
use crate::runtime::registry::ParamDef;
use crate::util::rng::Rng;

/// The kernel name GEMM records use in the perf DB and serve protocol.
pub const KERNEL: &str = "gemm";

/// One dense GEMM problem shape: C[m,n] = A[m,k] · B[k,n].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmShape {
    /// Rows of A and C.
    pub m: usize,
    /// Columns of B and C.
    pub n: usize,
    /// Columns of A / rows of B (the reduction dimension).
    pub k: usize,
}

impl GemmShape {
    /// Construct a shape (all dimensions must be non-zero).
    pub fn new(m: usize, n: usize, k: usize) -> GemmShape {
        assert!(m > 0 && n > 0 && k > 0, "GEMM dims must be non-zero");
        GemmShape { m, n, k }
    }

    /// Workload tag used as the perf-DB key, e.g. `m128n128k64`.
    pub fn tag(&self) -> String {
        format!("m{}n{}k{}", self.m, self.n, self.k)
    }

    /// Dims map in the manifest/workload convention.
    pub fn dims(&self) -> BTreeMap<String, i64> {
        [
            ("m".to_string(), self.m as i64),
            ("n".to_string(), self.n as i64),
            ("k".to_string(), self.k as i64),
        ]
        .into_iter()
        .collect()
    }

    /// Multiply-add flop count (2·m·n·k).
    pub fn flops(&self) -> u64 {
        2 * self.m as u64 * self.n as u64 * self.k as u64
    }

    /// Total operand + result footprint in bytes (f32 A, B, C).
    pub fn footprint_bytes(&self) -> u64 {
        4 * (self.m as u64 * self.k as u64
            + self.k as u64 * self.n as u64
            + self.m as u64 * self.n as u64)
    }
}

/// The standard shape sweep the portfolio experiments run over: square
/// sizes crossing the cache hierarchy plus skinny/tall/deep rectangles
/// (the shapes "A Few Fit Most" shows cluster into a few regimes).
pub fn default_sweep() -> Vec<GemmShape> {
    vec![
        GemmShape::new(32, 32, 32),
        GemmShape::new(64, 64, 64),
        GemmShape::new(96, 96, 96),
        GemmShape::new(160, 160, 160),
        GemmShape::new(192, 192, 64),
        GemmShape::new(256, 64, 32),
        GemmShape::new(64, 256, 32),
        GemmShape::new(32, 32, 512),
        GemmShape::new(512, 16, 64),
        GemmShape::new(24, 24, 96),
    ]
}

/// Shrunk sweep for smoke runs (`BENCH_QUICK`, CI, `--quick`).
pub fn quick_sweep() -> Vec<GemmShape> {
    vec![
        GemmShape::new(24, 24, 24),
        GemmShape::new(48, 48, 48),
        GemmShape::new(64, 16, 16),
        GemmShape::new(16, 64, 32),
    ]
}

/// The GEMM schedule space in canonical parameter order.  Shape-
/// independent: tiles clamp at matrix edges, so no constraints prune
/// the space and every shape shares one config enumeration (which is
/// what lets a portfolio config apply across the whole sweep).
pub fn space() -> TuningSpec {
    TuningSpec::new(
        KERNEL,
        "space",
        vec![
            ParamDef { name: "loop_order".into(), abbrev: "o".into(), values: vec![0, 1, 2] },
            ParamDef { name: "tile_m".into(), abbrev: "tm".into(), values: vec![8, 32, 128] },
            ParamDef { name: "tile_n".into(), abbrev: "tn".into(), values: vec![8, 32, 128] },
            ParamDef { name: "unroll".into(), abbrev: "u".into(), values: vec![1, 4] },
        ],
        &[],
        BTreeMap::new(),
    )
    .expect("gemm space has no constraints to fail parsing")
}

/// Every config of [`space`], in canonical enumeration order.
pub fn configs() -> Vec<Config> {
    space().enumerate()
}

/// The un-annotated default schedule: naive loop order, effectively
/// untiled (tile 128 covers most sweep shapes whole), no unrolling —
/// what a programmer writes before tuning.  This is the single-default
/// comparator of the portfolio bench.
pub fn default_config() -> Config {
    [
        ("loop_order".to_string(), 0i64),
        ("tile_m".to_string(), 128i64),
        ("tile_n".to_string(), 128i64),
        ("unroll".to_string(), 1i64),
    ]
    .into_iter()
    .collect()
}

/// Deterministic operands for a shape: (A[m·k], B[k·n]) row-major,
/// standard normal, seeded by (seed, tag) like every other workload
/// generator.
pub fn inputs(shape: GemmShape, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in shape.tag().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut rng = Rng::new(seed ^ h);
    let a = rng.gauss_vec_f32(shape.m * shape.k);
    let b = rng.gauss_vec_f32(shape.k * shape.n);
    (a, b)
}

/// Naive triple-loop reference (ascending-k accumulation) — the
/// correctness oracle every tiled variant is gated against.
pub fn reference(a: &[f32], b: &[f32], shape: GemmShape) -> Vec<f32> {
    let GemmShape { m, n, k } = shape;
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f32;
            for l in 0..k {
                s += a[i * k + l] * b[l * n + j];
            }
            c[i * n + j] = s;
        }
    }
    c
}

/// Run the blocked/tiled GEMM under a schedule config (see module docs
/// for the dimensions).  Handles odd/rectangular shapes by clamping
/// tiles at the edges; unknown/missing parameters fall back to the
/// naive schedule, so a transferred config from a richer space still
/// executes.
pub fn run_config(a: &[f32], b: &[f32], shape: GemmShape, config: &Config) -> Vec<f32> {
    let GemmShape { m, n, k } = shape;
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    let param = |name: &str, fallback: i64| -> usize {
        config.get(name).copied().unwrap_or(fallback).max(1) as usize
    };
    let tile_m = param("tile_m", 128);
    let tile_n = param("tile_n", 128);
    let unroll = param("unroll", 1).min(MAX_UNROLL);
    let order = config.get("loop_order").copied().unwrap_or(0);

    let mut c = vec![0.0f32; m * n];
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + tile_m).min(m);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + tile_n).min(n);
            match order {
                1 => tile_ikj(a, b, &mut c, (n, k), (i0, i1), (j0, j1), unroll),
                2 => tile_jki(a, b, &mut c, (n, k), (i0, i1), (j0, j1), unroll),
                _ => tile_ijk(a, b, &mut c, (n, k), (i0, i1), (j0, j1), unroll),
            }
            j0 = j1;
        }
        i0 = i1;
    }
    c
}

/// Hard cap on the unroll factor (sizes the accumulator array).
const MAX_UNROLL: usize = 8;

/// ijk within a tile: dot-product form, `unroll` partial accumulators
/// over the reduction (re-associates the sum — gated by tolerance).
fn tile_ijk(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    (n, k): (usize, usize),
    (i0, i1): (usize, usize),
    (j0, j1): (usize, usize),
    unroll: usize,
) {
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        for j in j0..j1 {
            let mut acc = [0.0f32; MAX_UNROLL];
            let chunks = k / unroll * unroll;
            let mut l = 0;
            while l < chunks {
                for lane in 0..unroll {
                    acc[lane] += arow[l + lane] * b[(l + lane) * n + j];
                }
                l += unroll;
            }
            let mut s = 0.0f32;
            for value in acc.iter().take(unroll) {
                s += value;
            }
            while l < k {
                s += arow[l] * b[l * n + j];
                l += 1;
            }
            c[i * n + j] = s;
        }
    }
}

/// ikj within a tile: stream one A element against a B row slice into
/// the C row slice (row-major friendly).  Accumulation stays in
/// ascending-k order for every element, so the result is bit-identical
/// to the reference at any unroll.
fn tile_ikj(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    (n, k): (usize, usize),
    (i0, i1): (usize, usize),
    (j0, j1): (usize, usize),
    unroll: usize,
) {
    let width = j1 - j0;
    let chunks = width / unroll * unroll;
    for i in i0..i1 {
        for l in 0..k {
            let ail = a[i * k + l];
            let brow = &b[l * n + j0..l * n + j1];
            let crow = &mut c[i * n + j0..i * n + j1];
            let mut idx = 0;
            while idx < chunks {
                for lane in 0..unroll {
                    crow[idx + lane] += ail * brow[idx + lane];
                }
                idx += unroll;
            }
            while idx < width {
                crow[idx] += ail * brow[idx];
                idx += 1;
            }
        }
    }
}

/// jki within a tile: walk columns of C with i innermost (strided —
/// the deliberately cache-hostile order).  Ascending-k accumulation,
/// bit-identical to the reference at any unroll.
fn tile_jki(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    (n, k): (usize, usize),
    (i0, i1): (usize, usize),
    (j0, j1): (usize, usize),
    unroll: usize,
) {
    let height = i1 - i0;
    let chunks = height / unroll * unroll;
    for j in j0..j1 {
        for l in 0..k {
            let blj = b[l * n + j];
            let mut idx = 0;
            while idx < chunks {
                for lane in 0..unroll {
                    let i = i0 + idx + lane;
                    c[i * n + j] += a[i * k + l] * blj;
                }
                idx += unroll;
            }
            while idx < height {
                let i = i0 + idx;
                c[i * n + j] += a[i * k + l] * blj;
                idx += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::selection::{check_outputs, Tolerance};

    #[test]
    fn space_is_shape_independent_and_sized() {
        let all = configs();
        assert_eq!(all.len(), 3 * 3 * 3 * 2);
        let spec = space();
        assert!(all.iter().all(|c| spec.is_valid(c)));
        assert!(all.contains(&default_config()));
    }

    #[test]
    fn config_ids_follow_declaration_order() {
        let spec = space();
        assert_eq!(spec.config_id(&default_config()), "o0_tm128_tn128_u1");
    }

    #[test]
    fn inputs_are_deterministic_per_shape_and_seed() {
        let s = GemmShape::new(8, 6, 4);
        let (a1, b1) = inputs(s, 7);
        let (a2, b2) = inputs(s, 7);
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
        let (a3, _) = inputs(s, 8);
        assert_ne!(a1, a3);
        assert_eq!(a1.len(), 8 * 4);
        assert_eq!(b1.len(), 4 * 6);
    }

    #[test]
    fn identity_times_anything_is_identity() {
        // A = I (4x4), B arbitrary: C must equal B for every config.
        let shape = GemmShape::new(4, 5, 4);
        let mut a = vec![0.0f32; 16];
        for i in 0..4 {
            a[i * 4 + i] = 1.0;
        }
        let (_, b) = inputs(shape, 3);
        for config in configs() {
            let c = run_config(&a, &b, shape, &config);
            assert_eq!(c, b, "config {:?}", space().config_id(&config));
        }
    }

    #[test]
    fn every_config_matches_reference_on_odd_shapes() {
        let tol = Tolerance::default();
        for shape in [
            GemmShape::new(1, 1, 1),
            GemmShape::new(3, 5, 7),
            GemmShape::new(37, 17, 29),
            GemmShape::new(65, 33, 17),
            GemmShape::new(128, 1, 8),
        ] {
            let (a, b) = inputs(shape, 11);
            let want = reference(&a, &b, shape);
            for config in configs() {
                let got = run_config(&a, &b, shape, &config);
                let report = check_outputs(&got, &want, tol);
                assert!(
                    report.ok,
                    "{} vs reference on {}: max abs err {:.3e}",
                    space().config_id(&config),
                    shape.tag(),
                    report.max_abs_err
                );
            }
        }
    }

    #[test]
    fn unknown_params_fall_back_to_naive_schedule() {
        let shape = GemmShape::new(6, 6, 6);
        let (a, b) = inputs(shape, 2);
        let got = run_config(&a, &b, shape, &Config::new());
        assert_eq!(got, reference(&a, &b, shape));
    }

    #[test]
    fn shape_derivations() {
        let s = GemmShape::new(128, 64, 32);
        assert_eq!(s.tag(), "m128n64k32");
        assert_eq!(s.flops(), 2 * 128 * 64 * 32);
        assert_eq!(s.footprint_bytes(), 4 * (128 * 32 + 32 * 64 + 128 * 64));
        assert_eq!(s.dims()["m"], 128);
    }
}
