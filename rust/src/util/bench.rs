//! Micro-benchmark harness for the `cargo bench` targets (the offline
//! dependency set has no criterion; this provides the subset the paper's
//! experiment benches need: named timed sections, warmup + repetition
//! with robust stats, and aligned text output).
//!
//! Benches built on this run as `harness = false` binaries; `cargo bench`
//! executes them sequentially and their stdout is the experiment record
//! (EXPERIMENTS.md is assembled from it).

use std::time::Instant;

use super::stats::Summary;

/// One benchmark runner with shared settings.
pub struct Bench {
    name: String,
    warmup: usize,
    reps: usize,
    results: Vec<BenchResult>,
}

/// One named measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Section name as passed to [`Bench::run`].
    pub id: String,
    /// Timing summary over the section's repetitions.
    pub summary: Summary,
    /// Optional derived metric (e.g. GFLOP/s) with its unit.
    pub metric: Option<(f64, String)>,
}

impl Bench {
    /// A runner with the default (BENCH_QUICK-aware) budgets.
    pub fn new(name: &str) -> Bench {
        // BENCH_QUICK=1 shrinks budgets (used by `make test` smoke runs).
        let quick = std::env::var("BENCH_QUICK").is_ok();
        Bench {
            name: name.to_string(),
            warmup: if quick { 1 } else { 2 },
            reps: if quick { 3 } else { 10 },
            results: Vec::new(),
        }
    }

    /// Override warmup and repetition counts.
    pub fn with_reps(mut self, warmup: usize, reps: usize) -> Bench {
        self.warmup = warmup;
        self.reps = reps.max(1);
        self
    }

    /// The bench's display name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Time a closure `reps` times (after warmup); records and returns
    /// the summary.
    pub fn run<F: FnMut()>(&mut self, id: &str, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let summary = Summary::from_samples(&samples).expect("non-empty samples");
        self.results.push(BenchResult { id: id.to_string(), summary: summary.clone(), metric: None });
        summary
    }

    /// Record an externally produced timing (e.g. a tuner outcome).
    pub fn record(&mut self, id: &str, summary: Summary) {
        self.results.push(BenchResult { id: id.to_string(), summary, metric: None });
    }

    /// Attach a derived metric to the most recent result.
    pub fn metric(&mut self, value: f64, unit: &str) {
        if let Some(last) = self.results.last_mut() {
            last.metric = Some((value, unit.to_string()));
        }
    }

    /// Every recorded section, in run order.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render the standard report block.
    pub fn report(&self) -> String {
        let mut out = format!("== bench: {} ==\n", self.name);
        let wid = self
            .results
            .iter()
            .map(|r| r.id.len())
            .max()
            .unwrap_or(4)
            .max(4);
        for r in &self.results {
            out.push_str(&format!(
                "{:<w$}  median {:>12}  min {:>12}  mad {:>10}",
                r.id,
                format_secs(r.summary.median),
                format_secs(r.summary.min),
                format_secs(r.summary.mad),
                w = wid
            ));
            if let Some((v, unit)) = &r.metric {
                out.push_str(&format!("  {v:.2} {unit}"));
            }
            out.push('\n');
        }
        out
    }
}

/// Seconds with auto-scaled unit, fixed width friendly.
pub fn format_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::new("demo").with_reps(1, 3);
        let s = b.run("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.n, 3);
        b.metric(12.5, "GFLOP/s");
        let rep = b.report();
        assert!(rep.contains("== bench: demo =="));
        assert!(rep.contains("spin"));
        assert!(rep.contains("GFLOP/s"));
    }

    #[test]
    fn format_units() {
        assert!(format_secs(5e-9).ends_with("ns"));
        assert!(format_secs(5e-6).ends_with("µs"));
        assert!(format_secs(5e-3).ends_with("ms"));
        assert!(format_secs(5.0).ends_with("s"));
    }

    #[test]
    fn record_external() {
        let mut b = Bench::new("x");
        b.record("ext", Summary::from_samples(&[1.0, 2.0, 3.0]).unwrap());
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].summary.median, 2.0);
    }
}
