//! Deterministic RNG (xorshift64*) for workload generation and the
//! stochastic search strategies.
//!
//! Determinism is a correctness requirement, not a convenience: the
//! tuner's correctness gate compares variant outputs against reference
//! outputs computed over the *same* generated inputs, and search-ablation
//! benches must replay identical trajectories run-to-run.  The python
//! tests mirror the same distributions via numpy seeds.

/// xorshift64* — tiny, fast, passes BigCrush on its high bits.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller draw.
    spare_gauss: Option<f64>,
}

impl Rng {
    /// Seed must be non-zero; zero is mapped to a fixed odd constant.
    pub fn new(seed: u64) -> Rng {
        let state = if seed == 0 { 0x9e3779b97f4a7c15 } else { seed };
        Rng { state, spare_gauss: None }
    }

    /// Next raw 64-bit output of the generator.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545f4914f6cdd1d)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> uniform double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) — n must be > 0.
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        // Multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (matches numpy's standard_normal
    /// distributionally; exact streams differ, which is fine — both
    /// sides only need "same distribution", the gate compares variant
    /// vs reference over the *rust*-generated inputs).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.spare_gauss.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let scale = (-2.0 * s.ln() / s).sqrt();
                self.spare_gauss = Some(v * scale);
                return u * scale;
            }
        }
    }

    /// Vector of standard-normal f32s.
    pub fn gauss_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gauss() as f32).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.gen_range(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    #[should_panic]
    fn gen_range_zero_panics() {
        Rng::new(1).gen_range(0);
    }

    #[test]
    fn gauss_moments_roughly_standard() {
        let mut r = Rng::new(1234);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
