//! Minimal command-line argument parser (the offline dependency set has
//! no clap; this covers the subcommand + `--flag [value]` surface the
//! binary and benches need).
//!
//! Conventions: the first non-flag token is the subcommand; `--key value`
//! and `--key=value` both bind values; a `--key` followed by another
//! flag (or end of args) is boolean true.  Unknown flags are collected
//! and reported by [`Args::finish`] so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional tokens (subcommand first).
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let tokens: Vec<String> = tokens.into_iter().collect();
        let mut positional = Vec::new();
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    flags
                        .entry(name.to_string())
                        .or_default()
                        .push(tokens[i + 1].clone());
                    i += 1;
                } else {
                    flags.entry(name.to_string()).or_default().push(String::new());
                }
            } else {
                positional.push(t.clone());
            }
            i += 1;
        }
        Args { positional, flags, consumed: Default::default() }
    }

    /// Parse the process's own args.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// The subcommand (first positional), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }

    /// String flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.flags.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    /// String flag with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).map(str::to_string).unwrap_or_else(|| default.to_string())
    }

    /// Boolean flag (present, possibly valueless).
    pub fn get_bool(&self, key: &str) -> bool {
        self.consumed.borrow_mut().insert(key.to_string());
        match self.flags.get(key).and_then(|v| v.last()) {
            Some(v) => v.is_empty() || v == "true" || v == "1",
            None => false,
        }
    }

    /// Typed flag parse with default; invalid values error.
    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("invalid value for --{key}: {v}")),
        }
    }

    /// Error on unconsumed (unknown) flags — call after all gets.
    pub fn finish(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !consumed.contains(*k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(anyhow::anyhow!("unknown flag(s): {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn positional_and_subcommand() {
        let a = args("tune extra");
        assert_eq!(a.subcommand(), Some("tune"));
        assert_eq!(a.positional, vec!["tune", "extra"]);
        assert_eq!(args("").subcommand(), None);
    }

    #[test]
    fn flag_forms() {
        let a = args("cmd --kernel axpy --budget=20 --quick --seed 7");
        assert_eq!(a.get("kernel"), Some("axpy"));
        assert_eq!(a.get("budget"), Some("20"));
        assert!(a.get_bool("quick"));
        assert!(!a.get_bool("missing"));
        assert_eq!(a.get_parsed::<u64>("seed", 0).unwrap(), 7);
    }

    #[test]
    fn parsed_defaults_and_errors() {
        let a = args("cmd --n bogus");
        assert_eq!(a.get_parsed::<usize>("m", 5).unwrap(), 5);
        assert!(a.get_parsed::<usize>("n", 5).is_err());
    }

    #[test]
    fn boolean_before_flag() {
        let a = args("cmd --quick --kernel axpy");
        assert!(a.get_bool("quick"));
        assert_eq!(a.get("kernel"), Some("axpy"));
    }

    #[test]
    fn repeated_flag_takes_last() {
        let a = args("cmd --k 1 --k 2");
        assert_eq!(a.get("k"), Some("2"));
    }

    #[test]
    fn finish_flags_unknown() {
        let a = args("cmd --known 1 --typo 2");
        let _ = a.get("known");
        assert!(a.finish().is_err());
        let b = args("cmd --known 1");
        let _ = b.get("known");
        assert!(b.finish().is_ok());
    }
}
