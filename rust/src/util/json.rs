//! Minimal JSON reader/writer for the artifact manifest and the
//! performance database.
//!
//! The pinned dependency set has no serde, and the two documents we
//! exchange (manifest.json written by `aot.py`, perfdb.json written by
//! us) are small and schema-stable, so a compact recursive-descent
//! parser is the right tool.  Numbers are stored as `f64`; every integer
//! we exchange is well below 2^53 so the round-trip is exact.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always `f64`; our integers stay below 2^53).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (sorted keys — deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The numeric value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to `i64`, if this is a `Num`.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    /// The numeric value as `u64` (`None` when negative or non-numeric).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as u64) } else { None })
    }

    /// The string slice, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The element slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key→value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize with 1-space indentation (matches `json.dump(indent=1)`).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    /// Serialize compactly.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    indent(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    indent(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                    if i + 1 < o.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                indent(out, depth);
                out.push('}');
            }
            other => other.write_compact(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push(' ');
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{}", n);
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    /// Byte offset of the failure in the input.
    pub offset: usize,
    /// Human-readable description of what was expected.
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs: only BMP escapes appear in our
                        // documents, but handle pairs for completeness.
                        let c = if (0xd800..0xdc00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences from the source.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| ParseError { offset: start, message: "bad number".into() })
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

/// Convenience builders used by perfdb and report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// A `Json::Num` from an `f64`.
pub fn num(n: f64) -> Json {
    Json::Num(n)
}

/// A `Json::Num` from an integer (exact below 2^53).
pub fn int(n: i64) -> Json {
    Json::Num(n as f64)
}

/// A `Json::Str` from a string slice.
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// A `Json::Arr` from a vector of values.
pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\Aé");
    }

    #[test]
    fn parses_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn round_trips_pretty_and_compact() {
        let src = r#"{"kernels": [{"name": "axpy", "flops": 8388608}], "version": 1}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.pretty()).unwrap(), v);
        assert_eq!(parse(&v.compact()).unwrap(), v);
    }

    #[test]
    fn integers_round_trip_exactly() {
        let v = parse("4194304").unwrap();
        assert_eq!(v.compact(), "4194304");
        assert_eq!(v.as_i64(), Some(4194304));
    }

    #[test]
    fn escapes_on_write() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&v.compact()).unwrap(), v);
    }
}
