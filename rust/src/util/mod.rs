//! Shared substrates: hand-rolled JSON, measurement statistics, and a
//! deterministic RNG.  These are deliberately dependency-free (the pinned
//! crate set has no serde/rand) — they are part of the "build every
//! substrate" surface of the reproduction.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod sha256;
pub mod stats;
