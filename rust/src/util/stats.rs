//! Measurement statistics for the empirical-evaluation stage.
//!
//! Autotuning decisions key off the **median** of repeated timings —
//! robust against the one-sided noise (scheduler preemption, cache
//! pollution) that wall-clock measurement on a shared host suffers.  MAD
//! (median absolute deviation) is the matching robust spread estimate,
//! used by the measurement harness to decide whether more repetitions
//! are needed and by the reports to print error bars.

/// Summary statistics over a sample of timings (seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Fastest observation.
    pub min: f64,
    /// Slowest observation.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median — the robust central estimate tuning decisions key off.
    pub median: f64,
    /// Median absolute deviation (unscaled).
    pub mad: f64,
    /// Sample standard deviation (n−1 denominator).
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; returns `None` on an empty or non-finite sample.
    pub fn from_samples(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() || samples.iter().any(|x| !x.is_finite()) {
            return None;
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let min = sorted[0];
        let max = sorted[n - 1];
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let median = median_of_sorted(&sorted);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = median_of_sorted(&devs);
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / if n > 1 { (n - 1) as f64 } else { 1.0 };
        let stddev = var.sqrt();
        Some(Summary { n, min, max, mean, median, mad, stddev })
    }

    /// Relative spread: MAD / median (0 when median is 0).
    pub fn rel_spread(&self) -> f64 {
        if self.median == 0.0 {
            0.0
        } else {
            self.mad / self.median
        }
    }
}

fn median_of_sorted(sorted: &[f64]) -> f64 {
    let n = sorted.len();
    if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    }
}

/// Median of an unsorted sample (`None` if empty/non-finite).
pub fn median(samples: &[f64]) -> Option<f64> {
    Summary::from_samples(samples).map(|s| s.median)
}

/// Percentile (0..=100) by nearest-rank on a copy of the sample.
pub fn percentile(samples: &[f64], p: f64) -> Option<f64> {
    if samples.is_empty() || !(0.0..=100.0).contains(&p) {
        return None;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    Some(sorted[rank])
}

/// Drop samples more than `k` MADs above the median (one-sided: timing
/// noise only ever adds time).  Returns the filtered sample; keeps the
/// original when fewer than 4 samples or when MAD is zero.
pub fn reject_outliers(samples: &[f64], k: f64) -> Vec<f64> {
    let summary = match Summary::from_samples(samples) {
        Some(s) if s.n >= 4 && s.mad > 0.0 => s,
        _ => return samples.to_vec(),
    };
    let cut = summary.median + k * summary.mad;
    samples.iter().copied().filter(|&x| x <= cut).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_samples(&[3.0, 1.0, 2.0]).unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.mad, 1.0);
    }

    #[test]
    fn summary_even_length_interpolates() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 10.0]).unwrap();
        assert_eq!(s.median, 2.5);
    }

    #[test]
    fn summary_rejects_empty_and_nan() {
        assert!(Summary::from_samples(&[]).is_none());
        assert!(Summary::from_samples(&[1.0, f64::NAN]).is_none());
        assert!(Summary::from_samples(&[f64::INFINITY]).is_none());
    }

    #[test]
    fn median_is_robust_to_one_spike() {
        let m = median(&[1.0, 1.0, 1.0, 1.0, 100.0]).unwrap();
        assert_eq!(m, 1.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), Some(10.0));
        assert_eq!(percentile(&xs, 50.0), Some(30.0));
        assert_eq!(percentile(&xs, 100.0), Some(50.0));
        assert_eq!(percentile(&[], 50.0), None);
        assert_eq!(percentile(&xs, 150.0), None);
    }

    #[test]
    fn outlier_rejection_drops_spike_only() {
        let xs = [1.0, 1.01, 0.99, 1.02, 0.98, 9.0];
        let kept = reject_outliers(&xs, 5.0);
        assert_eq!(kept.len(), 5);
        assert!(kept.iter().all(|&x| x < 2.0));
    }

    #[test]
    fn outlier_rejection_small_sample_passthrough() {
        let xs = [1.0, 9.0, 2.0];
        assert_eq!(reject_outliers(&xs, 5.0), xs.to_vec());
    }

    #[test]
    fn rel_spread_zero_for_constant() {
        let s = Summary::from_samples(&[2.0, 2.0, 2.0, 2.0]).unwrap();
        assert_eq!(s.rel_spread(), 0.0);
    }
}
