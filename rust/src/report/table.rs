//! Minimal right-aligned ASCII table builder used by all reports.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers.
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Whether any rows were added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with right-aligned, width-fitted columns.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                // Left-align first column, right-align the rest.
                if i == 0 {
                    line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    line.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV rendering (quoted only when needed).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Seconds → human-scaled string (µs/ms/s).
pub fn fmt_time(seconds: f64) -> String {
    if seconds < 1e-3 {
        format!("{:.2} µs", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.3} ms", seconds * 1e3)
    } else {
        format!("{:.3} s", seconds)
    }
}

/// A horizontal bar of `#` scaled to [0, max] over `width` chars.
pub fn bar(value: f64, max: f64, width: usize) -> String {
    if max <= 0.0 || value <= 0.0 {
        return String::new();
    }
    let frac = (value / max).clamp(0.0, 1.0);
    "#".repeat((frac * width as f64).round() as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "12345".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[1].chars().all(|c| c == '-'));
        assert!(lines[3].starts_with("longer"));
        // Right-aligned numeric column.
        assert!(lines[2].ends_with("    1"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        Table::new(&["a", "b"]).row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["k", "v"]);
        t.row(vec!["a,b".into(), "say \"hi\"".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5e-6), "2.50 µs");
        assert_eq!(fmt_time(1.5e-3), "1.500 ms");
        assert_eq!(fmt_time(2.0), "2.000 s");
    }

    #[test]
    fn bar_scaling() {
        assert_eq!(bar(5.0, 10.0, 10), "#####");
        assert_eq!(bar(10.0, 10.0, 10), "##########");
        assert_eq!(bar(0.0, 10.0, 10), "");
        assert_eq!(bar(1.0, 0.0, 10), "");
    }
}
