//! Figure-1 renderer: "auto-vectorized (baseline) vs autotuned kernel's
//! performance" — per input size, absolute execution times (the paper's
//! lines, left axis) and the relative speedup of the autotuned variant
//! (the paper's bars, right axis).

use super::table::{bar, fmt_time, Table};

/// One size point of the figure.
#[derive(Debug, Clone)]
pub struct Fig1Row {
    /// Size label, e.g. "n65536".
    pub size: String,
    /// Baseline (un-annotated default schedule) median seconds.
    pub baseline_s: f64,
    /// Pure-XLA reference artifact median seconds (vendor comparator).
    pub reference_s: f64,
    /// Autotuned best-variant median seconds.
    pub tuned_s: f64,
    /// Winning variant id (or "baseline").
    pub best_id: String,
    /// Evaluations the search spent.
    pub evaluations: usize,
}

impl Fig1Row {
    /// Paper's bar value: relative speedup of autotuned over baseline
    /// in percent time reduction.
    pub fn reduction_pct(&self) -> f64 {
        if self.baseline_s <= 0.0 {
            return 0.0;
        }
        (1.0 - self.tuned_s / self.baseline_s) * 100.0
    }

    /// Baseline time over tuned time (the figure's headline ratio).
    pub fn speedup(&self) -> f64 {
        if self.tuned_s > 0.0 {
            self.baseline_s / self.tuned_s
        } else {
            0.0
        }
    }

    /// Autotuned time / XLA-reference time (the vendor-comparator ratio).
    pub fn vs_reference(&self) -> f64 {
        if self.reference_s > 0.0 {
            self.tuned_s / self.reference_s
        } else {
            0.0
        }
    }
}

/// The full figure for one kernel.
#[derive(Debug, Clone)]
pub struct Fig1Report {
    /// Kernel family the figure covers.
    pub kernel: String,
    /// One row per input size.
    pub rows: Vec<Fig1Row>,
}

impl Fig1Report {
    /// An empty report for one kernel.
    pub fn new(kernel: impl Into<String>) -> Fig1Report {
        Fig1Report { kernel: kernel.into(), rows: Vec::new() }
    }

    /// Append one size point.
    pub fn push(&mut self, row: Fig1Row) {
        self.rows.push(row);
    }

    /// Headline: maximum speedup across sizes (the paper reports
    /// "up to 43% or 2.3x").
    pub fn max_speedup(&self) -> f64 {
        self.rows.iter().map(Fig1Row::speedup).fold(1.0, f64::max)
    }

    /// Maximum time-reduction percentage across sizes.
    pub fn max_reduction_pct(&self) -> f64 {
        self.rows.iter().map(Fig1Row::reduction_pct).fold(0.0, f64::max)
    }

    /// ASCII rendering: the table plus speedup bars (right axis).
    pub fn render(&self) -> String {
        let mut t = Table::new(&[
            "size", "baseline", "autotuned", "xla-ref", "best variant", "evals",
            "speedup", "reduction", "vs-ref",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.size.clone(),
                fmt_time(r.baseline_s),
                fmt_time(r.tuned_s),
                fmt_time(r.reference_s),
                r.best_id.clone(),
                r.evaluations.to_string(),
                format!("{:.2}x", r.speedup()),
                format!("{:+.1}%", r.reduction_pct()),
                format!("{:.2}", r.vs_reference()),
            ]);
        }
        let mut out = format!(
            "Figure 1 [{}]: auto-vectorized (baseline) vs autotuned\n\n",
            self.kernel
        );
        out.push_str(&t.render());
        out.push('\n');
        // Bars: relative speedup per size (the figure's right axis).
        let max_pct = self.max_reduction_pct().max(1.0);
        for r in &self.rows {
            out.push_str(&format!(
                "  {:>10}  |{:<40}| {:+.1}%\n",
                r.size,
                bar(r.reduction_pct().max(0.0), max_pct, 40),
                r.reduction_pct()
            ));
        }
        out.push_str(&format!(
            "\nautotuning delivers up to {:.0}% time reduction ({:.2}x speedup)\n",
            self.max_reduction_pct(),
            self.max_speedup()
        ));
        out
    }

    /// CSV with the exact series the figure plots.
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(&[
            "kernel", "size", "baseline_s", "tuned_s", "reference_s", "best_id",
            "evaluations", "speedup", "reduction_pct", "vs_reference",
        ]);
        for r in &self.rows {
            t.row(vec![
                self.kernel.clone(),
                r.size.clone(),
                format!("{:.9}", r.baseline_s),
                format!("{:.9}", r.tuned_s),
                format!("{:.9}", r.reference_s),
                r.best_id.clone(),
                r.evaluations.to_string(),
                format!("{:.4}", r.speedup()),
                format!("{:.2}", r.reduction_pct()),
                format!("{:.4}", r.vs_reference()),
            ]);
        }
        t.to_csv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> Fig1Report {
        let mut r = Fig1Report::new("axpy");
        r.push(Fig1Row {
            size: "n4096".into(),
            baseline_s: 10e-6,
            tuned_s: 8e-6,
            reference_s: 9e-6,
            best_id: "b1024_u2".into(),
            evaluations: 9,
        });
        r.push(Fig1Row {
            size: "n65536".into(),
            baseline_s: 100e-6,
            tuned_s: 43.5e-6,
            reference_s: 50e-6,
            best_id: "b4096_u4".into(),
            evaluations: 12,
        });
        r
    }

    #[test]
    fn reduction_and_speedup_math() {
        let r = report();
        assert!((r.rows[0].reduction_pct() - 20.0).abs() < 1e-9);
        assert!((r.rows[1].speedup() - 2.2988).abs() < 1e-3);
        assert!((r.max_speedup() - 100.0 / 43.5).abs() < 1e-9);
    }

    #[test]
    fn render_contains_series_and_headline() {
        let s = report().render();
        assert!(s.contains("n4096"));
        assert!(s.contains("b4096_u4"));
        assert!(s.contains("speedup"));
        assert!(s.contains("up to"));
        assert!(s.contains('#')); // bars present
    }

    #[test]
    fn csv_has_one_line_per_row_plus_header() {
        let csv = report().to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("kernel,size,"));
    }

    #[test]
    fn degenerate_rows_do_not_panic() {
        let row = Fig1Row {
            size: "z".into(),
            baseline_s: 0.0,
            tuned_s: 0.0,
            reference_s: 0.0,
            best_id: "baseline".into(),
            evaluations: 0,
        };
        assert_eq!(row.reduction_pct(), 0.0);
        assert_eq!(row.speedup(), 0.0);
    }
}
