//! Report renderers: regenerate the paper's figure/table formats from
//! tuning outcomes (ASCII for the terminal, CSV for plotting).

pub mod fig1;
pub mod stats;
pub mod table;

pub use fig1::{Fig1Report, Fig1Row};
pub use stats::{outcome_json, serve_stats_json, stats_json};
pub use table::Table;
