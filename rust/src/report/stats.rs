//! TuneStats rendering: the cost-accounting side of the reports.
//!
//! The overhead bench and the CLI both need to show *where tuning time
//! went* (compile vs measure, repetitions spent vs saved); this module
//! owns the serialization so the JSON schema lives in exactly one
//! place and the bench trajectory stays machine-readable run-to-run.

use std::collections::BTreeMap;

use crate::coordinator::tuner::{TuneOutcome, TuneStats};
use crate::service::server::ServeStats;
use crate::util::json::Json;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn int(x: u64) -> Json {
    Json::Num(x as f64)
}

/// JSON view of a [`TuneStats`].
pub fn stats_json(stats: &TuneStats) -> Json {
    let fields: BTreeMap<String, Json> = [
        ("compile_ms".to_string(), num(stats.compile_ms)),
        ("measure_ms".to_string(), num(stats.measure_ms)),
        ("reps_timed".to_string(), int(stats.reps_timed)),
        ("reps_saved".to_string(), int(stats.reps_saved)),
        ("compiles".to_string(), int(stats.compiles)),
        ("cache_hits".to_string(), int(stats.cache_hits)),
        ("batches".to_string(), int(stats.batches)),
        ("pruned".to_string(), int(stats.pruned)),
        ("gated".to_string(), int(stats.gated)),
    ]
    .into_iter()
    .collect();
    Json::Obj(fields)
}

/// JSON view of a whole tuning outcome (the bench-trajectory record).
pub fn outcome_json(outcome: &TuneOutcome) -> Json {
    let fields: BTreeMap<String, Json> = [
        ("kernel".to_string(), Json::Str(outcome.kernel.clone())),
        ("tag".to_string(), Json::Str(outcome.tag.clone())),
        ("strategy".to_string(), Json::Str(outcome.strategy.clone())),
        ("baseline_ms".to_string(), num(outcome.baseline_time() * 1e3)),
        ("tuned_ms".to_string(), num(outcome.best_time() * 1e3)),
        ("reference_ms".to_string(), num(outcome.reference.cost() * 1e3)),
        ("speedup".to_string(), num(outcome.speedup())),
        ("evaluations".to_string(), int(outcome.evaluations() as u64)),
        (
            "best".to_string(),
            outcome
                .best
                .as_ref()
                .map(|b| Json::Str(b.config_id.clone()))
                .unwrap_or(Json::Null),
        ),
        ("stats".to_string(), stats_json(&outcome.stats)),
    ]
    .into_iter()
    .collect();
    Json::Obj(fields)
}

/// JSON view of the daemon's counters — the serve-side analogue of
/// [`stats_json`], consumed by the `stats` op, the smoke test, and the
/// serve-throughput bench.
pub fn serve_stats_json(stats: &ServeStats) -> Json {
    let fields: BTreeMap<String, Json> = [
        ("lookups".to_string(), int(stats.lookups)),
        ("deploys".to_string(), int(stats.deploys)),
        ("lru_hits".to_string(), int(stats.lru_hits)),
        ("shard_reads".to_string(), int(stats.shard_reads)),
        ("records".to_string(), int(stats.records)),
        ("transfer_misses".to_string(), int(stats.transfer_misses)),
        ("portfolios".to_string(), int(stats.portfolios)),
        ("portfolio_transfers".to_string(), int(stats.portfolio_transfers)),
        ("tasks_queued".to_string(), int(stats.tasks_queued)),
        ("tasks_leased".to_string(), int(stats.tasks_leased)),
        ("tasks_completed".to_string(), int(stats.tasks_completed)),
        ("tasks_failed".to_string(), int(stats.tasks_failed)),
        ("leases_expired".to_string(), int(stats.leases_expired)),
        ("retunes".to_string(), int(stats.retunes)),
        ("errors".to_string(), int(stats.errors)),
        ("dedup_hits".to_string(), int(stats.dedup_hits)),
        ("conns_shed".to_string(), int(stats.conns_shed)),
        ("conns_closed_idle".to_string(), int(stats.conns_closed_idle)),
        ("tasks_pending".to_string(), int(stats.tasks_pending)),
        ("tasks_inflight".to_string(), int(stats.tasks_inflight)),
        (
            "queue_depth".to_string(),
            Json::Obj(
                stats
                    .queue_depth
                    .iter()
                    .map(|(kind, depth)| (kind.clone(), int(*depth)))
                    .collect(),
            ),
        ),
        ("lru_len".to_string(), int(stats.lru_len)),
        ("snapshot_gen".to_string(), int(stats.snapshot_gen)),
        ("snapshot_publishes".to_string(), int(stats.snapshot_publishes)),
        ("stale_locks_reaped".to_string(), int(stats.stale_locks_reaped)),
        ("shards_quarantined".to_string(), int(stats.shards_quarantined)),
        ("regressions".to_string(), int(stats.regressions)),
        ("regressions_active".to_string(), int(stats.regressions_active)),
        // Ledger totals surface in core-seconds (the unit operators
        // budget in); the store accumulates exact core-milliseconds.
        ("tuning_spend_core_seconds".to_string(), num(stats.tuning_spend_ms as f64 / 1000.0)),
        (
            "tuning_benefit_core_seconds".to_string(),
            num(stats.tuning_benefit_ms as f64 / 1000.0),
        ),
    ]
    .into_iter()
    .collect();
    Json::Obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn stats_json_round_trips() {
        let stats = TuneStats {
            compile_ms: 123.5,
            measure_ms: 45.25,
            reps_timed: 87,
            reps_saved: 41,
            compiles: 9,
            cache_hits: 3,
            batches: 4,
            pruned: 6,
            gated: 1,
        };
        let j = stats_json(&stats);
        let parsed = json::parse(&j.compact()).unwrap();
        assert_eq!(parsed.get("reps_timed").and_then(Json::as_u64), Some(87));
        assert_eq!(parsed.get("reps_saved").and_then(Json::as_u64), Some(41));
        assert_eq!(parsed.get("compile_ms").and_then(Json::as_f64), Some(123.5));
        assert_eq!(parsed.get("batches").and_then(Json::as_u64), Some(4));
    }

    #[test]
    fn stats_render_mentions_the_headline_numbers() {
        let stats = TuneStats { reps_timed: 87, reps_saved: 41, ..TuneStats::default() };
        let line = stats.render();
        assert!(line.contains("87 timed"));
        assert!(line.contains("41 saved"));
    }

    #[test]
    fn serve_stats_json_round_trips() {
        let stats = ServeStats {
            lookups: 100,
            deploys: 7,
            lru_hits: 90,
            shard_reads: 10,
            records: 3,
            transfer_misses: 2,
            portfolios: 5,
            portfolio_transfers: 2,
            tasks_queued: 4,
            tasks_leased: 3,
            tasks_completed: 2,
            tasks_failed: 1,
            leases_expired: 1,
            retunes: 1,
            errors: 0,
            dedup_hits: 2,
            conns_shed: 1,
            conns_closed_idle: 1,
            tasks_pending: 3,
            tasks_inflight: 1,
            queue_depth: [
                ("retune".to_string(), 2u64),
                ("sweep".to_string(), 0),
                ("portfolio-rebuild".to_string(), 1),
            ]
            .into_iter()
            .collect(),
            lru_len: 12,
            snapshot_gen: 6,
            snapshot_publishes: 8,
            stale_locks_reaped: 2,
            shards_quarantined: 1,
            regressions: 2,
            regressions_active: 1,
            tuning_spend_ms: 90_500,
            tuning_benefit_ms: 120_250,
        };
        let parsed = json::parse(&serve_stats_json(&stats).compact()).unwrap();
        assert_eq!(parsed.get("lookups").and_then(Json::as_u64), Some(100));
        assert_eq!(parsed.get("lru_hits").and_then(Json::as_u64), Some(90));
        assert_eq!(parsed.get("tasks_queued").and_then(Json::as_u64), Some(4));
        assert_eq!(parsed.get("tasks_leased").and_then(Json::as_u64), Some(3));
        assert_eq!(parsed.get("tasks_completed").and_then(Json::as_u64), Some(2));
        assert_eq!(parsed.get("tasks_failed").and_then(Json::as_u64), Some(1));
        assert_eq!(parsed.get("leases_expired").and_then(Json::as_u64), Some(1));
        assert_eq!(parsed.get("tasks_pending").and_then(Json::as_u64), Some(3));
        assert_eq!(parsed.get("tasks_inflight").and_then(Json::as_u64), Some(1));
        assert_eq!(
            parsed
                .get("queue_depth")
                .and_then(|d| d.get("portfolio-rebuild"))
                .and_then(Json::as_u64),
            Some(1)
        );
        assert_eq!(parsed.get("portfolios").and_then(Json::as_u64), Some(5));
        assert_eq!(parsed.get("portfolio_transfers").and_then(Json::as_u64), Some(2));
        assert_eq!(parsed.get("dedup_hits").and_then(Json::as_u64), Some(2));
        assert_eq!(parsed.get("conns_shed").and_then(Json::as_u64), Some(1));
        assert_eq!(parsed.get("conns_closed_idle").and_then(Json::as_u64), Some(1));
        assert_eq!(parsed.get("snapshot_gen").and_then(Json::as_u64), Some(6));
        assert_eq!(parsed.get("snapshot_publishes").and_then(Json::as_u64), Some(8));
        assert_eq!(parsed.get("stale_locks_reaped").and_then(Json::as_u64), Some(2));
        assert_eq!(parsed.get("shards_quarantined").and_then(Json::as_u64), Some(1));
        assert_eq!(parsed.get("regressions").and_then(Json::as_u64), Some(2));
        assert_eq!(parsed.get("regressions_active").and_then(Json::as_u64), Some(1));
        assert_eq!(
            parsed.get("tuning_spend_core_seconds").and_then(Json::as_f64),
            Some(90.5)
        );
        assert_eq!(
            parsed.get("tuning_benefit_core_seconds").and_then(Json::as_f64),
            Some(120.25)
        );
    }
}
