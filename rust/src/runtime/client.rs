//! PJRT client wrapper: one process-wide CPU client, compile HLO text
//! artifacts into [`Executable`]s.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

#[cfg(not(feature = "xla-runtime"))]
use crate::xla;

use super::executable::Executable;

/// Owns the PJRT client.  Cheap to clone via `Arc` inside [`crate::runtime::Registry`];
/// typically constructed once per process (client startup is ~100ms).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Arc<Runtime>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Runtime { client }))
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// PJRT platform version string.
    pub fn platform_version(&self) -> String {
        self.client.platform_version()
    }

    /// Number of devices the client sees.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Compile an HLO-text artifact file into an executable.
    ///
    /// HLO *text* is the interchange format: jax >= 0.5 serializes protos
    /// with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
    /// text parser reassigns ids and round-trips cleanly.
    pub fn compile_file(&self, path: &Path) -> Result<Executable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path: {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path_str}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA-compiling {path_str}"))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "artifact".to_string());
        Ok(Executable::new(exe, name))
    }

    /// Upload an f32 host tensor to a device buffer (device-resident
    /// pipelines upload once and iterate on-device).
    pub fn buffer_from_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("uploading f32 buffer")
    }

    /// Compile HLO text held in memory (used by tests and the annotation
    /// round-trip tooling).
    pub fn compile_text(&self, hlo_text: &str, name: &str) -> Result<Executable> {
        // The xla crate only exposes file-based text parsing; stage via a
        // temp file.  Compilation dominates, the file write is noise.
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "portatune-hlo-{}-{}.txt",
            std::process::id(),
            name.replace(|c: char| !c.is_alphanumeric(), "_")
        ));
        std::fs::write(&path, hlo_text).context("staging HLO text")?;
        let result = self.compile_file(&path);
        let _ = std::fs::remove_file(&path);
        result
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.platform_name())
            .field("devices", &self.device_count())
            .finish()
    }
}
