//! Typed host tensors and their conversion to `xla::Literal`.
//!
//! The manifest declares every artifact's input signature as
//! (name, dtype, shape); the workload generators produce matching
//! [`TensorData`]; this module is the only place the dtype/shape ⇄
//! Literal mapping lives.

use anyhow::{Context, Result};

#[cfg(not(feature = "xla-runtime"))]
use crate::xla;

/// Element types exchanged with artifacts (matches `aot.py::_dtype_str`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit IEEE-754 float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    /// Parse the manifest's dtype string (`"f32"` / `"i32"`).
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => Err(anyhow::anyhow!("unknown dtype in manifest: {other}")),
        }
    }

    /// The manifest spelling of this dtype.
    pub fn as_str(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }

    /// Bytes per element.
    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// An input slot declared by the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Slot name from the manifest.
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Dense row-major shape.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Product of the shape dims.
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A concrete host tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    /// Dense row-major f32 tensor.
    F32 {
        /// Tensor shape.
        shape: Vec<usize>,
        /// Row-major elements (`shape` product long).
        data: Vec<f32>,
    },
    /// Dense row-major i32 tensor.
    I32 {
        /// Tensor shape.
        shape: Vec<usize>,
        /// Row-major elements (`shape` product long).
        data: Vec<i32>,
    },
}

impl TensorData {
    /// An f32 tensor (panics on shape/data length mismatch).
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> TensorData {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        TensorData::F32 { shape, data }
    }

    /// An i32 tensor (panics on shape/data length mismatch).
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> TensorData {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        TensorData::I32 { shape, data }
    }

    /// Scalar-as-rank-1 convenience (the kernels take f32[1] scalars).
    pub fn scalar_f32(v: f32) -> TensorData {
        TensorData::f32(vec![1], vec![v])
    }

    /// The tensor's shape.
    pub fn shape(&self) -> &[usize] {
        match self {
            TensorData::F32 { shape, .. } | TensorData::I32 { shape, .. } => shape,
        }
    }

    /// The tensor's element type.
    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32 { .. } => DType::F32,
            TensorData::I32 { .. } => DType::I32,
        }
    }

    /// Product of the shape dims.
    pub fn element_count(&self) -> usize {
        self.shape().iter().product()
    }

    /// The f32 elements, if this is an `F32` tensor.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    /// The i32 elements, if this is an `I32` tensor.
    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            TensorData::I32 { data, .. } => Some(data),
            _ => None,
        }
    }

    /// Does this tensor match a manifest slot?
    pub fn matches(&self, spec: &TensorSpec) -> bool {
        self.dtype() == spec.dtype && self.shape() == spec.shape.as_slice()
    }

    /// Convert to an `xla::Literal` (rank-1 upload + reshape; the literal
    /// layout is dense row-major either way).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            TensorData::F32 { data, .. } => xla::Literal::vec1(data),
            TensorData::I32 { data, .. } => xla::Literal::vec1(data),
        };
        if dims.len() == 1 {
            Ok(lit)
        } else {
            lit.reshape(&dims).context("reshaping literal")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parses() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("i32").unwrap(), DType::I32);
        assert!(DType::parse("f64").is_err());
        assert_eq!(DType::F32.as_str(), "f32");
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        TensorData::f32(vec![3], vec![1.0, 2.0]);
    }

    #[test]
    fn spec_matching() {
        let spec = TensorSpec { name: "x".into(), dtype: DType::F32, shape: vec![2, 3] };
        let t = TensorData::f32(vec![2, 3], vec![0.0; 6]);
        assert!(t.matches(&spec));
        let wrong_shape = TensorData::f32(vec![3, 2], vec![0.0; 6]);
        assert!(!wrong_shape.matches(&spec));
        let wrong_dtype = TensorData::i32(vec![2, 3], vec![0; 6]);
        assert!(!wrong_dtype.matches(&spec));
    }

    #[test]
    fn element_counts() {
        let spec = TensorSpec { name: "v".into(), dtype: DType::I32, shape: vec![4, 8] };
        assert_eq!(spec.element_count(), 32);
        assert_eq!(TensorData::scalar_f32(1.0).element_count(), 1);
    }
}
