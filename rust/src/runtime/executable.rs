//! A compiled variant: typed execution over [`TensorData`] inputs with
//! output materialization (the unit the measurement harness times).

use anyhow::{Context, Result};

#[cfg(not(feature = "xla-runtime"))]
use crate::xla;

use super::literal::TensorData;

/// A PJRT-compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub(crate) fn new(exe: xla::PjRtLoadedExecutable, name: String) -> Executable {
        Executable { exe, name }
    }

    /// Artifact-derived display name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with pre-built literals and materialize the single output.
    ///
    /// Includes host transfer (`to_literal_sync`) so the timed unit is
    /// "results available to the coordinator", matching how the paper
    /// times kernels (wall clock around the kernel call).
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let buffers = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let out = buffers[0][0]
            .to_literal_sync()
            .context("materializing output")?;
        // Artifacts are lowered with return_tuple=True: unwrap the 1-tuple.
        out.to_tuple1().context("unwrapping output tuple")
    }

    /// Execute with typed tensors; returns the flat f32 output.
    pub fn run(&self, inputs: &[TensorData]) -> Result<Vec<f32>> {
        let literals = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let out = self.run_literals(&literals)?;
        out.to_vec::<f32>().context("reading f32 output")
    }

    /// Execute and return the raw output literal (for chained pipelines
    /// like the Jacobi solver that feed outputs back as inputs).
    pub fn run_to_literal(&self, inputs: &[TensorData]) -> Result<xla::Literal> {
        let literals = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        self.run_literals(&literals)
    }

    /// Device-resident execution: run over device buffers and return the
    /// raw output buffer WITHOUT host materialization.
    ///
    /// Only valid for *untupled* artifacts (`.nt.hlo.txt`, lowered with
    /// `return_tuple=False`) — their single output is a plain array
    /// buffer that can be fed straight back as the next call's input.
    /// This is the solver hot loop's fast path: no host<->device copy per
    /// iteration (see EXPERIMENTS.md §Perf).
    pub fn run_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        let out = self
            .exe
            .execute_b(inputs)
            .with_context(|| format!("executing {} over buffers", self.name))?;
        out.into_iter()
            .next()
            .and_then(|per_device| per_device.into_iter().next())
            .ok_or_else(|| anyhow::anyhow!("empty output from {}", self.name))
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").field("name", &self.name).finish()
    }
}
