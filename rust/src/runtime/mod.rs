//! Runtime: load AOT artifacts (HLO text emitted by `python/compile/aot.py`)
//! and execute them on the PJRT CPU client via the `xla` crate.
//!
//! Python never runs here — the coordinator's entire hot path (variant
//! compilation, measurement, deployment) goes through this module.
//!
//! Flow: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute` (see /opt/xla-example/load_hlo for the
//! reference wiring).  Artifacts are lowered with `return_tuple=True`, so
//! every execution unwraps a 1-tuple.

pub mod client;
pub mod executable;
pub mod literal;
pub mod registry;

pub use client::Runtime;
pub use executable::Executable;
pub use literal::{DType, TensorData, TensorSpec};
pub use registry::{
    KernelEntry, Manifest, ParamDef, PrefetchHandle, Registry, Variant, Workload,
};
