//! Offline stand-in for the `xla` crate (mounted as `crate::xla` when the
//! `xla-runtime` feature is off).
//!
//! It mirrors the exact API surface `runtime/` consumes so the whole
//! coordinator layer — search strategies, racing, constraint evaluation,
//! perf DB, reports — builds and unit-tests on machines without the
//! xla_extension native library.  Every entry point that would need a
//! real PJRT client returns [`Error::Unavailable`]; nothing panics, so
//! integration tests can probe `Runtime::cpu()` and skip gracefully.

/// Error type matching the real crate's role in `Result` signatures.
#[derive(Debug, Clone)]
pub enum Error {
    /// The named entry point needs the real XLA runtime.
    Unavailable(&'static str),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: XLA runtime not available (built without the `xla-runtime` feature)"
            ),
        }
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// Stub PJRT client: construction always fails, which is the one honest
/// answer an offline build can give.
pub struct PjRtClient;

impl PjRtClient {
    /// Always fails: no PJRT without the native library.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    /// Static stub platform name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Static stub platform version.
    pub fn platform_version(&self) -> String {
        "0".to_string()
    }

    /// Always 0 — the stub has no devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Always fails (see [`Error::Unavailable`]).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    /// Always fails (see [`Error::Unavailable`]).
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("buffer_from_host_buffer"))
    }
}

/// Stub HLO module handle.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Always fails (see [`Error::Unavailable`]).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub computation handle.
pub struct XlaComputation;

impl XlaComputation {
    /// Total constructor (the failure happens at compile time instead).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Always fails (see [`Error::Unavailable`]).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("execute"))
    }

    /// Always fails (see [`Error::Unavailable`]).
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("execute_b"))
    }
}

/// Stub device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Always fails (see [`Error::Unavailable`]).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("to_literal_sync"))
    }
}

/// Dataless literal: convertible-to but never executable.
pub struct Literal;

impl Literal {
    /// Total constructor — data is discarded, execution is impossible anyway.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    /// Total no-op reshape.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Always fails (see [`Error::Unavailable`]).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }

    /// Always fails (see [`Error::Unavailable`]).
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::Unavailable("Literal::to_tuple1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must not hand out a client");
        let msg = err.to_string();
        assert!(msg.contains("xla-runtime"), "error must name the feature: {msg}");
    }

    #[test]
    fn literal_builders_are_total() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_ok());
        assert!(Literal::vec1(&[0i32]).to_vec::<i32>().is_err());
    }
}
