//! Artifact registry: the typed view of `artifacts/manifest.json` plus a
//! lazy compile cache.
//!
//! The manifest is the contract between the build-time python layer and
//! the runtime: kernel families, their parameter schemas and constraint
//! strings, and per-workload artifact paths.  The registry compiles
//! artifacts on first use and memoizes the executables — the tuner's
//! search strategies may revisit configurations, and benches re-measure
//! winners, so compile-once matters (XLA compilation is 10–300 ms per
//! artifact).

use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

use super::client::Runtime;
use super::executable::Executable;
use super::literal::{DType, TensorSpec};

/// One tuning parameter's schema (name, id abbreviation, domain).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamDef {
    /// Parameter name (matches constraint identifiers).
    pub name: String,
    /// Short prefix used in variant ids (`b` in `b1024_u4`).
    pub abbrev: String,
    /// Finite ordered value domain.
    pub values: Vec<i64>,
}

/// One pre-lowered variant of a workload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Variant {
    /// Stable variant id derived from the parameter values.
    pub id: String,
    /// The parameter assignment this artifact was lowered with.
    pub params: BTreeMap<String, i64>,
    /// Artifact path relative to the manifest root.
    pub path: String,
}

/// One concrete workload (fixed shapes) of a kernel family.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    /// Shape tag (`n65536`, `m128n128k64`, ...).
    pub tag: String,
    /// Named problem dimensions.
    pub dims: BTreeMap<String, i64>,
    /// Declared input signature, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Declared output signature.
    pub output: TensorSpec,
    /// Flop count of one execution (roofline reporting).
    pub flops: u64,
    /// Bytes moved by one execution (roofline reporting).
    pub bytes: u64,
    /// Pure-XLA reference artifact (semantics oracle + vendor-library
    /// comparator).
    pub baseline: String,
    /// Variant id of the un-annotated default schedule (Figure 1's
    /// "no pragmas" series); `None` for pre-default manifests.
    pub default: Option<String>,
    /// Whether untupled twins (`*.nt.hlo.txt`) exist for device-resident
    /// iteration (output buffer feeds back as the next input).
    pub untupled: bool,
    /// Every pre-lowered schedule variant of this workload.
    pub variants: Vec<Variant>,
}

/// Path of the untupled twin of an artifact (`x.hlo.txt` → `x.nt.hlo.txt`).
pub fn untupled_path(path: &str) -> String {
    match path.strip_suffix(".hlo.txt") {
        Some(stem) => format!("{stem}.nt.hlo.txt"),
        None => format!("{path}.nt"),
    }
}

impl Workload {
    /// Find a variant by id.
    pub fn variant(&self, id: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| v.id == id)
    }
}

/// One kernel family as declared by the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelEntry {
    /// Kernel family name.
    pub name: String,
    /// Tuning parameter schemas.
    pub params: Vec<ParamDef>,
    /// Constraint strings over params and workload dims.
    pub constraints: Vec<String>,
    /// The family's concrete workloads.
    pub workloads: Vec<Workload>,
}

impl KernelEntry {
    /// Find a workload by tag.
    pub fn workload(&self, tag: &str) -> Option<&Workload> {
        self.workloads.iter().find(|w| w.tag == tag)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Schema version (currently 1).
    pub version: i64,
    /// Every kernel family the artifact set covers.
    pub kernels: Vec<KernelEntry>,
}

impl Manifest {
    /// Find a kernel family by name.
    pub fn kernel(&self, name: &str) -> Option<&KernelEntry> {
        self.kernels.iter().find(|k| k.name == name)
    }

    /// Parse from JSON text (schema written by `aot.py`).
    pub fn parse(text: &str) -> Result<Manifest> {
        let root = json::parse(text).context("parsing manifest.json")?;
        let version = root
            .get("version")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("manifest missing version"))?;
        if version != 1 {
            return Err(anyhow::anyhow!("unsupported manifest version {version}"));
        }
        let kernels = root
            .get("kernels")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing kernels array"))?
            .iter()
            .map(parse_kernel)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { version, kernels })
    }
}

fn req<'a>(v: &'a Json, key: &str) -> Result<&'a Json> {
    v.get(key).ok_or_else(|| anyhow::anyhow!("manifest field missing: {key}"))
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    req(v, key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("manifest field not a string: {key}"))
}

fn parse_kernel(v: &Json) -> Result<KernelEntry> {
    let name = req_str(v, "name")?;
    let params = req(v, "params")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("params not an array"))?
        .iter()
        .map(|p| {
            Ok(ParamDef {
                name: req_str(p, "name")?,
                abbrev: req_str(p, "abbrev")?,
                values: req(p, "values")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("param values not an array"))?
                    .iter()
                    .map(|x| x.as_i64().ok_or_else(|| anyhow::anyhow!("non-int param value")))
                    .collect::<Result<Vec<_>>>()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let constraints = req(v, "constraints")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("constraints not an array"))?
        .iter()
        .map(|c| {
            c.as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("constraint not a string"))
        })
        .collect::<Result<Vec<_>>>()?;
    let workloads = req(v, "workloads")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("workloads not an array"))?
        .iter()
        .map(parse_workload)
        .collect::<Result<Vec<_>>>()?;
    Ok(KernelEntry { name, params, constraints, workloads })
}

fn parse_tensor_spec(v: &Json, default_name: &str) -> Result<TensorSpec> {
    let name = v
        .get("name")
        .and_then(Json::as_str)
        .unwrap_or(default_name)
        .to_string();
    let dtype = DType::parse(&req_str(v, "dtype")?)?;
    let shape = req(v, "shape")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
        .iter()
        .map(|d| {
            d.as_u64()
                .map(|x| x as usize)
                .ok_or_else(|| anyhow::anyhow!("non-int shape dim"))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(TensorSpec { name, dtype, shape })
}

fn parse_dims(v: &Json) -> Result<BTreeMap<String, i64>> {
    v.as_obj()
        .ok_or_else(|| anyhow::anyhow!("dims not an object"))?
        .iter()
        .map(|(k, d)| {
            d.as_i64()
                .map(|x| (k.clone(), x))
                .ok_or_else(|| anyhow::anyhow!("non-int dim {k}"))
        })
        .collect()
}

fn parse_workload(v: &Json) -> Result<Workload> {
    let variants = req(v, "variants")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("variants not an array"))?
        .iter()
        .map(|t| {
            Ok(Variant {
                id: req_str(t, "id")?,
                params: parse_dims(req(t, "params")?)?,
                path: req_str(t, "path")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(Workload {
        tag: req_str(v, "tag")?,
        dims: parse_dims(req(v, "dims")?)?,
        inputs: req(v, "inputs")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("inputs not an array"))?
            .iter()
            .enumerate()
            .map(|(i, t)| parse_tensor_spec(t, &format!("arg{i}")))
            .collect::<Result<Vec<_>>>()?,
        output: parse_tensor_spec(req(v, "output")?, "out")?,
        flops: req(v, "flops")?
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("flops not a non-negative int"))?,
        bytes: req(v, "bytes")?
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("bytes not a non-negative int"))?,
        baseline: req_str(v, "baseline")?,
        default: v.get("default").and_then(Json::as_str).map(str::to_string),
        untupled: v.get("untupled").and_then(Json::as_bool).unwrap_or(false),
        variants,
    })
}

/// Shared compile cache: ready executables plus in-flight compile
/// tracking, `Arc`-owned so background prefetch workers outlive any one
/// borrow of the [`Registry`].
///
/// PJRT compilation is thread-safe and CPU-bound (10–300 ms per
/// artifact), which is exactly what the tuner's batched pipeline
/// overlaps with single-threaded measurement.  In-flight tracking means
/// a `load` racing a prefetch worker for the same path waits for that
/// compile instead of duplicating it.
struct CompileCache {
    runtime: Arc<Runtime>,
    root: PathBuf,
    ready: Mutex<HashMap<String, Arc<Executable>>>,
    /// Paths being compiled right now (any thread); guarded with `done`.
    inflight: Mutex<HashSet<String>>,
    done: Condvar,
    compiles: Mutex<u64>,
    compile_secs: Mutex<f64>,
    hits: Mutex<u64>,
}

impl CompileCache {
    fn load(&self, rel_path: &str) -> Result<Arc<Executable>> {
        loop {
            if let Some(exe) = self.ready.lock().unwrap().get(rel_path) {
                *self.hits.lock().unwrap() += 1;
                return Ok(exe.clone());
            }
            let mut inflight = self.inflight.lock().unwrap();
            if !inflight.contains(rel_path) {
                inflight.insert(rel_path.to_string());
                break;
            }
            // Another thread is compiling this artifact: wait, then
            // re-check `ready` (on a compile error we take over).
            let guard = self.done.wait(inflight).unwrap();
            drop(guard);
        }
        // Double-check: the previous holder may have completed between
        // our `ready` miss and the `inflight` acquisition.
        if let Some(exe) = self.ready.lock().unwrap().get(rel_path) {
            let exe = exe.clone();
            self.inflight.lock().unwrap().remove(rel_path);
            self.done.notify_all();
            *self.hits.lock().unwrap() += 1;
            return Ok(exe);
        }
        let result: Result<Arc<Executable>> = (|| {
            let full = self.root.join(rel_path);
            let t0 = Instant::now();
            let exe = Arc::new(self.runtime.compile_file(&full)?);
            let dt = t0.elapsed().as_secs_f64();
            *self.compiles.lock().unwrap() += 1;
            *self.compile_secs.lock().unwrap() += dt;
            self.ready.lock().unwrap().insert(rel_path.to_string(), exe.clone());
            Ok(exe)
        })();
        self.inflight.lock().unwrap().remove(rel_path);
        self.done.notify_all();
        result
    }
}

/// Handle over in-flight prefetch workers.  Dropping it detaches them —
/// they finish compiling into the shared cache on their own; `wait`
/// joins them for deterministic accounting (benches).
pub struct PrefetchHandle {
    workers: Vec<thread::JoinHandle<()>>,
}

impl PrefetchHandle {
    /// Block until every prefetch worker has drained the queue.
    pub fn wait(self) {
        for h in self.workers {
            let _ = h.join();
        }
    }

    /// Number of worker threads spawned (0 = everything was cached).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }
}

/// Artifact root + manifest + compile cache.
pub struct Registry {
    manifest: Manifest,
    cache: Arc<CompileCache>,
}

impl Registry {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(runtime: Arc<Runtime>, root: impl AsRef<Path>) -> Result<Registry> {
        let root = root.as_ref().to_path_buf();
        let mpath = root.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {mpath:?} — run `make artifacts` first"))?;
        let manifest = Manifest::parse(&text)?;
        Ok(Registry {
            manifest,
            cache: Arc::new(CompileCache {
                runtime,
                root,
                ready: Mutex::new(HashMap::new()),
                inflight: Mutex::new(HashSet::new()),
                done: Condvar::new(),
                compiles: Mutex::new(0),
                compile_secs: Mutex::new(0.0),
                hits: Mutex::new(0),
            }),
        })
    }

    /// The backing PJRT runtime.
    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.cache.runtime
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The artifact root directory.
    pub fn root(&self) -> &Path {
        &self.cache.root
    }

    /// Number of XLA compilations performed (cache misses) — used by the
    /// overhead bench to attribute tuning cost.
    pub fn compile_count(&self) -> u64 {
        *self.cache.compiles.lock().unwrap()
    }

    /// Total wall-clock spent compiling, across all threads, in
    /// milliseconds.  With prefetch this can exceed the tuning wall time
    /// — that surplus is exactly the overlap the batched pipeline buys.
    pub fn compile_ms(&self) -> f64 {
        *self.cache.compile_secs.lock().unwrap() * 1e3
    }

    /// Number of `load` calls served from the ready cache.
    pub fn cache_hits(&self) -> u64 {
        *self.cache.hits.lock().unwrap()
    }

    /// Compile (or fetch from cache) the artifact at a manifest-relative
    /// path.  If the artifact is being prefetched on another thread,
    /// waits for that compile instead of duplicating it.
    pub fn load(&self, rel_path: &str) -> Result<Arc<Executable>> {
        self.cache.load(rel_path)
    }

    /// Compile a batch of artifacts on background worker threads while
    /// the caller keeps the main thread for measurement (timing fidelity:
    /// only compilation is parallel, never the timed executions).
    ///
    /// Compile errors are swallowed here — the subsequent synchronous
    /// `load` of the failing path re-compiles and surfaces the error in
    /// the evaluation that owns it.
    pub fn prefetch(&self, rel_paths: &[String]) -> PrefetchHandle {
        let pending: Vec<String> = {
            let ready = self.cache.ready.lock().unwrap();
            rel_paths
                .iter()
                .filter(|p| !ready.contains_key(p.as_str()))
                .cloned()
                .collect()
        };
        if pending.is_empty() {
            return PrefetchHandle { workers: Vec::new() };
        }
        self.spawn_prefetch(pending)
    }

    /// Background-thread prefetch.  Requires the backend's client and
    /// executable types to be `Send + Sync`, which the hermetic stub
    /// guarantees.
    #[cfg(not(feature = "xla-runtime"))]
    fn spawn_prefetch(&self, pending: Vec<String>) -> PrefetchHandle {
        let nworkers = thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1))
            .unwrap_or(1)
            .clamp(1, 8)
            .min(pending.len());
        let queue = Arc::new(Mutex::new(pending));
        let workers = (0..nworkers)
            .map(|_| {
                let cache = Arc::clone(&self.cache);
                let queue = Arc::clone(&queue);
                thread::spawn(move || loop {
                    let next = queue.lock().unwrap().pop();
                    match next {
                        Some(path) => {
                            let _ = cache.load(&path);
                        }
                        None => break,
                    }
                })
            })
            .collect();
        PrefetchHandle { workers }
    }

    /// Real-backend prefetch: the PJRT C++ layer is thread-safe, but
    /// the Rust binding types do not declare `Send`/`Sync`, so
    /// executables cannot cross threads.  Compile the batch eagerly on
    /// the caller's thread instead — the batched pipeline stays correct
    /// (every artifact is warm before any repetition is timed), it just
    /// forgoes compile/measure overlap until the bindings grow
    /// thread-safe wrappers.
    #[cfg(feature = "xla-runtime")]
    fn spawn_prefetch(&self, pending: Vec<String>) -> PrefetchHandle {
        for path in &pending {
            let _ = self.cache.load(path);
        }
        PrefetchHandle { workers: Vec::new() }
    }

    /// Drop all cached executables (used by the overhead bench to model
    /// cold-start tuning).
    pub fn clear_cache(&self) {
        self.cache.ready.lock().unwrap().clear();
    }

    /// Find (kernel, workload) or error with the available options.
    pub fn find(&self, kernel: &str, tag: &str) -> Result<(&KernelEntry, &Workload)> {
        let entry = self.manifest.kernel(kernel).ok_or_else(|| {
            let names: Vec<_> = self.manifest.kernels.iter().map(|k| k.name.as_str()).collect();
            anyhow::anyhow!("unknown kernel {kernel}; available: {names:?}")
        })?;
        let workload = entry.workload(tag).ok_or_else(|| {
            let tags: Vec<_> = entry.workloads.iter().map(|w| w.tag.as_str()).collect();
            anyhow::anyhow!("unknown workload {tag} for {kernel}; available: {tags:?}")
        })?;
        Ok((entry, workload))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "generated_by": "compile.aot",
      "kernels": [
        {
          "name": "axpy",
          "params": [
            {"name": "block_size", "abbrev": "b", "values": [256, 1024]},
            {"name": "unroll", "abbrev": "u", "values": [1, 2]}
          ],
          "constraints": ["block_size <= n", "block_size % unroll == 0"],
          "workloads": [
            {
              "tag": "n4096",
              "dims": {"n": 4096},
              "inputs": [
                {"name": "a", "dtype": "f32", "shape": [1]},
                {"name": "x", "dtype": "f32", "shape": [4096]},
                {"name": "y", "dtype": "f32", "shape": [4096]}
              ],
              "output": {"dtype": "f32", "shape": [4096]},
              "flops": 8192,
              "bytes": 49152,
              "baseline": "axpy/n4096/base.hlo.txt",
              "default": "b256_u1",
              "variants": [
                {"id": "b256_u1", "params": {"block_size": 256, "unroll": 1},
                 "path": "axpy/n4096/b256_u1.hlo.txt"}
              ]
            }
          ]
        }
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        assert_eq!(m.kernels.len(), 1);
        let k = m.kernel("axpy").unwrap();
        assert_eq!(k.params.len(), 2);
        assert_eq!(k.params[0].values, vec![256, 1024]);
        assert_eq!(k.constraints.len(), 2);
        let w = k.workload("n4096").unwrap();
        assert_eq!(w.dims["n"], 4096);
        assert_eq!(w.inputs.len(), 3);
        assert_eq!(w.inputs[1].shape, vec![4096]);
        assert_eq!(w.output.dtype, DType::F32);
        assert_eq!(w.flops, 8192);
        assert_eq!(w.default.as_deref(), Some("b256_u1"));
        assert_eq!(w.variants[0].params["block_size"], 256);
        assert!(w.variant("b256_u1").is_some());
        assert!(w.variant("nope").is_none());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"version": 1}"#).is_err());
        let noname = SAMPLE.replace("\"name\": \"axpy\",", "");
        assert!(Manifest::parse(&noname).is_err());
    }

    #[test]
    fn kernel_lookup_misses() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.kernel("nope").is_none());
        assert!(m.kernel("axpy").unwrap().workload("nope").is_none());
    }
}
