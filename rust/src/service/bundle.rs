//! Offline decision bundles: one versioned, checksummed artifact
//! holding a platform's serve state — every shard document, the
//! exporter's fingerprint, and the snapshot generation it was cut at.
//!
//! This closes the cold-start loop: `portatune bundle export` packs the
//! store, the artifact ships with the program (the "ship the autotune
//! cache" idiom), and on the far side either a daemon imports it at
//! startup (`portatune serve --bundle` / `portatune bundle import`) or
//! [`crate::service::client::Client::from_bundle`] answers
//! `lookup`/`deploy`/`portfolio` from it entirely offline — zero daemon
//! round-trips, identical replies by construction (both paths shape
//! replies through [`ServeSnapshot`]).
//!
//! # Format
//!
//! Line-structured, with length-prefixed + SHA-256-checksummed section
//! payloads and a whole-file footer checksum:
//!
//! ```text
//! portatune-bundle v1
//! section meta <byte-len> <sha256-hex>
//! <meta payload bytes>
//! section shard0 <byte-len> <sha256-hex>
//! <shard document bytes>
//! ...
//! end <sha256-hex of every preceding byte>
//! ```
//!
//! The `meta` payload is compact JSON:
//! `{"version":1,"platform":...,"generation":N,"shards":N,
//! "fingerprint":{...}|null}` — it declares the shard-section count, so
//! even a truncation that removes whole trailing sections *and* splices
//! a matching footer is named.  Shard payloads are the store's shard
//! documents verbatim (checksum header included), which is what makes
//! export → import byte-identical.  Every rejection names the exact
//! failing section (`header`, `meta`, `shardN`, `footer`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::perfdb::Shard;
use crate::coordinator::platform::Fingerprint;
use crate::service::protocol::{reply_err, reply_ok, Request};
use crate::service::snapshot::ServeSnapshot;
use crate::util::json::{self, Json};
use crate::util::sha256;

/// First line of every bundle; the trailing `v1` is the format version.
pub const BUNDLE_MAGIC: &str = "portatune-bundle v1";

/// Bundle self-description, carried in the `meta` section.
#[derive(Debug, Clone)]
pub struct BundleMeta {
    /// The platform key this bundle primarily serves (the exporter's
    /// host, or `--platform` at export time).  Offline queries that
    /// name no platform default to it.
    pub platform: String,
    /// Snapshot generation the bundle was cut at; offline replies echo
    /// it, so bundle answers are comparable to live ones.
    pub generation: u64,
    /// The exporter's fingerprint — the transfer-ranking fallback for
    /// platforms with no stored fingerprint, frozen at export so
    /// offline answers do not drift with the querying machine.
    pub fingerprint: Option<Fingerprint>,
}

impl BundleMeta {
    fn to_json(&self, shards: usize) -> Json {
        json::obj(vec![
            ("version", json::int(1)),
            ("platform", json::s(&self.platform)),
            ("generation", json::int(self.generation as i64)),
            ("shards", json::int(shards as i64)),
            (
                "fingerprint",
                self.fingerprint.as_ref().map(Fingerprint::to_json).unwrap_or(Json::Null),
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<(BundleMeta, usize)> {
        let version = v.get("version").and_then(Json::as_i64).unwrap_or(0);
        anyhow::ensure!(version == 1, "bundle section meta: unsupported version {version}");
        let platform = v
            .get("platform")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("bundle section meta: missing platform"))?
            .to_string();
        let generation = v.get("generation").and_then(Json::as_u64).unwrap_or(0);
        let shards = v
            .get("shards")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("bundle section meta: missing shard count"))?
            as usize;
        let fingerprint = match v.get("fingerprint") {
            Some(Json::Null) | None => None,
            Some(f) => Some(Fingerprint::from_json(f).ok_or_else(|| {
                anyhow::anyhow!("bundle section meta: malformed fingerprint")
            })?),
        };
        Ok((BundleMeta { platform, generation, fingerprint }, shards))
    }
}

/// Serialize a bundle from its meta and the raw shard document texts
/// (exactly as they sit on disk — see the module docs on byte
/// identity).
pub fn write_bundle(meta: &BundleMeta, shard_texts: &[String]) -> String {
    let mut out = String::new();
    out.push_str(BUNDLE_MAGIC);
    out.push('\n');
    let mut section = |name: &str, payload: &str| {
        out.push_str(&format!(
            "section {name} {} {}\n{payload}\n",
            payload.len(),
            sha256::hex_digest(payload.as_bytes())
        ));
    };
    section("meta", &meta.to_json(shard_texts.len()).compact());
    for (i, text) in shard_texts.iter().enumerate() {
        section(&format!("shard{i}"), text);
    }
    let footer = sha256::hex_digest(out.as_bytes());
    out.push_str(&format!("end {footer}\n"));
    out
}

/// Parse and fully verify a bundle.  Every failure mode — bad magic,
/// truncation anywhere, any flipped byte — is rejected with the exact
/// failing section named in the error.
pub fn parse_bundle(text: &str) -> Result<(BundleMeta, Vec<String>)> {
    let bytes = text.as_bytes();
    let header_end = text
        .find('\n')
        .ok_or_else(|| anyhow::anyhow!("bundle header: truncated before the first line end"))?;
    anyhow::ensure!(
        &text[..header_end] == BUNDLE_MAGIC,
        "bundle header: unrecognized magic {:?} (want {BUNDLE_MAGIC:?})",
        text[..header_end].chars().take(40).collect::<String>()
    );
    let mut pos = header_end + 1;
    let mut sections: Vec<(String, String)> = Vec::new();
    let mut saw_footer = false;
    while pos < bytes.len() {
        let line_end = text[pos..]
            .find('\n')
            .map(|i| pos + i)
            .ok_or_else(|| anyhow::anyhow!("bundle footer: missing (file truncated)"))?;
        let line = &text[pos..line_end];
        if let Some(rest) = line.strip_prefix("section ") {
            let mut parts = rest.split(' ');
            let (name, len, stated) = match (parts.next(), parts.next(), parts.next(), parts.next())
            {
                (Some(n), Some(l), Some(s), None) => (n.to_string(), l, s),
                _ => anyhow::bail!("bundle structure: malformed section header {line:?}"),
            };
            let len: usize = len
                .parse()
                .map_err(|_| anyhow::anyhow!("bundle section {name}: non-numeric length"))?;
            let payload_start = line_end + 1;
            let payload = bytes.get(payload_start..payload_start + len).ok_or_else(|| {
                anyhow::anyhow!(
                    "bundle section {name}: truncated inside payload (need {len} bytes, have {})",
                    bytes.len().saturating_sub(payload_start)
                )
            })?;
            anyhow::ensure!(
                sha256::hex_digest(payload) == stated,
                "bundle section {name}: checksum mismatch"
            );
            anyhow::ensure!(
                bytes.get(payload_start + len) == Some(&b'\n'),
                "bundle section {name}: missing payload terminator"
            );
            let payload = std::str::from_utf8(payload)
                .map_err(|_| anyhow::anyhow!("bundle section {name}: payload is not UTF-8"))?;
            sections.push((name, payload.to_string()));
            pos = payload_start + len + 1;
        } else if let Some(stated) = line.strip_prefix("end ") {
            anyhow::ensure!(
                sha256::hex_digest(&bytes[..pos]) == stated,
                "bundle footer: whole-file checksum mismatch"
            );
            anyhow::ensure!(
                line_end + 1 == bytes.len(),
                "bundle footer: trailing data after the footer line"
            );
            saw_footer = true;
            break;
        } else {
            anyhow::bail!("bundle structure: unrecognized line {line:?}");
        }
    }
    anyhow::ensure!(saw_footer, "bundle footer: missing (file truncated)");
    let mut sections = sections.into_iter();
    let (meta_name, meta_text) = sections
        .next()
        .ok_or_else(|| anyhow::anyhow!("bundle section meta: missing"))?;
    anyhow::ensure!(meta_name == "meta", "bundle section meta: first section is {meta_name:?}");
    let meta_json = json::parse(&meta_text)
        .map_err(|e| anyhow::anyhow!("bundle section meta: invalid json ({e})"))?;
    let (meta, declared) = BundleMeta::from_json(&meta_json)?;
    let mut shard_texts = Vec::new();
    for (i, (name, text)) in sections.enumerate() {
        anyhow::ensure!(
            name == format!("shard{i}"),
            "bundle section {name}: expected shard{i} at this position"
        );
        shard_texts.push(text);
    }
    anyhow::ensure!(
        shard_texts.len() == declared,
        "bundle section meta: declares {declared} shards, found {}",
        shard_texts.len()
    );
    Ok((meta, shard_texts))
}

/// A fully verified bundle, indexed for serving: what
/// [`crate::service::client::Client::from_bundle`] answers from.
#[derive(Debug)]
pub struct OfflineBundle {
    platform: String,
    host: Fingerprint,
    snapshot: ServeSnapshot,
}

impl OfflineBundle {
    /// Parse, verify, and index a bundle from its serialized text.
    pub fn from_text(text: &str) -> Result<OfflineBundle> {
        let (meta, shard_texts) = parse_bundle(text)?;
        let shards = shard_texts
            .iter()
            .enumerate()
            .map(|(i, t)| {
                Shard::parse(t).with_context(|| format!("bundle section shard{i}"))
            })
            .collect::<Result<Vec<Shard>>>()?;
        let host = meta.fingerprint.clone().unwrap_or_else(Fingerprint::detect);
        Ok(OfflineBundle {
            platform: meta.platform,
            host,
            snapshot: ServeSnapshot::build(shards, meta.generation),
        })
    }

    /// Load a bundle file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<OfflineBundle> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bundle {}", path.display()))?;
        Self::from_text(&text).with_context(|| format!("loading bundle {}", path.display()))
    }

    /// The bundle's default platform (queries naming none use it).
    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// The indexed snapshot the bundle serves from.
    pub fn snapshot(&self) -> &ServeSnapshot {
        &self.snapshot
    }

    /// Answer one request offline.  The read ops (`ping`, `lookup`,
    /// `deploy`, `portfolio`) shape their replies through the same
    /// [`ServeSnapshot`] methods the daemon uses, so the answers are
    /// identical to a live daemon serving the same snapshot; every
    /// other op needs daemon state and gets a definitive error reply.
    pub fn answer(&self, req: &Request) -> Json {
        match req {
            Request::Ping => reply_ok(vec![
                ("op", json::s("pong")),
                ("platform", json::s(&self.platform)),
            ]),
            Request::Lookup { platform, kernel, workload } => {
                let platform = platform.as_deref().unwrap_or(&self.platform);
                self.snapshot.lookup_reply(platform, kernel, workload).0
            }
            Request::Deploy { platform, kernel, workload, fingerprint } => {
                let platform = platform.as_deref().unwrap_or(&self.platform);
                self.snapshot
                    .deploy_reply(platform, kernel, workload, fingerprint.as_ref(), &self.host)
                    .0
            }
            Request::Portfolio { platform, kernel, dims, fingerprint } => {
                let platform = platform.as_deref().unwrap_or(&self.platform);
                let dims: Option<&BTreeMap<String, i64>> = dims.as_ref();
                self.snapshot
                    .portfolio_reply(platform, kernel, dims, fingerprint.as_ref(), &self.host)
                    .0
            }
            Request::Report { platform } => {
                // The economics report is shard data, so the offline
                // bundle answers it too — same shaping as the daemon,
                // minus the (sentinel-owned, daemon-only) live flags.
                self.snapshot.report_reply(platform.as_deref())
            }
            other => reply_err(&format!(
                "offline bundle client: op '{}' requires a daemon",
                other.op_name()
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::perfdb::{unix_now, DbEntry, ShardedDb};

    fn fp() -> Fingerprint {
        Fingerprint {
            cpu_model: "Bundle CPU".into(),
            num_cpus: 8,
            simd: vec!["avx2".into()],
            cache_l1d_kb: 32,
            cache_l2_kb: 1024,
            cache_l3_kb: 8192,
            os: "linux".into(),
        }
    }

    fn entry(platform: &str, kernel: &str, tag: &str, id: &str) -> DbEntry {
        DbEntry {
            platform_key: platform.into(),
            kernel: kernel.into(),
            tag: tag.into(),
            best_params: [("block_size".to_string(), 256i64)].into_iter().collect(),
            best_config_id: id.into(),
            best_time_s: 1e-3,
            baseline_time_s: 2e-3,
            reference_time_s: 9e-4,
            evaluations: 4,
            strategy: "exhaustive".into(),
            recorded_at: unix_now(),
        }
    }

    fn sample_bundle() -> String {
        // Unique per call: the tests run in parallel in one process.
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dir = std::env::temp_dir()
            .join(format!("portatune-bundletest-{}-{seq}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let db = ShardedDb::open(&dir).unwrap();
        db.record(Some(&fp()), entry("p1", "axpy", "n4096", "cfg1")).unwrap();
        db.record(None, entry("p2", "dot", "n4096", "cfg2")).unwrap();
        let texts: Vec<String> = ["p1", "p2"]
            .iter()
            .map(|p| db.export_shard_text(p).unwrap().unwrap())
            .collect();
        std::fs::remove_dir_all(&dir).ok();
        let meta = BundleMeta {
            platform: "p1".into(),
            generation: 9,
            fingerprint: Some(fp()),
        };
        write_bundle(&meta, &texts)
    }

    #[test]
    fn round_trips_meta_and_shard_texts() {
        let text = sample_bundle();
        let (meta, shards) = parse_bundle(&text).unwrap();
        assert_eq!(meta.platform, "p1");
        assert_eq!(meta.generation, 9);
        assert_eq!(shards.len(), 2);
        // Re-serializing the parsed payloads reproduces the bundle.
        assert_eq!(write_bundle(&meta, &shards), text);
    }

    #[test]
    fn offline_answers_come_from_the_snapshot() {
        let bundle = OfflineBundle::from_text(&sample_bundle()).unwrap();
        let reply = bundle.answer(&Request::Lookup {
            platform: Some("p1".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
        });
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
        assert_eq!(reply.get("gen").and_then(Json::as_u64), Some(9));
        let reply = bundle.answer(&Request::Stats);
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(false));
        assert!(reply
            .get("error")
            .and_then(Json::as_str)
            .unwrap()
            .contains("requires a daemon"));
    }

    #[test]
    fn bad_magic_names_the_header() {
        let text = sample_bundle().replacen("portatune", "portatun3", 1);
        let err = format!("{:#}", parse_bundle(&text).unwrap_err());
        assert!(err.contains("bundle header"), "{err}");
    }

    #[test]
    fn flipped_payload_byte_names_its_section() {
        let text = sample_bundle();
        // Flip a byte inside the second shard's payload.
        let marker = "cfg2";
        let at = text.find(marker).unwrap();
        let mut bytes = text.into_bytes();
        bytes[at] ^= 0x01;
        let err =
            format!("{:#}", parse_bundle(std::str::from_utf8(&bytes).unwrap()).unwrap_err());
        assert!(err.contains("shard1"), "flip must be pinned to shard1: {err}");
    }

    #[test]
    fn truncation_names_the_failing_section() {
        let text = sample_bundle();
        let cut = &text[..text.len() / 2];
        let err = format!("{:#}", parse_bundle(cut).unwrap_err());
        assert!(err.contains("bundle"), "{err}");
    }
}
