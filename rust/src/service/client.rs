//! Client side of the serve protocol — what `portatune query` (and any
//! embedder that wants tuned configurations without running a search)
//! speaks.
//!
//! One connection per call: requests are rare (deploy-time lookups),
//! so connection reuse buys nothing and a stateless client cannot leak
//! sockets.  Both endpoints the daemon listens on are supported, plus
//! a fully offline one: [`Client::from_bundle`] loads an exported
//! decision bundle (see [`crate::service::bundle`]) and answers
//! `lookup`/`deploy`/`portfolio` in-process with zero daemon
//! round-trips — the cold-start path for machines without a daemon.
//!
//! **Resilience.**  Every socket carries connect/read/write timeouts
//! (a dead daemon can no longer hang `query`/`work` forever), and
//! transient failures retry under a bounded [`RetryPolicy`] with
//! exponential backoff + jitter.  Retry safety is per op:
//!
//! * idempotent ops (lookup, deploy, stats, the lease/heartbeat/fail
//!   ops, portfolio reads and replacements) retry transparently;
//! * the non-idempotent writes — `record` and `task-complete` — retry
//!   only when they carry a client-generated `request_id` the daemon
//!   dedupes (the typed helpers [`Client::record`] and
//!   [`Client::complete_task`] always attach one); a bare
//!   `Request::Record`/`Request::TaskComplete` without an id is sent
//!   exactly once;
//! * `shutdown` is always a single attempt.
//!
//! Only transport-level failures (connect errors, timeouts, a
//! connection closed without a reply) and the daemon's explicit
//! `overloaded` shed reply are retried; any other daemon-reported
//! error is returned immediately.

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::perfdb::DbEntry;
use crate::coordinator::platform::Fingerprint;
use crate::obs::trace;
use crate::service::faults::{self, InjectionPoint};
use crate::service::protocol::Request;
use crate::service::scheduler::{TaskKind, TuningTask};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// A checked-out task: what to do plus the lease that owns it.
#[derive(Debug, Clone)]
pub struct LeasedTask {
    /// Lease id to heartbeat / settle with.
    pub lease_id: u64,
    /// Granted lease TTL in seconds.
    pub ttl_s: u64,
    /// The work itself.
    pub task: TuningTask,
}

/// Where the daemon listens — or, for the offline variant, where the
/// answers come from without any daemon at all.
#[derive(Debug, Clone)]
pub enum Endpoint {
    /// `host:port`.
    Tcp(String),
    /// Unix-domain socket path.
    #[cfg(unix)]
    Unix(PathBuf),
    /// An in-process offline decision bundle: read ops are answered
    /// from its snapshot, write/task ops fail with a daemon-required
    /// error.  `Arc` so cloning the client shares the parsed bundle.
    Bundle(std::sync::Arc<crate::service::bundle::OfflineBundle>),
}

/// Bounded-retry + timeout configuration for a [`Client`].
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts for a retryable op (1 = no retries).
    pub attempts: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub base_delay: Duration,
    /// Backoff ceiling.
    pub max_delay: Duration,
    /// TCP connect timeout.
    pub connect_timeout: Duration,
    /// Socket read/write timeout, set at connect time.
    pub io_timeout: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            attempts: 3,
            base_delay: Duration::from_millis(50),
            max_delay: Duration::from_secs(2),
            connect_timeout: Duration::from_secs(5),
            io_timeout: Duration::from_secs(10),
        }
    }
}

impl RetryPolicy {
    /// Exponential backoff with full jitter for the given retry
    /// (1-based): `base * 2^(n-1)` capped at `max_delay`, scaled by a
    /// uniform factor in [0.5, 1) so synchronized clients desynchronize.
    pub fn backoff(&self, retry: u32) -> Duration {
        let exp = self
            .base_delay
            .saturating_mul(1u32 << retry.saturating_sub(1).min(16))
            .min(self.max_delay);
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos() as u64)
            .unwrap_or(0);
        let mut rng = Rng::new(nanos ^ ((retry as u64) << 32) | 1);
        exp.mul_f64(0.5 + 0.5 * rng.next_f64())
    }
}

/// A stateless protocol client.
#[derive(Debug, Clone)]
pub struct Client {
    endpoint: Endpoint,
    policy: RetryPolicy,
}

impl Client {
    /// A client for a TCP endpoint (`host:port`).
    pub fn tcp(addr: impl Into<String>) -> Client {
        Client { endpoint: Endpoint::Tcp(addr.into()), policy: RetryPolicy::default() }
    }

    #[cfg(unix)]
    /// A client for a Unix-domain-socket endpoint.
    pub fn unix(path: impl Into<PathBuf>) -> Client {
        Client { endpoint: Endpoint::Unix(path.into()), policy: RetryPolicy::default() }
    }

    /// A fully offline client over an exported decision bundle: loads
    /// and verifies the bundle once, then answers read ops from its
    /// snapshot with zero daemon round-trips.
    pub fn from_bundle(path: impl AsRef<std::path::Path>) -> Result<Client> {
        let bundle = crate::service::bundle::OfflineBundle::load(path)?;
        Ok(Client {
            endpoint: Endpoint::Bundle(std::sync::Arc::new(bundle)),
            policy: RetryPolicy::default(),
        })
    }

    /// Replace the retry/timeout policy (builder style).
    pub fn with_policy(mut self, policy: RetryPolicy) -> Client {
        self.policy = policy;
        self
    }

    /// Send one request, return the parsed reply object.  Retryable
    /// ops (see the module docs) are re-sent under the policy when the
    /// failure was transient; everything else is a single attempt.
    ///
    /// When tracing is armed the whole call (retries included) is one
    /// `call:<op>` span, and the request carries a `trace_id` — the
    /// thread's ambient id if set (workers propagate their task id),
    /// else a fresh one — which the daemon echoes and stamps into its
    /// own spans, linking client and server timelines.
    pub fn call(&self, req: &Request) -> Result<Json> {
        let trace_id = if trace::enabled() {
            Some(trace::current().unwrap_or_else(trace::fresh_trace_id))
        } else {
            None
        };
        let span = trace::span(format!("call:{}", req.op_name()), "client");
        let result = self.call_retrying(req, trace_id.as_deref());
        if let Some(s) = span {
            s.finish(trace_id.as_deref());
        }
        result
    }

    fn call_retrying(&self, req: &Request, trace_id: Option<&str>) -> Result<Json> {
        let attempts = if Self::op_retries_transparently(req) {
            self.policy.attempts.max(1)
        } else {
            1
        };
        let mut last = None;
        for attempt in 1..=attempts {
            if attempt > 1 {
                std::thread::sleep(self.policy.backoff(attempt - 1));
            }
            match self.call_once(req, trace_id) {
                Ok(reply) => return Ok(reply),
                Err(e) if attempt < attempts && error_is_transient(&e) => last = Some(e),
                Err(e) => return Err(e),
            }
        }
        Err(last
            .unwrap_or_else(|| anyhow::anyhow!("retry budget exhausted"))
            .context(format!("after {attempts} attempts")))
    }

    /// Whether `req` may be transparently re-sent after a transient
    /// failure without risking double application.
    fn op_retries_transparently(req: &Request) -> bool {
        match req {
            // Non-idempotent writes: only safe with a dedupe id.
            Request::Record { request_id, .. } | Request::TaskComplete { request_id, .. } => {
                request_id.is_some()
            }
            // Retrying shutdown against a daemon that just obeyed it
            // only produces a confusing connect error.
            Request::Shutdown => false,
            _ => true,
        }
    }

    fn call_once(&self, req: &Request, trace_id: Option<&str>) -> Result<Json> {
        match &self.endpoint {
            Endpoint::Tcp(addr) => {
                use std::net::ToSocketAddrs;
                let sock = addr
                    .to_socket_addrs()
                    .with_context(|| format!("resolving portatune daemon address {addr}"))?
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("address {addr} resolved to nothing"))?;
                let stream =
                    std::net::TcpStream::connect_timeout(&sock, self.policy.connect_timeout)
                        .with_context(|| format!("connecting to portatune daemon at {addr}"))?;
                let _ = stream.set_read_timeout(Some(self.policy.io_timeout));
                let _ = stream.set_write_timeout(Some(self.policy.io_timeout));
                let _ = stream.set_nodelay(true);
                if faults::hit(InjectionPoint::ClientConnectDrop) {
                    anyhow::bail!("fault-injected: connection dropped before request");
                }
                Self::exchange(req, trace_id, &stream, &stream)
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let stream = std::os::unix::net::UnixStream::connect(path).with_context(|| {
                    format!("connecting to portatune daemon at {}", path.display())
                })?;
                let _ = stream.set_read_timeout(Some(self.policy.io_timeout));
                let _ = stream.set_write_timeout(Some(self.policy.io_timeout));
                if faults::hit(InjectionPoint::ClientConnectDrop) {
                    anyhow::bail!("fault-injected: connection dropped before request");
                }
                Self::exchange(req, trace_id, &stream, &stream)
            }
            Endpoint::Bundle(bundle) => {
                // No socket: the bundle answers in-process.  Error
                // replies convert exactly as `exchange` converts them,
                // so `error_is_transient` and callers see the same
                // `daemon error: ...` shape either way.
                let reply = bundle.answer(req);
                if reply.get("ok").and_then(Json::as_bool) != Some(true) {
                    let msg = reply
                        .get("error")
                        .and_then(Json::as_str)
                        .unwrap_or("daemon reported failure without a message");
                    return Err(anyhow::anyhow!("daemon error: {msg}"));
                }
                Ok(reply)
            }
        }
    }

    /// Write one tuning record, attaching a fresh `request_id` so the
    /// write retries safely: a lost ack re-sends the same id and the
    /// daemon replays the original reply instead of re-recording.
    pub fn record(&self, entry: DbEntry, fingerprint: Option<Fingerprint>) -> Result<Json> {
        self.record_with_spend(entry, fingerprint, None)
    }

    /// [`record`](Self::record), declaring how many core-milliseconds
    /// of tuning work (compile + measure) produced this entry.  The
    /// daemon accrues the spend into the platform's core-hour ledger
    /// atomically with the entry, so retried sends cannot double-bill.
    pub fn record_with_spend(
        &self,
        entry: DbEntry,
        fingerprint: Option<Fingerprint>,
        spend_ms: Option<u64>,
    ) -> Result<Json> {
        self.call(&Request::Record {
            entry: Box::new(entry),
            fingerprint,
            request_id: Some(fresh_request_id()),
            spend_ms,
        })
    }

    /// Fetch the tuning-economics report: per-kernel spend / benefit /
    /// break-even plus active regressions, optionally filtered to one
    /// platform.
    pub fn report(&self, platform: Option<String>) -> Result<Json> {
        self.call(&Request::Report { platform })
    }

    /// Check out the next tuning task under a lease (the worker
    /// fleet's poll).  `Ok(None)` means the queue had nothing matching
    /// the filters.
    pub fn lease_task(
        &self,
        kind: Option<TaskKind>,
        platform: Option<String>,
        ttl_s: Option<u64>,
    ) -> Result<Option<LeasedTask>> {
        let reply = self.call(&Request::TaskLease { kind, platform, ttl_s })?;
        if reply.get("found").and_then(Json::as_bool) != Some(true) {
            return Ok(None);
        }
        let lease_id = reply
            .get("lease_id")
            .and_then(Json::as_u64)
            .context("task-lease reply missing lease_id")?;
        let ttl_s = reply.get("ttl_s").and_then(Json::as_u64).unwrap_or(0);
        let task = TuningTask::from_json(
            reply.get("task").context("task-lease reply missing task")?,
        )?;
        Ok(Some(LeasedTask { lease_id, ttl_s, task }))
    }

    /// Extend a lease.  `Ok(false)` means the lease is gone (expired
    /// or settled) and the worker should abandon the task.
    pub fn heartbeat_task(&self, lease_id: u64) -> Result<bool> {
        let reply = self.call(&Request::TaskHeartbeat { lease_id })?;
        Ok(reply.get("extended").and_then(Json::as_bool) == Some(true))
    }

    /// Settle a lease as done.  `Ok(true)` when this call settled it,
    /// `Ok(false)` when it was already settled (idempotent retry).
    /// Carries a fresh `request_id` so a retried completion whose
    /// first ack was lost still answers like the first attempt.
    pub fn complete_task(&self, lease_id: u64) -> Result<bool> {
        let reply = self.call(&Request::TaskComplete {
            lease_id,
            request_id: Some(fresh_request_id()),
        })?;
        Ok(reply.get("duplicate").and_then(Json::as_bool) != Some(true))
    }

    /// Settle a lease as failed.  `Ok(true)` when the task requeued
    /// for another attempt, `Ok(false)` when it was dropped or already
    /// settled.
    pub fn fail_task(&self, lease_id: u64, error: &str) -> Result<bool> {
        let reply = self.call(&Request::TaskFail {
            lease_id,
            error: Some(error.to_string()),
        })?;
        Ok(reply.get("requeued").and_then(Json::as_bool) == Some(true))
    }

    fn exchange(
        req: &Request,
        trace_id: Option<&str>,
        mut writer: impl Write,
        reader: impl std::io::Read,
    ) -> Result<Json> {
        writer
            .write_all(req.to_line_traced(trace_id).as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush())
            .context("sending request")?;
        faults::stall(InjectionPoint::ClientReadStall);
        let mut line = String::new();
        BufReader::new(reader).read_line(&mut line).context("reading reply")?;
        anyhow::ensure!(!line.trim().is_empty(), "daemon closed the connection without a reply");
        let reply = json::parse(line.trim()).context("parsing reply json")?;
        if reply.get("ok").and_then(Json::as_bool) != Some(true) {
            let msg = reply
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("daemon reported failure without a message");
            return Err(anyhow::anyhow!("daemon error: {msg}"));
        }
        Ok(reply)
    }
}

/// A process-unique opaque dedupe id: pid + wall-clock nanos + a
/// process-wide sequence number.  Uniqueness, not secrecy, is the
/// requirement — the daemon only compares ids for equality.
fn fresh_request_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    format!("{:x}-{nanos:x}-{seq:x}", std::process::id())
}

/// Transient = worth retrying: transport failures (connect/timeout/
/// closed-without-reply) and the daemon's explicit `overloaded` shed
/// reply.  Any other daemon-reported error is definitive.
fn error_is_transient(e: &anyhow::Error) -> bool {
    let text = format!("{e:#}");
    match text.find("daemon error: ") {
        None => true,
        Some(i) => text[i + "daemon error: ".len()..].starts_with("overloaded"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_safety_classification() {
        let entry = || {
            Box::new(DbEntry {
                platform_key: "p".into(),
                kernel: "axpy".into(),
                tag: "n64".into(),
                best_params: Default::default(),
                best_config_id: "b".into(),
                best_time_s: 1.0,
                baseline_time_s: 1.0,
                reference_time_s: 1.0,
                evaluations: 1,
                strategy: "t".into(),
                recorded_at: 1,
            })
        };
        assert!(Client::op_retries_transparently(&Request::Ping));
        assert!(Client::op_retries_transparently(&Request::Stats));
        assert!(Client::op_retries_transparently(&Request::TaskHeartbeat { lease_id: 1 }));
        assert!(!Client::op_retries_transparently(&Request::Shutdown));
        assert!(!Client::op_retries_transparently(&Request::Record {
            entry: entry(),
            fingerprint: None,
            request_id: None,
            spend_ms: None,
        }));
        assert!(Client::op_retries_transparently(&Request::Record {
            entry: entry(),
            fingerprint: None,
            request_id: Some("id-1".into()),
            spend_ms: Some(1200),
        }));
        assert!(!Client::op_retries_transparently(&Request::TaskComplete {
            lease_id: 1,
            request_id: None,
        }));
        assert!(Client::op_retries_transparently(&Request::TaskComplete {
            lease_id: 1,
            request_id: Some("id-2".into()),
        }));
    }

    #[test]
    fn transient_error_classification() {
        assert!(error_is_transient(&anyhow::anyhow!("connecting to portatune daemon at x")));
        assert!(error_is_transient(&anyhow::anyhow!(
            "daemon closed the connection without a reply"
        )));
        assert!(error_is_transient(&anyhow::anyhow!(
            "daemon error: overloaded: 64 connections in flight"
        )));
        assert!(!error_is_transient(&anyhow::anyhow!("daemon error: unknown op warp")));
    }

    #[test]
    fn backoff_grows_and_caps() {
        let p = RetryPolicy {
            base_delay: Duration::from_millis(100),
            max_delay: Duration::from_millis(350),
            ..RetryPolicy::default()
        };
        // Jitter scales into [0.5, 1) of the exponential value.
        let b1 = p.backoff(1);
        assert!(b1 >= Duration::from_millis(50) && b1 < Duration::from_millis(100), "{b1:?}");
        let b4 = p.backoff(4);
        assert!(b4 < Duration::from_millis(350), "cap violated: {b4:?}");
    }

    #[test]
    fn request_ids_are_unique() {
        let ids: std::collections::HashSet<String> =
            (0..100).map(|_| fresh_request_id()).collect();
        assert_eq!(ids.len(), 100);
    }

    #[test]
    fn dead_endpoint_errors_within_the_retry_budget() {
        // Port 1 refuses immediately; three attempts must still come
        // back as a transport error, not hang.
        let client = Client::tcp("127.0.0.1:1").with_policy(RetryPolicy {
            attempts: 3,
            base_delay: Duration::from_millis(1),
            ..RetryPolicy::default()
        });
        assert!(client.call(&Request::Ping).is_err());
    }
}
