//! Client side of the serve protocol — what `portatune query` (and any
//! embedder that wants tuned configurations without running a search)
//! speaks.
//!
//! One connection per call: requests are rare (deploy-time lookups),
//! so connection reuse buys nothing and a stateless client cannot leak
//! sockets.  Both endpoints the daemon listens on are supported.

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::service::protocol::Request;
use crate::service::scheduler::{TaskKind, TuningTask};
use crate::util::json::{self, Json};

/// A checked-out task: what to do plus the lease that owns it.
#[derive(Debug, Clone)]
pub struct LeasedTask {
    /// Lease id to heartbeat / settle with.
    pub lease_id: u64,
    /// Granted lease TTL in seconds.
    pub ttl_s: u64,
    /// The work itself.
    pub task: TuningTask,
}

/// Where the daemon listens.
#[derive(Debug, Clone)]
pub enum Endpoint {
    /// `host:port`.
    Tcp(String),
    /// Unix-domain socket path.
    #[cfg(unix)]
    Unix(PathBuf),
}

/// A stateless protocol client.
#[derive(Debug, Clone)]
pub struct Client {
    endpoint: Endpoint,
}

impl Client {
    /// A client for a TCP endpoint (`host:port`).
    pub fn tcp(addr: impl Into<String>) -> Client {
        Client { endpoint: Endpoint::Tcp(addr.into()) }
    }

    #[cfg(unix)]
    /// A client for a Unix-domain-socket endpoint.
    pub fn unix(path: impl Into<PathBuf>) -> Client {
        Client { endpoint: Endpoint::Unix(path.into()) }
    }

    /// Send one request, return the parsed reply object.
    pub fn call(&self, req: &Request) -> Result<Json> {
        match &self.endpoint {
            Endpoint::Tcp(addr) => {
                let stream = std::net::TcpStream::connect(addr)
                    .with_context(|| format!("connecting to portatune daemon at {addr}"))?;
                let _ = stream.set_nodelay(true);
                Self::exchange(req, &stream, &stream)
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let stream = std::os::unix::net::UnixStream::connect(path).with_context(|| {
                    format!("connecting to portatune daemon at {}", path.display())
                })?;
                Self::exchange(req, &stream, &stream)
            }
        }
    }

    /// Check out the next tuning task under a lease (the worker
    /// fleet's poll).  `Ok(None)` means the queue had nothing matching
    /// the filters.
    pub fn lease_task(
        &self,
        kind: Option<TaskKind>,
        platform: Option<String>,
        ttl_s: Option<u64>,
    ) -> Result<Option<LeasedTask>> {
        let reply = self.call(&Request::TaskLease { kind, platform, ttl_s })?;
        if reply.get("found").and_then(Json::as_bool) != Some(true) {
            return Ok(None);
        }
        let lease_id = reply
            .get("lease_id")
            .and_then(Json::as_u64)
            .context("task-lease reply missing lease_id")?;
        let ttl_s = reply.get("ttl_s").and_then(Json::as_u64).unwrap_or(0);
        let task = TuningTask::from_json(
            reply.get("task").context("task-lease reply missing task")?,
        )?;
        Ok(Some(LeasedTask { lease_id, ttl_s, task }))
    }

    /// Extend a lease.  `Ok(false)` means the lease is gone (expired
    /// or settled) and the worker should abandon the task.
    pub fn heartbeat_task(&self, lease_id: u64) -> Result<bool> {
        let reply = self.call(&Request::TaskHeartbeat { lease_id })?;
        Ok(reply.get("extended").and_then(Json::as_bool) == Some(true))
    }

    /// Settle a lease as done.  `Ok(true)` when this call settled it,
    /// `Ok(false)` when it was already settled (idempotent retry).
    pub fn complete_task(&self, lease_id: u64) -> Result<bool> {
        let reply = self.call(&Request::TaskComplete { lease_id })?;
        Ok(reply.get("duplicate").and_then(Json::as_bool) != Some(true))
    }

    /// Settle a lease as failed.  `Ok(true)` when the task requeued
    /// for another attempt, `Ok(false)` when it was dropped or already
    /// settled.
    pub fn fail_task(&self, lease_id: u64, error: &str) -> Result<bool> {
        let reply = self.call(&Request::TaskFail {
            lease_id,
            error: Some(error.to_string()),
        })?;
        Ok(reply.get("requeued").and_then(Json::as_bool) == Some(true))
    }

    fn exchange(
        req: &Request,
        mut writer: impl Write,
        reader: impl std::io::Read,
    ) -> Result<Json> {
        writer
            .write_all(req.to_line().as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush())
            .context("sending request")?;
        let mut line = String::new();
        BufReader::new(reader).read_line(&mut line).context("reading reply")?;
        anyhow::ensure!(!line.trim().is_empty(), "daemon closed the connection without a reply");
        let reply = json::parse(line.trim()).context("parsing reply json")?;
        if reply.get("ok").and_then(Json::as_bool) != Some(true) {
            let msg = reply
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("daemon reported failure without a message");
            return Err(anyhow::anyhow!("daemon error: {msg}"));
        }
        Ok(reply)
    }
}
