//! Client side of the serve protocol — what `portatune query` (and any
//! embedder that wants tuned configurations without running a search)
//! speaks.
//!
//! One connection per call: requests are rare (deploy-time lookups),
//! so connection reuse buys nothing and a stateless client cannot leak
//! sockets.  Both endpoints the daemon listens on are supported.

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::service::protocol::Request;
use crate::util::json::{self, Json};

/// Where the daemon listens.
#[derive(Debug, Clone)]
pub enum Endpoint {
    /// `host:port`.
    Tcp(String),
    /// Unix-domain socket path.
    #[cfg(unix)]
    Unix(PathBuf),
}

/// A stateless protocol client.
#[derive(Debug, Clone)]
pub struct Client {
    endpoint: Endpoint,
}

impl Client {
    /// A client for a TCP endpoint (`host:port`).
    pub fn tcp(addr: impl Into<String>) -> Client {
        Client { endpoint: Endpoint::Tcp(addr.into()) }
    }

    #[cfg(unix)]
    /// A client for a Unix-domain-socket endpoint.
    pub fn unix(path: impl Into<PathBuf>) -> Client {
        Client { endpoint: Endpoint::Unix(path.into()) }
    }

    /// Send one request, return the parsed reply object.
    pub fn call(&self, req: &Request) -> Result<Json> {
        match &self.endpoint {
            Endpoint::Tcp(addr) => {
                let stream = std::net::TcpStream::connect(addr)
                    .with_context(|| format!("connecting to portatune daemon at {addr}"))?;
                let _ = stream.set_nodelay(true);
                Self::exchange(req, &stream, &stream)
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let stream = std::os::unix::net::UnixStream::connect(path).with_context(|| {
                    format!("connecting to portatune daemon at {}", path.display())
                })?;
                Self::exchange(req, &stream, &stream)
            }
        }
    }

    fn exchange(
        req: &Request,
        mut writer: impl Write,
        reader: impl std::io::Read,
    ) -> Result<Json> {
        writer
            .write_all(req.to_line().as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush())
            .context("sending request")?;
        let mut line = String::new();
        BufReader::new(reader).read_line(&mut line).context("reading reply")?;
        anyhow::ensure!(!line.trim().is_empty(), "daemon closed the connection without a reply");
        let reply = json::parse(line.trim()).context("parsing reply json")?;
        if reply.get("ok").and_then(Json::as_bool) != Some(true) {
            let msg = reply
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("daemon reported failure without a message");
            return Err(anyhow::anyhow!("daemon error: {msg}"));
        }
        Ok(reply)
    }
}
