//! The regression sentinel: live drift detection on the record stream.
//!
//! The TTL scan re-tunes on a clock; the Kernel Tuning Toolkit line of
//! work (Petrovič et al.) argues a production tuner must also re-tune
//! on *evidence* — a served config that got slower on live hardware.
//! Every `record` carries an observed cost; the sentinel compares it
//! against the stored best the fleet had been serving via a windowed
//! EWMA and a threshold test, and confirms a regression only when both
//! the smoothed ratio and the recent-window mean exceed the firing
//! threshold with enough samples.  One noisy measurement can never
//! fire it; a genuine slowdown fires it within a handful of records.
//!
//! All state is integer permille arithmetic (ratios ×1000), so
//! detection is bit-deterministic — the fleet simulation replays a
//! seeded slowdown and gets the same detection tick every run.
//!
//! Confirmation and recovery are *transitions*: [`Sentinel::observe`]
//! reports `Confirmed` exactly once per episode (the caller audits,
//! bumps metrics, and enqueues the evidence-driven retune task) and
//! `Cleared` exactly once when the smoothed ratio falls back under the
//! clear threshold (hysteresis, so a ratio hovering at the threshold
//! cannot flap).

use std::collections::{HashMap, VecDeque};

/// Identity the sentinel watches: (platform, kernel, workload).
pub type SentinelKey = (String, String, String);

/// Detection thresholds.  Defaults fire on a sustained ≥ 1.3× cost
/// ratio after 5 samples and clear below 1.1× — see
/// `docs/OBSERVABILITY.md` ("Tuning economics") for how to tune them.
#[derive(Debug, Clone)]
pub struct SentinelConfig {
    /// Recent samples kept per key for the window-mean test (and the
    /// audit evidence).
    pub window: usize,
    /// Minimum samples in the window before a regression can confirm.
    pub min_samples: usize,
    /// EWMA weight of the newest sample, permille (300 = 0.3).
    pub alpha_pm: u64,
    /// Smoothed AND window-mean ratio (permille) at or above which a
    /// regression confirms (1300 = observed 1.3× the stored best).
    pub fire_pm: u64,
    /// Smoothed ratio (permille) at or below which an active
    /// regression clears.
    pub clear_pm: u64,
}

impl Default for SentinelConfig {
    fn default() -> SentinelConfig {
        SentinelConfig { window: 8, min_samples: 5, alpha_pm: 300, fire_pm: 1300, clear_pm: 1100 }
    }
}

/// A state transition reported by [`Sentinel::observe`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SentinelEvent {
    /// The key crossed into regression — fire the alarm exactly once.
    Confirmed {
        /// Smoothed observed/stored cost ratio, permille.
        ratio_pm: u64,
        /// Samples in the evidence window at confirmation.
        window_n: u64,
        /// Mean ratio over the evidence window, permille.
        window_mean_pm: u64,
        /// Worst (highest) ratio in the evidence window, permille.
        window_max_pm: u64,
    },
    /// The key recovered — smoothed ratio fell under the clear bar.
    Cleared {
        /// Smoothed ratio at recovery, permille.
        ratio_pm: u64,
    },
}

#[derive(Debug, Default)]
struct Window {
    /// Smoothed ratio, permille; 0 = no sample yet.
    ewma_pm: u64,
    /// Last `window` raw ratios, oldest first.
    recent: VecDeque<u64>,
    regressing: bool,
}

/// Per-key windowed-EWMA regression detector.  Lives server-side (and
/// inside the fleet sim); nothing here persists — a restarted daemon
/// re-learns from the live stream within `min_samples` records.
#[derive(Debug, Default)]
pub struct Sentinel {
    cfg: SentinelConfig,
    windows: HashMap<SentinelKey, Window>,
}

impl Sentinel {
    /// A sentinel with the given thresholds.
    pub fn new(cfg: SentinelConfig) -> Sentinel {
        Sentinel { cfg, windows: HashMap::new() }
    }

    /// Feed one observation: the cost a live record reports
    /// (`observed_s`) against the stored best the snapshot had been
    /// serving (`stored_best_s`).  Returns the key's regression state
    /// after the observation plus the transition, if this observation
    /// caused one.
    pub fn observe(
        &mut self,
        platform: &str,
        kernel: &str,
        tag: &str,
        observed_s: f64,
        stored_best_s: f64,
    ) -> (bool, Option<SentinelEvent>) {
        let usable = |v: f64| v.is_finite() && v > 0.0;
        if !usable(observed_s) || !usable(stored_best_s) {
            return (self.is_regressing(platform, kernel, tag), None);
        }
        // Rounded once, then integer math only: bit-deterministic.
        let ratio_pm = ((observed_s / stored_best_s) * 1000.0).round() as u64;
        let key = (platform.to_string(), kernel.to_string(), tag.to_string());
        let w = self.windows.entry(key).or_default();
        w.ewma_pm = if w.ewma_pm == 0 {
            ratio_pm
        } else {
            // alpha·sample + (1−alpha)·ewma, permille weights, rounded.
            (self.cfg.alpha_pm * ratio_pm + (1000 - self.cfg.alpha_pm) * w.ewma_pm + 500) / 1000
        };
        w.recent.push_back(ratio_pm);
        while w.recent.len() > self.cfg.window {
            w.recent.pop_front();
        }
        let n = w.recent.len() as u64;
        let mean_pm = w.recent.iter().sum::<u64>() / n;
        if !w.regressing {
            if w.recent.len() >= self.cfg.min_samples
                && w.ewma_pm >= self.cfg.fire_pm
                && mean_pm >= self.cfg.fire_pm
            {
                w.regressing = true;
                let event = SentinelEvent::Confirmed {
                    ratio_pm: w.ewma_pm,
                    window_n: n,
                    window_mean_pm: mean_pm,
                    window_max_pm: w.recent.iter().copied().max().unwrap_or(ratio_pm),
                };
                return (true, Some(event));
            }
            (false, None)
        } else if w.ewma_pm <= self.cfg.clear_pm {
            w.regressing = false;
            let ratio = w.ewma_pm;
            (false, Some(SentinelEvent::Cleared { ratio_pm: ratio }))
        } else {
            (true, None)
        }
    }

    /// Whether a key is currently flagged.
    pub fn is_regressing(&self, platform: &str, kernel: &str, tag: &str) -> bool {
        self.windows
            .get(&(platform.to_string(), kernel.to_string(), tag.to_string()))
            .map(|w| w.regressing)
            .unwrap_or(false)
    }

    /// Drop a key's history (a retune landed a new best: the old
    /// ratios were measured against a dead baseline).  Returns whether
    /// the key had been flagged.
    pub fn reset(&mut self, platform: &str, kernel: &str, tag: &str) -> bool {
        self.windows
            .remove(&(platform.to_string(), kernel.to_string(), tag.to_string()))
            .map(|w| w.regressing)
            .unwrap_or(false)
    }

    /// Currently flagged keys, sorted (deterministic surfaces: the
    /// `report` op, snapshot rebuilds, the fleet sim).
    pub fn regressing_keys(&self) -> Vec<SentinelKey> {
        let mut keys: Vec<SentinelKey> =
            self.windows.iter().filter(|(_, w)| w.regressing).map(|(k, _)| k.clone()).collect();
        keys.sort();
        keys
    }

    /// How many keys are currently flagged (the
    /// `portatune_regressions_active` gauge).
    pub fn active(&self) -> usize {
        self.windows.values().filter(|w| w.regressing).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sustained_slowdown_confirms_exactly_once() {
        let mut s = Sentinel::new(SentinelConfig::default());
        let mut confirmations = 0;
        let mut first_regressing = None;
        for i in 0..10 {
            let (reg, event) = s.observe("p1", "axpy", "n4096", 2.0e-3, 1.0e-3);
            if let Some(SentinelEvent::Confirmed { ratio_pm, window_n, .. }) = &event {
                confirmations += 1;
                assert!(*ratio_pm >= 1300);
                assert!(*window_n >= 5);
            }
            if reg && first_regressing.is_none() {
                first_regressing = Some(i);
            }
        }
        assert_eq!(confirmations, 1, "confirmation is a transition, not a level");
        assert_eq!(first_regressing, Some(4), "fires at min_samples, not before");
        assert!(s.is_regressing("p1", "axpy", "n4096"));
        assert_eq!(s.active(), 1);
        assert_eq!(s.regressing_keys().len(), 1);
    }

    #[test]
    fn single_spike_never_fires() {
        let mut s = Sentinel::new(SentinelConfig::default());
        // One 5x outlier surrounded by healthy samples.
        for observed in [1.0e-3, 1.05e-3, 5.0e-3, 0.95e-3, 1.0e-3, 1.0e-3, 1.02e-3, 0.99e-3] {
            let (reg, event) = s.observe("p1", "axpy", "n4096", observed, 1.0e-3);
            assert!(!reg, "a lone spike must not confirm");
            assert!(event.is_none());
        }
    }

    #[test]
    fn recovery_clears_with_hysteresis() {
        let mut s = Sentinel::new(SentinelConfig::default());
        for _ in 0..6 {
            s.observe("p1", "axpy", "n4096", 2.0e-3, 1.0e-3);
        }
        assert!(s.is_regressing("p1", "axpy", "n4096"));
        let mut cleared = 0;
        for _ in 0..12 {
            let (_, event) = s.observe("p1", "axpy", "n4096", 1.0e-3, 1.0e-3);
            if matches!(event, Some(SentinelEvent::Cleared { .. })) {
                cleared += 1;
            }
        }
        assert_eq!(cleared, 1, "recovery reported exactly once");
        assert!(!s.is_regressing("p1", "axpy", "n4096"));
        assert_eq!(s.active(), 0);
    }

    #[test]
    fn reset_forgets_the_dead_baseline() {
        let mut s = Sentinel::new(SentinelConfig::default());
        for _ in 0..6 {
            s.observe("p1", "axpy", "n4096", 2.0e-3, 1.0e-3);
        }
        assert!(s.reset("p1", "axpy", "n4096"), "reset reports the flag it dropped");
        assert!(!s.is_regressing("p1", "axpy", "n4096"));
        assert!(!s.reset("p1", "axpy", "n4096"));
    }

    #[test]
    fn keys_are_independent_and_bad_inputs_are_ignored() {
        let mut s = Sentinel::new(SentinelConfig::default());
        for _ in 0..6 {
            s.observe("p1", "axpy", "n4096", 2.0e-3, 1.0e-3);
            s.observe("p2", "axpy", "n4096", 1.0e-3, 1.0e-3);
        }
        assert!(s.is_regressing("p1", "axpy", "n4096"));
        assert!(!s.is_regressing("p2", "axpy", "n4096"));
        // Zero/negative costs carry no signal and must not panic.
        let (reg, event) = s.observe("p3", "axpy", "n4096", 0.0, 1.0e-3);
        assert!(!reg);
        assert!(event.is_none());
        let (_, event) = s.observe("p1", "axpy", "n4096", 1.0e-3, 0.0);
        assert!(event.is_none());
    }
}
