//! The leased task queue behind the distributed worker fleet.
//!
//! Tuned configurations rot: hardware drifts (microcode, cache
//! partitioning, a new machine inheriting an old shard) and entries
//! age past usefulness.  Built portfolios rot the same way — their
//! `built_at` stamp ages under the identical TTL/drift signals, but
//! refreshing one needs a full sweep, not a single re-tune.  The
//! [`TaskQueue`] turns both staleness signals into typed
//! [`TuningTask`]s that a fleet of `portatune work` processes (or the
//! daemon's own in-process re-tune worker) can drain:
//!
//! * [`TaskKind::Retune`] — one (kernel, workload) re-tune through the
//!   batched [`Tuner`] (artifact-backed kernels);
//! * [`TaskKind::Sweep`] — a whole-shape-sweep re-measure of a native
//!   kernel family (stale native entries collapse into one sweep task
//!   per (platform, kernel): the artifact tuner cannot re-measure
//!   them, and a sweep refreshes every shape at once);
//! * [`TaskKind::PortfolioRebuild`] — sweep + portfolio reconstruction
//!   when a shard's stored portfolio outlives the TTL or its platform
//!   fingerprint drifts.  A queued rebuild subsumes the sweep task for
//!   the same (platform, kernel) — rebuilding re-records every sweep
//!   entry anyway.
//!
//! **Lease semantics** make the queue loss-proof: handing a task out
//! ([`TaskQueue::lease`]) moves it to an in-flight table with a TTL
//! and a lease id; [`heartbeat`](TaskQueue::heartbeat) extends the
//! TTL, [`complete`](TaskQueue::complete)/[`fail`](TaskQueue::fail)
//! settle it, and [`expire`](TaskQueue::expire) requeues any lease
//! whose holder went silent — a crashed worker never loses work.  The
//! legacy `retune-next` op is an alias for a default-TTL lease of the
//! next [`TaskKind::Retune`] task, so pre-fleet pollers keep working
//! *and* gain crash-proofing for free.
//!
//! Guarantees the property tests pin down:
//!
//! * an expired lease requeues its task **exactly once**;
//! * a double `complete` is idempotent (the second reports
//!   [`CompleteOutcome::Duplicate`]);
//! * a completed task is never re-leased (only a *later scan* finding
//!   the data still stale can create a new task with the same
//!   identity);
//! * at any instant a task identity is pending, leased, or settled —
//!   never two of those at once, so two workers draining concurrently
//!   cannot execute the same task twice.
//!
//! Two churn bounds keep the queue convergent:
//!
//! * **attempts** — `task-fail`s and lease expiries both count toward
//!   [`MAX_ATTEMPTS`]; a task that keeps failing or keeps losing its
//!   lease (a poison task, or a legacy `retune-next` poller that
//!   never settles) is dropped instead of ping-ponging forever.  The
//!   staleness scan recreates it — with fresh attempts — only if the
//!   data is genuinely still stale, so nothing is ever lost;
//! * **resolution stamps** — completing a task records the data
//!   version (`recorded_at`/`built_at`) it was queued against.  The
//!   scan will not requeue an identity whose completion demonstrably
//!   could not refresh its data (an `--any-platform` worker whose
//!   results land under its own key, not the stale foreign shard's)
//!   until the shard's stamp actually changes.
//!
//! Two staleness signals, checked per frontier entry and per stored
//! portfolio:
//!
//! * **fingerprint drift** — the shard's stored fingerprint no longer
//!   hashes to the shard's own platform key: the machine kept recording
//!   under a pinned/cached key while its hardware changed underneath.
//!   Only keys in [`Fingerprint::key`]'s derived `slug-hex16` shape
//!   whose slug matches the stored fingerprint's CPU-model are eligible
//!   — clients may record under arbitrary wire-supplied names
//!   ("remote-box"), and those can never re-hash to themselves, so
//!   treating them as drifted would re-queue them forever;
//! * **TTL expiry** — `recorded_at` (entries) or `built_at`
//!   (portfolios) is older than the configured TTL.
//!
//! Scans are idempotent: an identity already pending or leased is
//! never queued twice, and settling a task releases its slot so a
//! later scan can re-queue it if it is still stale.
//!
//! [`Tuner`]: crate::coordinator::tuner::Tuner

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

use anyhow::Result;

use crate::coordinator::perfdb::Shard;
use crate::coordinator::platform::Fingerprint;
use crate::obs;
use crate::util::json::{self, Json};
use crate::workload::gemm;

/// Lease TTL applied when a `task-lease` request names none (and the
/// TTL backing the `retune-next` compatibility alias).
pub const DEFAULT_LEASE_TTL_S: u64 = 600;

/// How many times a task may be `task-fail`ed **or lose its lease to
/// expiry** before the queue drops it instead of requeueing (a poison
/// task — or one held by a legacy poller that never settles — must not
/// ping-pong through the fleet forever; the next staleness scan
/// recreates it if the data is still stale).
pub const MAX_ATTEMPTS: u32 = 3;

/// How many settled lease ids the queue remembers for idempotency
/// checks before pruning the oldest.
const SETTLED_KEEP: usize = 4096;

/// What a queued task asks a worker to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TaskKind {
    /// Re-tune one (kernel, workload) through the batched tuner.
    Retune,
    /// Re-measure a native kernel family's whole shape sweep.
    Sweep,
    /// Sweep + rebuild a platform's variant portfolio.
    PortfolioRebuild,
}

impl TaskKind {
    /// Stable wire spelling of the kind.
    pub fn as_str(&self) -> &'static str {
        match self {
            TaskKind::Retune => "retune",
            TaskKind::Sweep => "sweep",
            TaskKind::PortfolioRebuild => "portfolio-rebuild",
        }
    }

    /// Parse the wire spelling.
    pub fn parse(s: &str) -> Option<TaskKind> {
        match s {
            "retune" => Some(TaskKind::Retune),
            "sweep" => Some(TaskKind::Sweep),
            "portfolio-rebuild" => Some(TaskKind::PortfolioRebuild),
            _ => None,
        }
    }
}

/// Why a task was queued.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StaleReason {
    /// Entry (or portfolio) older than the TTL.
    TtlExpired {
        /// Age in seconds at scan time.
        age_s: u64,
    },
    /// The platform under this key no longer matches its stored
    /// fingerprint.
    FingerprintDrift,
    /// The regression sentinel confirmed the served config has gone
    /// slow on live hardware (see [`crate::service::sentinel`]) — an
    /// evidence-driven retune, not a clock-driven one.
    Regression {
        /// Smoothed observed/stored cost ratio (permille) at
        /// confirmation.
        ratio_pm: u64,
    },
}

impl StaleReason {
    /// Stable wire spelling of the reason.
    pub fn as_str(&self) -> &'static str {
        match self {
            StaleReason::TtlExpired { .. } => "ttl-expired",
            StaleReason::FingerprintDrift => "fingerprint-drift",
            StaleReason::Regression { .. } => "regression",
        }
    }
}

/// Dedupe identity of a task: what it would *do*, independent of when
/// it was queued or how often it failed.
pub type TaskIdentity = (TaskKind, String, String, Option<String>);

/// One queued unit of tuning work.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TuningTask {
    /// What to do.
    pub kind: TaskKind,
    /// Platform whose data went stale.
    pub platform_key: String,
    /// Kernel family.
    pub kernel: String,
    /// Workload tag; `None` for kernel-wide kinds (sweep, rebuild).
    pub tag: Option<String>,
    /// Why the task was queued.
    pub reason: StaleReason,
    /// How many times the task has been `task-fail`ed back.
    pub attempts: u32,
}

impl TuningTask {
    /// The dedupe identity (see [`TaskIdentity`]).
    pub fn identity(&self) -> TaskIdentity {
        (self.kind, self.platform_key.clone(), self.kernel.clone(), self.tag.clone())
    }

    /// Wire form for `task-lease` / `retune-next` replies.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("kind", json::s(self.kind.as_str())),
            ("platform", json::s(&self.platform_key)),
            ("kernel", json::s(&self.kernel)),
        ];
        if let Some(tag) = &self.tag {
            fields.push(("workload", json::s(tag)));
        }
        fields.push(("reason", json::s(self.reason.as_str())));
        match &self.reason {
            StaleReason::TtlExpired { age_s } => {
                fields.push(("age_s", json::int(*age_s as i64)));
            }
            StaleReason::Regression { ratio_pm } => {
                fields.push(("ratio_pm", json::int(*ratio_pm as i64)));
            }
            StaleReason::FingerprintDrift => {}
        }
        if self.attempts > 0 {
            fields.push(("attempts", json::int(self.attempts as i64)));
        }
        json::obj(fields)
    }

    /// Parse the [`to_json`](Self::to_json) form (what `portatune
    /// work` receives).  `kind` defaults to retune so pre-fleet
    /// daemons' `retune-next` replies still parse.
    pub fn from_json(v: &Json) -> Result<TuningTask> {
        let gs = |k: &str| -> Result<String> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("task missing {k}"))
        };
        let kind = match v.get("kind").and_then(Json::as_str) {
            None => TaskKind::Retune,
            Some(s) => {
                TaskKind::parse(s).ok_or_else(|| anyhow::anyhow!("unknown task kind {s}"))?
            }
        };
        let reason = match v.get("reason").and_then(Json::as_str) {
            Some("fingerprint-drift") => StaleReason::FingerprintDrift,
            Some("regression") => StaleReason::Regression {
                ratio_pm: v.get("ratio_pm").and_then(Json::as_u64).unwrap_or(0),
            },
            // Unknown reasons (a newer daemon) degrade to ttl-expired:
            // the worker still knows *what* to do, just not why.
            _ => StaleReason::TtlExpired {
                age_s: v.get("age_s").and_then(Json::as_u64).unwrap_or(0),
            },
        };
        Ok(TuningTask {
            kind,
            platform_key: gs("platform")?,
            kernel: gs("kernel")?,
            tag: v.get("workload").and_then(Json::as_str).map(str::to_string),
            reason,
            attempts: v.get("attempts").and_then(Json::as_u64).unwrap_or(0) as u32,
        })
    }
}

/// An in-flight lease: the task, its TTL, and when it expires.
#[derive(Debug, Clone)]
struct Lease {
    task: TuningTask,
    ttl_s: u64,
    expires_at: u64,
}

/// How a settled lease ended (kept for idempotency checks).
#[derive(Debug, Clone)]
enum Settled {
    Completed,
    Failed,
    /// The lease expired and its task was requeued; the identity is
    /// kept so a *late* completion can withdraw the requeued copy.
    Expired(TaskIdentity),
}

/// What `complete` reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompleteOutcome {
    /// The lease was live (or had expired with its task still waiting
    /// unleased — the late completion withdrew it); the task is done.
    Settled,
    /// The lease was already settled — a retried `task-complete`, or a
    /// late completion whose task another worker already picked up.
    /// Idempotent: nothing changed.
    Duplicate,
    /// The lease id was never issued (or pruned long ago).
    Unknown,
}

/// What one `expire_report` pass did, task by task.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExpireReport {
    /// Tasks whose lease lapsed and that went back to pending.
    pub requeued: Vec<TuningTask>,
    /// Tasks abandoned after exhausting [`MAX_ATTEMPTS`].
    pub dropped: Vec<TuningTask>,
}

/// What `fail` reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailOutcome {
    /// The task went back to the pending queue for another worker.
    Requeued,
    /// The task exhausted [`MAX_ATTEMPTS`] and was dropped (the next
    /// scan recreates it if the data is still stale).
    Dropped,
    /// The lease was already settled; nothing changed.
    Duplicate,
    /// The lease id was never issued.
    Unknown,
}

/// FIFO of typed tuning tasks with lease-based checkout.
#[derive(Debug)]
pub struct TaskQueue {
    ttl_s: u64,
    pending: VecDeque<TuningTask>,
    leased: HashMap<u64, Lease>,
    /// Settled lease ids (bounded by `SETTLED_KEEP`).  BTreeMap so
    /// pruning drops the *oldest* ids (ids are monotonic).
    settled: BTreeMap<u64, Settled>,
    /// Identities currently pending or leased (scan dedupe).
    queued: HashSet<TaskIdentity>,
    /// Data version (`recorded_at`/`built_at`) each scan-queued
    /// identity was created against.
    stamps: HashMap<TaskIdentity, u64>,
    /// Identities completed at least once, with the newest data stamp
    /// their execution ran against.  The scan skips an identity whose
    /// shard stamp has not moved past its resolution — the completed
    /// work demonstrably did not (and will not) refresh that data, so
    /// requeueing it would churn forever (see module docs).
    resolved: HashMap<TaskIdentity, u64>,
    /// Drift tasks ever queued.  Unlike TTL tasks — which re-recording
    /// resolves (fresh `recorded_at`/`built_at`) — a drifted shard is a
    /// historical inconsistency no re-tune can repair (the fresh record
    /// lands under the machine's *new* key), so each is delivered at
    /// most once per queue lifetime instead of re-queuing after every
    /// settle forever.
    drift_notified: HashSet<TaskIdentity>,
    /// Wall-clock second each pending identity was (re)queued at, for
    /// the queue-age-at-lease histogram.  Only the paths that carry a
    /// clock (scan, expiry requeue) stamp entries; a bare [`enqueue`]
    /// records no age at lease.
    ///
    /// [`enqueue`]: Self::enqueue
    enqueued_at: HashMap<TaskIdentity, u64>,
    next_lease: u64,
}

impl TaskQueue {
    /// An empty queue with the given staleness TTL.
    pub fn new(ttl_s: u64) -> TaskQueue {
        TaskQueue {
            ttl_s,
            pending: VecDeque::new(),
            leased: HashMap::new(),
            settled: BTreeMap::new(),
            queued: HashSet::new(),
            stamps: HashMap::new(),
            resolved: HashMap::new(),
            drift_notified: HashSet::new(),
            enqueued_at: HashMap::new(),
            next_lease: 0,
        }
    }

    /// The configured staleness TTL in seconds.
    pub fn ttl_s(&self) -> u64 {
        self.ttl_s
    }

    /// Pending (not-yet-leased) task count.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// Whether no tasks are pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Currently-leased task count.
    pub fn leased_len(&self) -> usize {
        self.leased.len()
    }

    /// Pending depth per task kind (the `stats` op's gauge).
    pub fn depth_by_kind(&self) -> BTreeMap<&'static str, u64> {
        let mut depth: BTreeMap<&'static str, u64> = BTreeMap::new();
        for kind in [TaskKind::Retune, TaskKind::Sweep, TaskKind::PortfolioRebuild] {
            depth.insert(kind.as_str(), 0);
        }
        for t in &self.pending {
            *depth.entry(t.kind.as_str()).or_insert(0) += 1;
        }
        depth
    }

    /// Queue a task unless its identity is already pending or leased.
    /// Returns whether it was added.
    pub fn enqueue(&mut self, task: TuningTask) -> bool {
        self.enqueue_at(task, 0)
    }

    /// Like [`enqueue`](Self::enqueue), stamping the enqueue time so a
    /// later [`lease`](Self::lease) can record the task's queue age
    /// (`now == 0` means "no clock available": no age is recorded).
    pub fn enqueue_at(&mut self, task: TuningTask, now: u64) -> bool {
        let identity = task.identity();
        if !self.queued.insert(identity.clone()) {
            return false;
        }
        if matches!(task.reason, StaleReason::FingerprintDrift) {
            self.drift_notified.insert(identity.clone());
        }
        if now > 0 {
            self.enqueued_at.insert(identity, now);
        }
        self.pending.push_back(task);
        true
    }

    /// Scan shards against the daemon host's live fingerprint at time
    /// `now`; queue every newly-stale frontier entry and portfolio.
    /// Returns how many tasks were added.  (`host` reserved for
    /// lineage-aware drift rules; the current rule needs only
    /// shard-internal consistency.)
    pub fn scan(&mut self, shards: &[Shard], host: &Fingerprint, now: u64) -> usize {
        self.scan_report(shards, host, now).len()
    }

    /// Like [`scan`](Self::scan) but returns the tasks actually queued,
    /// so callers can audit each enqueue decision with its reason.
    pub fn scan_report(
        &mut self,
        shards: &[Shard],
        _host: &Fingerprint,
        now: u64,
    ) -> Vec<TuningTask> {
        let mut added = Vec::new();
        for shard in shards {
            let drifted = match &shard.fingerprint {
                // A *derived* key that its own stored fingerprint no
                // longer hashes to: the machine changed while records
                // kept landing under the old key.  Arbitrary
                // wire-supplied keys are exempt (see module docs).
                Some(fp) => {
                    key_derived_from(&shard.platform_key, fp)
                        && fp.key() != shard.platform_key
                }
                None => false,
            };
            // Portfolios first: a queued rebuild subsumes the sweep
            // task the same shard's stale native entries would create.
            for p in &shard.portfolios {
                let identity = (
                    TaskKind::PortfolioRebuild,
                    shard.platform_key.clone(),
                    p.kernel.clone(),
                    None,
                );
                let Some(reason) =
                    self.stale_reason(drifted, &identity, p.built_at, now)
                else {
                    continue;
                };
                let task = TuningTask {
                    kind: TaskKind::PortfolioRebuild,
                    platform_key: shard.platform_key.clone(),
                    kernel: p.kernel.clone(),
                    tag: None,
                    reason,
                    attempts: 0,
                };
                if self.enqueue_scanned(task.clone(), p.built_at, now) {
                    added.push(task);
                }
            }
            for entry in shard.frontier() {
                // Native kernels have no artifact for the tuner to
                // re-measure; their stale shapes collapse into one
                // whole-sweep task per (platform, kernel).
                let (kind, tag) = if entry.kernel == gemm::KERNEL {
                    (TaskKind::Sweep, None)
                } else {
                    (TaskKind::Retune, Some(entry.tag.clone()))
                };
                if kind == TaskKind::Sweep
                    && self.queued.contains(&(
                        TaskKind::PortfolioRebuild,
                        shard.platform_key.clone(),
                        entry.kernel.clone(),
                        None,
                    ))
                {
                    continue; // rebuild re-records the sweep anyway
                }
                let identity =
                    (kind, shard.platform_key.clone(), entry.kernel.clone(), tag.clone());
                let Some(reason) =
                    self.stale_reason(drifted, &identity, entry.recorded_at, now)
                else {
                    continue;
                };
                let task = TuningTask {
                    kind,
                    platform_key: shard.platform_key.clone(),
                    kernel: entry.kernel.clone(),
                    tag,
                    reason,
                    attempts: 0,
                };
                if self.enqueue_scanned(task.clone(), entry.recorded_at, now) {
                    added.push(task);
                }
            }
        }
        added
    }

    /// Drift outranks TTL but is delivered once; an already-notified
    /// drifted identity still gets ordinary TTL staleness checks (its
    /// data keeps aging).  `None` means "not stale" — including the
    /// resolution-stamp case: an identity already completed against a
    /// data version at least this new cannot be refreshed by running
    /// again, so it only requeues once the shard's stamp moves.
    fn stale_reason(
        &self,
        drifted: bool,
        identity: &TaskIdentity,
        stamped_at: u64,
        now: u64,
    ) -> Option<StaleReason> {
        if self.resolved.get(identity).is_some_and(|&s| s >= stamped_at) {
            return None;
        }
        if drifted && !self.drift_notified.contains(identity) {
            return Some(StaleReason::FingerprintDrift);
        }
        let age_s = now.saturating_sub(stamped_at);
        if age_s <= self.ttl_s {
            return None;
        }
        Some(StaleReason::TtlExpired { age_s })
    }

    /// Scan-side enqueue: records the data stamp the task targets (so
    /// completion can mark the identity resolved at that version) and
    /// clears any prior resolution — the check in `stale_reason` only
    /// lets a stamped identity through once its data moved, at which
    /// point it is fair game again.  A dedupe-rejected enqueue still
    /// merges the stamp upward (a kernel-wide sweep task covers shapes
    /// with heterogeneous `recorded_at`s).
    fn enqueue_scanned(&mut self, task: TuningTask, stamped_at: u64, now: u64) -> bool {
        let identity = task.identity();
        self.resolved.remove(&identity);
        let stamp = self.stamps.entry(identity).or_insert(0);
        *stamp = (*stamp).max(stamped_at);
        self.enqueue_at(task, now)
    }

    /// Check out the first pending task matching the filters under a
    /// lease of `ttl_s` seconds.  Returns the lease id and a copy of
    /// the task.  `platform` lets a worker take only tasks it can
    /// actually measure (its own hardware); `kind` lets the legacy
    /// `retune-next` alias and single-purpose workers skip kinds they
    /// cannot execute.
    pub fn lease(
        &mut self,
        kind: Option<TaskKind>,
        platform: Option<&str>,
        ttl_s: u64,
        now: u64,
    ) -> Option<(u64, TuningTask)> {
        let idx = self.pending.iter().position(|t| {
            kind.map_or(true, |k| t.kind == k)
                && platform.map_or(true, |p| t.platform_key == p)
        })?;
        let task = self.pending.remove(idx)?;
        if let Some(at) = self.enqueued_at.remove(&task.identity()) {
            obs::metrics().queue_age_at_lease_s.record(now.saturating_sub(at));
        }
        self.next_lease += 1;
        let id = self.next_lease;
        let ttl_s = ttl_s.max(1);
        // Saturating: `ttl_s` arrives from the wire, and an absurd
        // value must neither overflow-panic nor wrap into a lease that
        // is born expired (which would hand the task to a second
        // worker while the first still runs it).
        let expires_at = now.saturating_add(ttl_s);
        self.leased.insert(id, Lease { task: task.clone(), ttl_s, expires_at });
        Some((id, task))
    }

    /// Extend a live lease by its original TTL.  Returns the TTL when
    /// the lease is live, `None` when it is unknown or already settled
    /// (the worker has lost it and must stop).
    pub fn heartbeat(&mut self, lease_id: u64, now: u64) -> Option<u64> {
        let lease = self.leased.get_mut(&lease_id)?;
        lease.expires_at = now.saturating_add(lease.ttl_s);
        Some(lease.ttl_s)
    }

    /// Requeue every lease whose TTL ran out.  Each expired lease
    /// requeues its task exactly once: the lease moves to the settled
    /// table, so a second `expire` (or a straggling heartbeat) cannot
    /// duplicate it.  A lease loss counts toward [`MAX_ATTEMPTS`] —
    /// otherwise a task held by a crash-looping worker (or a legacy
    /// `retune-next` poller that never settles) would requeue and
    /// re-execute forever; once exhausted the task drops and only a
    /// scan that still finds the data stale recreates it.  Returns how
    /// many leases expired.
    pub fn expire(&mut self, now: u64) -> usize {
        let report = self.expire_report(now);
        report.requeued.len() + report.dropped.len()
    }

    /// Like [`expire`](Self::expire), but returns the affected tasks
    /// themselves, split by outcome — the audit log records a
    /// `task-requeued` or `task-dropped` entry per task, not a bare
    /// count.
    pub fn expire_report(&mut self, now: u64) -> ExpireReport {
        let expired: Vec<u64> = self
            .leased
            .iter()
            .filter(|(_, l)| now >= l.expires_at)
            .map(|(&id, _)| id)
            .collect();
        let mut report = ExpireReport::default();
        for id in expired {
            if let Some(lease) = self.leased.remove(&id) {
                let mut task = lease.task;
                self.settle(id, Settled::Expired(task.identity()));
                task.attempts += 1;
                if task.attempts >= MAX_ATTEMPTS {
                    let identity = task.identity();
                    self.queued.remove(&identity);
                    self.stamps.remove(&identity);
                    report.dropped.push(task);
                } else {
                    // Identity stays in `queued`: the task is still
                    // live, just back in pending.  Queue age restarts
                    // at the requeue, not the original enqueue.
                    self.enqueued_at.insert(task.identity(), now);
                    self.pending.push_back(task.clone());
                    report.requeued.push(task);
                }
            }
        }
        report
    }

    /// Settle a lease as done.  Idempotent: see [`CompleteOutcome`].
    pub fn complete(&mut self, lease_id: u64) -> CompleteOutcome {
        crate::service::faults::stall(crate::service::faults::InjectionPoint::LeaseSettleDelay);
        if let Some(lease) = self.leased.remove(&lease_id) {
            self.resolve(lease.task.identity());
            self.settle(lease_id, Settled::Completed);
            return CompleteOutcome::Settled;
        }
        match self.settled.get(&lease_id).cloned() {
            Some(Settled::Completed) | Some(Settled::Failed) => CompleteOutcome::Duplicate,
            Some(Settled::Expired(identity)) => {
                // The worker finished after its lease expired.  If the
                // requeued copy is still waiting, withdraw it — the
                // work is done; if another worker already leased it,
                // that execution will settle on its own.
                if let Some(idx) =
                    self.pending.iter().position(|t| t.identity() == identity)
                {
                    self.pending.remove(idx);
                    self.enqueued_at.remove(&identity);
                    self.resolve(identity);
                    self.settle(lease_id, Settled::Completed);
                    CompleteOutcome::Settled
                } else {
                    CompleteOutcome::Duplicate
                }
            }
            None => CompleteOutcome::Unknown,
        }
    }

    /// Release a completed identity and record which data version its
    /// execution ran against, so the scan stops requeueing work that
    /// demonstrably cannot refresh its shard (see module docs).
    fn resolve(&mut self, identity: TaskIdentity) {
        self.queued.remove(&identity);
        if let Some(stamp) = self.stamps.remove(&identity) {
            self.resolved.insert(identity, stamp);
        }
    }

    /// Settle a lease as failed; the task requeues until it exhausts
    /// [`MAX_ATTEMPTS`] (shared with expiry losses).
    pub fn fail(&mut self, lease_id: u64) -> FailOutcome {
        crate::service::faults::stall(crate::service::faults::InjectionPoint::LeaseSettleDelay);
        if let Some(mut lease) = self.leased.remove(&lease_id) {
            self.settle(lease_id, Settled::Failed);
            lease.task.attempts += 1;
            if lease.task.attempts >= MAX_ATTEMPTS {
                let identity = lease.task.identity();
                self.queued.remove(&identity);
                self.stamps.remove(&identity);
                return FailOutcome::Dropped;
            }
            self.pending.push_back(lease.task);
            return FailOutcome::Requeued;
        }
        match self.settled.get(&lease_id) {
            Some(_) => FailOutcome::Duplicate,
            None => FailOutcome::Unknown,
        }
    }

    /// Settle a lease without judging the task: the holder chose not
    /// to execute it now (the daemon's local cooldown path).  The
    /// identity is released with no resolution recorded and no attempt
    /// charged, so a later scan requeues it as soon as it is due.
    pub fn defer(&mut self, lease_id: u64) -> bool {
        if let Some(lease) = self.leased.remove(&lease_id) {
            let identity = lease.task.identity();
            self.queued.remove(&identity);
            self.stamps.remove(&identity);
            self.settle(lease_id, Settled::Failed);
            true
        } else {
            false
        }
    }

    fn settle(&mut self, lease_id: u64, how: Settled) {
        self.settled.insert(lease_id, how);
        while self.settled.len() > SETTLED_KEEP {
            let oldest = *self.settled.keys().next().expect("settled non-empty");
            self.settled.remove(&oldest);
        }
    }
}

/// Whether a platform key has [`Fingerprint::key`]'s derived shape
/// (`<slug>-<16 lowercase hex>`); only such keys can meaningfully be
/// checked for drift by re-hashing their stored fingerprint.
fn is_derived_key(key: &str) -> bool {
    let bytes = key.as_bytes();
    bytes.len() > 17
        && bytes[bytes.len() - 17] == b'-'
        && bytes[bytes.len() - 16..]
            .iter()
            .all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(b))
}

/// Whether `key` plausibly *was derived from* `fp`: derived shape AND
/// the slug prefix matches the fingerprint's sanitized CPU model.  A
/// wire-supplied name that merely looks hash-shaped (e.g.
/// `gpu-node-00a1b2c3d4e5f601`) fails the model-prefix check, so it is
/// never flagged as drifted.  (Byte comparison — `key` is an arbitrary
/// wire string, so no char-boundary slicing.)
fn key_derived_from(key: &str, fp: &Fingerprint) -> bool {
    if !is_derived_key(key) {
        return false;
    }
    let slug = crate::coordinator::platform::sanitize(&fp.cpu_model);
    key.as_bytes()[..key.len() - 17] == *slug.as_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ledger::Ledger;
    use crate::coordinator::perfdb::DbEntry;
    use crate::coordinator::portfolio::{Portfolio, PortfolioItem, FEATURE_NAMES};

    fn fp(l2: u64) -> Fingerprint {
        Fingerprint {
            cpu_model: "Test CPU".into(),
            num_cpus: 8,
            simd: vec!["avx2".into()],
            cache_l1d_kb: 32,
            cache_l2_kb: l2,
            cache_l3_kb: 8192,
            os: "linux".into(),
        }
    }

    fn entry(platform: &str, kernel: &str, tag: &str, recorded_at: u64) -> DbEntry {
        DbEntry {
            platform_key: platform.into(),
            kernel: kernel.into(),
            tag: tag.into(),
            best_params: Default::default(),
            best_config_id: "cfg".into(),
            best_time_s: 1e-3,
            baseline_time_s: 2e-3,
            reference_time_s: 9e-4,
            evaluations: 9,
            strategy: "exhaustive".into(),
            recorded_at,
        }
    }

    fn portfolio(kernel: &str, built_at: u64) -> Portfolio {
        Portfolio {
            kernel: kernel.into(),
            strategy: "greedy-cover".into(),
            k_max: 4,
            retained: 0.95,
            built_at,
            feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
            items: vec![PortfolioItem {
                config: [("tile_m".to_string(), 32i64)].into_iter().collect(),
                config_id: "o1_tm32".into(),
                centroid: vec![5.0; FEATURE_NAMES.len()],
                covered: vec!["m32n32k32".into()],
            }],
        }
    }

    fn retune_task(platform: &str, kernel: &str, tag: &str) -> TuningTask {
        TuningTask {
            kind: TaskKind::Retune,
            platform_key: platform.into(),
            kernel: kernel.into(),
            tag: Some(tag.into()),
            reason: StaleReason::TtlExpired { age_s: 9000 },
            attempts: 0,
        }
    }

    #[test]
    fn queues_ttl_expired_only_once() {
        let host = fp(1024);
        let key = host.key();
        let shard = Shard {
            platform_key: key.clone(),
            fingerprint: Some(host.clone()),
            entries: vec![entry(&key, "axpy", "n4096", 1000)],
            portfolios: Vec::new(),
            ledger: Ledger::default(),
        };
        let mut q = TaskQueue::new(3600);
        // Within TTL: nothing queued.
        assert_eq!(q.scan(std::slice::from_ref(&shard), &host, 2000), 0);
        // Past TTL: queued exactly once across repeated scans.
        assert_eq!(q.scan(std::slice::from_ref(&shard), &host, 10_000), 1);
        assert_eq!(q.scan(std::slice::from_ref(&shard), &host, 10_000), 0);
        let (id, task) = q.lease(None, None, 60, 10_000).unwrap();
        assert_eq!(task.kernel, "axpy");
        assert_eq!(task.kind, TaskKind::Retune);
        assert_eq!(task.reason, StaleReason::TtlExpired { age_s: 9_000 });
        // Leased, not settled: scans still see the identity as taken.
        assert_eq!(q.scan(std::slice::from_ref(&shard), &host, 10_000), 0);
        assert_eq!(q.complete(id), CompleteOutcome::Settled);
        // Completed against this exact data version: the completion
        // demonstrably did not refresh the shard (stamp unchanged), so
        // re-running it cannot help — the scan must NOT churn it.
        assert_eq!(q.scan(std::slice::from_ref(&shard), &host, 10_000), 0);
        // A fresh record lands (stamp moves) and later goes stale
        // again: the identity is fair game once more.
        let renewed = Shard {
            entries: vec![entry(&key, "axpy", "n4096", 2000)],
            ..shard
        };
        assert_eq!(q.scan(&[renewed], &host, 10_000), 1);
    }

    #[test]
    fn repeated_lease_losses_drop_the_task_until_rescanned() {
        // A legacy retune-next poller (or a crash-looping worker)
        // never settles its lease; expiry must charge attempts so the
        // task cannot re-execute forever.
        let mut q = TaskQueue::new(3600);
        assert!(q.enqueue(retune_task("p1", "axpy", "n4096")));
        let mut now = 0;
        for _ in 0..MAX_ATTEMPTS - 1 {
            let (_, _) = q.lease(None, None, 10, now).unwrap();
            now += 10;
            assert_eq!(q.expire(now), 1);
            assert_eq!(q.len(), 1, "still under the attempt bound: requeued");
        }
        let (_, task) = q.lease(None, None, 10, now).unwrap();
        assert_eq!(task.attempts, MAX_ATTEMPTS - 1);
        now += 10;
        assert_eq!(q.expire(now), 1, "the lease itself still expires");
        assert!(q.is_empty(), "attempts exhausted: dropped, not requeued");
        // Nothing is lost: the identity slot is free, so the next scan
        // (or enqueue) recreates it with fresh attempts.
        assert!(q.enqueue(retune_task("p1", "axpy", "n4096")));
    }

    #[test]
    fn stamped_enqueue_records_queue_age_at_lease() {
        // The registry is process-global, so assert on deltas: other
        // tests recording concurrently only ever push the count up.
        let before = obs::metrics().queue_age_at_lease_s.count();
        let mut q = TaskQueue::new(3600);
        assert!(q.enqueue_at(retune_task("p1", "axpy", "n4096"), 100));
        let (id, _) = q.lease(None, None, 60, 160).unwrap();
        assert!(
            obs::metrics().queue_age_at_lease_s.count() > before,
            "a stamped enqueue must record its age when leased"
        );
        assert_eq!(q.complete(id), CompleteOutcome::Settled);
        // An unstamped enqueue records nothing.
        let before = obs::metrics().queue_age_at_lease_s.snapshot();
        assert!(q.enqueue(retune_task("p2", "dot", "n1024")));
        let _ = q.lease(None, None, 60, 500).unwrap();
        let after = obs::metrics().queue_age_at_lease_s.snapshot();
        // Only other tests' concurrent recordings may differ; this
        // lease contributed no bin increment of its own, which we can
        // at least smoke-check via the exact-age bucket for 400s.
        let bin = obs::Histogram::bucket_index(400);
        assert!(after[bin] >= before[bin], "snapshot is monotone");
    }

    #[test]
    fn huge_wire_ttls_saturate_instead_of_wrapping() {
        let mut q = TaskQueue::new(3600);
        assert!(q.enqueue(retune_task("p1", "axpy", "n4096")));
        // A hostile/buggy client asks for a lease of ~u64::MAX secs:
        // must not overflow into a lease that is born expired (which
        // would hand the task to a second worker immediately).
        let (id, _) = q.lease(None, None, u64::MAX, 1_000_000).unwrap();
        assert_eq!(q.expire(u64::MAX - 1), 0, "saturated lease never expires early");
        assert_eq!(q.heartbeat(id, u64::MAX - 1), Some(u64::MAX));
        assert_eq!(q.complete(id), CompleteOutcome::Settled);
    }

    #[test]
    fn defer_releases_without_resolving_or_charging_attempts() {
        let host = fp(1024);
        let key = host.key();
        let shard = Shard {
            platform_key: key.clone(),
            fingerprint: Some(host.clone()),
            entries: vec![entry(&key, "axpy", "n4096", 1000)],
            portfolios: Vec::new(),
            ledger: Ledger::default(),
        };
        let mut q = TaskQueue::new(3600);
        assert_eq!(q.scan(std::slice::from_ref(&shard), &host, 10_000), 1);
        let (id, _) = q.lease(None, None, 60, 10_000).unwrap();
        assert!(q.defer(id));
        assert!(!q.defer(id), "double defer is a no-op");
        // Unlike complete, a deferred identity requeues on the very
        // next scan (same stamp): the work was skipped, not resolved.
        assert_eq!(q.scan(std::slice::from_ref(&shard), &host, 10_000), 1);
        let (_, task) = q.lease(None, None, 60, 10_000).unwrap();
        assert_eq!(task.attempts, 0, "defer charges no attempt");
    }

    #[test]
    fn stale_portfolio_queues_rebuild_and_subsumes_sweep() {
        let host = fp(1024);
        let key = host.key();
        let shard = Shard {
            platform_key: key.clone(),
            fingerprint: Some(host.clone()),
            // A stale native-gemm entry AND a stale gemm portfolio:
            // only the rebuild task queues (it re-records the sweep).
            entries: vec![entry(&key, gemm::KERNEL, "m32n32k32", 1000)],
            portfolios: vec![portfolio(gemm::KERNEL, 1000)],
            ledger: Ledger::default(),
        };
        let mut q = TaskQueue::new(3600);
        assert_eq!(q.scan(std::slice::from_ref(&shard), &host, 10_000), 1);
        let (_, task) = q.lease(None, None, 60, 10_000).unwrap();
        assert_eq!(task.kind, TaskKind::PortfolioRebuild);
        assert_eq!(task.kernel, gemm::KERNEL);
        assert_eq!(task.tag, None);
    }

    #[test]
    fn stale_native_entries_collapse_into_one_sweep_task() {
        let host = fp(1024);
        let key = host.key();
        let shard = Shard {
            platform_key: key.clone(),
            fingerprint: Some(host.clone()),
            entries: vec![
                entry(&key, gemm::KERNEL, "m32n32k32", 1000),
                entry(&key, gemm::KERNEL, "m64n64k64", 1000),
                entry(&key, "axpy", "n4096", 1000),
            ],
            portfolios: Vec::new(),
            ledger: Ledger::default(),
        };
        let mut q = TaskQueue::new(3600);
        // Two stale gemm shapes -> ONE sweep task; axpy -> one retune.
        assert_eq!(q.scan(&[shard], &host, 10_000), 2);
        let depth = q.depth_by_kind();
        assert_eq!(depth["sweep"], 1);
        assert_eq!(depth["retune"], 1);
        assert_eq!(depth["portfolio-rebuild"], 0);
    }

    #[test]
    fn queues_drifted_fingerprint_regardless_of_age() {
        let host = fp(1024);
        let drifted_fp = fp(512); // hardware changed; key() differs
        let shard = Shard {
            // Shard still filed under the *old* key.
            platform_key: fp(1024).key(),
            fingerprint: Some(drifted_fp),
            entries: vec![entry("x", "axpy", "n4096", u64::MAX / 2)],
            portfolios: vec![portfolio("gemm", u64::MAX / 2)],
            ledger: Ledger::default(),
        };
        let mut q = TaskQueue::new(u64::MAX);
        assert_eq!(q.scan(std::slice::from_ref(&shard), &host, u64::MAX / 2), 2);
        let (id, task) = q.lease(Some(TaskKind::Retune), None, 60, 0).unwrap();
        assert_eq!(task.reason, StaleReason::FingerprintDrift);
        assert_eq!(q.complete(id), CompleteOutcome::Settled);
        let (id, task) = q.lease(Some(TaskKind::PortfolioRebuild), None, 60, 0).unwrap();
        assert_eq!(task.reason, StaleReason::FingerprintDrift);
        assert_eq!(q.complete(id), CompleteOutcome::Settled);
        // Drift is unfixable by re-tuning (fresh records land under the
        // new key), so it is delivered once — not re-queued every scan.
        assert_eq!(q.scan(&[shard], &host, u64::MAX / 2), 0);
        assert!(q.is_empty());
    }

    #[test]
    fn wire_supplied_keys_are_never_drift_flagged() {
        // A client recorded under an arbitrary name with its fingerprint
        // attached: the name can never re-hash to itself, but that is
        // not drift — flagging it would re-queue the entry forever.
        let host = fp(1024);
        let shard = Shard {
            platform_key: "remote-box".into(),
            fingerprint: Some(fp(512)),
            entries: vec![entry("remote-box", "axpy", "n4096", 5000)],
            portfolios: Vec::new(),
            ledger: Ledger::default(),
        };
        let mut q = TaskQueue::new(u64::MAX);
        assert_eq!(q.scan(&[shard], &host, 6000), 0);
        assert!(!is_derived_key("remote-box"));
        assert!(is_derived_key(&host.key()));
        assert!(!is_derived_key("ends-with-UPPER-0123456789ABCDEF"));
        // Hash-shaped wire names still fail the model-prefix check.
        assert!(is_derived_key("gpu-node-00a1b2c3d4e5f601"));
        assert!(!key_derived_from("gpu-node-00a1b2c3d4e5f601", &fp(512)));
        assert!(key_derived_from(&host.key(), &host));
    }

    #[test]
    fn fresh_matching_shards_queue_nothing() {
        let host = fp(1024);
        let key = host.key();
        let shard = Shard {
            platform_key: key.clone(),
            fingerprint: Some(host.clone()),
            entries: vec![entry(&key, "axpy", "n4096", 5000)],
            portfolios: vec![portfolio("gemm", 5000)],
            ledger: Ledger::default(),
        };
        let mut q = TaskQueue::new(3600);
        assert_eq!(q.scan(&[shard], &host, 5100), 0);
        assert!(q.is_empty());
    }

    #[test]
    fn lease_filters_by_platform_and_kind() {
        let mut q = TaskQueue::new(3600);
        assert!(q.enqueue(retune_task("other-box", "axpy", "n4096")));
        assert!(q.enqueue(retune_task("my-box", "dot", "n4096")));
        // A platform-filtered lease skips foreign tasks...
        let (id, task) = q.lease(None, Some("my-box"), 60, 0).unwrap();
        assert_eq!(task.kernel, "dot");
        assert!(q.lease(None, Some("my-box"), 60, 0).is_none());
        assert_eq!(q.complete(id), CompleteOutcome::Settled);
        // ...and the foreign task stays pending for the fleet.
        assert_eq!(q.len(), 1);
        assert!(q.lease(Some(TaskKind::Sweep), None, 60, 0).is_none());
        let (_, task) = q.lease(Some(TaskKind::Retune), None, 60, 0).unwrap();
        assert_eq!(task.platform_key, "other-box");
    }

    #[test]
    fn expired_lease_requeues_exactly_once() {
        let mut q = TaskQueue::new(3600);
        assert!(q.enqueue(retune_task("p1", "axpy", "n4096")));
        let (id, _) = q.lease(None, None, 10, 100).unwrap();
        assert_eq!(q.len(), 0);
        // Not yet expired.
        assert_eq!(q.expire(105), 0);
        // Expired: requeued once; repeated expiry sweeps add nothing.
        assert_eq!(q.expire(110), 1);
        assert_eq!(q.expire(110), 0);
        assert_eq!(q.expire(10_000), 0);
        assert_eq!(q.len(), 1);
        // The dead lease is gone: heartbeats on it fail.
        assert!(q.heartbeat(id, 111).is_none());
        // The requeued task leases again under a NEW id.
        let (id2, task) = q.lease(None, None, 10, 120).unwrap();
        assert_ne!(id, id2);
        assert_eq!(task.kernel, "axpy");
    }

    #[test]
    fn heartbeat_extends_the_lease() {
        let mut q = TaskQueue::new(3600);
        assert!(q.enqueue(retune_task("p1", "axpy", "n4096")));
        let (id, _) = q.lease(None, None, 10, 100).unwrap();
        assert_eq!(q.heartbeat(id, 108), Some(10));
        // Would have expired at 110 without the heartbeat; now 118.
        assert_eq!(q.expire(112), 0);
        assert_eq!(q.expire(118), 1);
    }

    #[test]
    fn double_complete_is_idempotent() {
        let mut q = TaskQueue::new(3600);
        assert!(q.enqueue(retune_task("p1", "axpy", "n4096")));
        let (id, _) = q.lease(None, None, 60, 0).unwrap();
        assert_eq!(q.complete(id), CompleteOutcome::Settled);
        assert_eq!(q.complete(id), CompleteOutcome::Duplicate);
        assert_eq!(q.complete(id), CompleteOutcome::Duplicate);
        assert_eq!(q.complete(999), CompleteOutcome::Unknown);
        assert!(q.is_empty());
    }

    #[test]
    fn completed_task_is_never_re_leased() {
        let mut q = TaskQueue::new(3600);
        assert!(q.enqueue(retune_task("p1", "axpy", "n4096")));
        let (id, _) = q.lease(None, None, 10, 100).unwrap();
        assert_eq!(q.complete(id), CompleteOutcome::Settled);
        // Even an expiry sweep far in the future cannot resurrect it.
        assert_eq!(q.expire(10_000), 0);
        assert!(q.lease(None, None, 10, 10_000).is_none());
    }

    #[test]
    fn late_complete_after_expiry_withdraws_the_requeued_copy() {
        let mut q = TaskQueue::new(3600);
        assert!(q.enqueue(retune_task("p1", "axpy", "n4096")));
        let (id, _) = q.lease(None, None, 10, 100).unwrap();
        assert_eq!(q.expire(110), 1);
        assert_eq!(q.len(), 1);
        // The worker was slow, not dead: its completion withdraws the
        // requeued copy so nobody re-executes finished work.
        assert_eq!(q.complete(id), CompleteOutcome::Settled);
        assert_eq!(q.len(), 0);
        assert!(q.lease(None, None, 10, 120).is_none());
        // But if another worker had already re-leased it, the late
        // completion is a duplicate and the new lease runs its course.
        assert!(q.enqueue(retune_task("p2", "dot", "n4096")));
        let (id_a, _) = q.lease(None, None, 10, 200).unwrap();
        assert_eq!(q.expire(210), 1);
        let (id_b, _) = q.lease(None, None, 10, 211).unwrap();
        assert_eq!(q.complete(id_a), CompleteOutcome::Duplicate);
        assert_eq!(q.complete(id_b), CompleteOutcome::Settled);
    }

    #[test]
    fn failed_tasks_requeue_until_attempts_exhaust() {
        let mut q = TaskQueue::new(3600);
        assert!(q.enqueue(retune_task("p1", "axpy", "n4096")));
        for attempt in 1..MAX_ATTEMPTS {
            let (id, task) = q.lease(None, None, 60, 0).unwrap();
            assert_eq!(task.attempts, attempt - 1);
            assert_eq!(q.fail(id), FailOutcome::Requeued);
        }
        let (id, task) = q.lease(None, None, 60, 0).unwrap();
        assert_eq!(task.attempts, MAX_ATTEMPTS - 1);
        assert_eq!(q.fail(id), FailOutcome::Dropped);
        assert!(q.is_empty());
        assert_eq!(q.fail(id), FailOutcome::Duplicate);
        assert_eq!(q.fail(777), FailOutcome::Unknown);
        // The identity slot is released: a later scan can requeue it.
        assert!(q.enqueue(retune_task("p1", "axpy", "n4096")));
    }

    #[test]
    fn task_json_round_trips() {
        let task = TuningTask {
            kind: TaskKind::PortfolioRebuild,
            platform_key: "p1".into(),
            kernel: "gemm".into(),
            tag: None,
            reason: StaleReason::TtlExpired { age_s: 9000 },
            attempts: 1,
        };
        let j = task.to_json();
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("portfolio-rebuild"));
        assert_eq!(j.get("reason").and_then(Json::as_str), Some("ttl-expired"));
        assert_eq!(j.get("age_s").and_then(Json::as_u64), Some(9000));
        assert!(j.get("workload").is_none());
        assert_eq!(TuningTask::from_json(&j).unwrap(), task);

        let retune = retune_task("p1", "axpy", "n4096");
        let j = retune.to_json();
        assert_eq!(j.get("workload").and_then(Json::as_str), Some("n4096"));
        assert_eq!(TuningTask::from_json(&j).unwrap(), retune);

        // Pre-fleet replies (no kind) default to retune.
        let legacy = json::obj(vec![
            ("platform", json::s("p1")),
            ("kernel", json::s("axpy")),
            ("workload", json::s("n4096")),
            ("reason", json::s("fingerprint-drift")),
        ]);
        let parsed = TuningTask::from_json(&legacy).unwrap();
        assert_eq!(parsed.kind, TaskKind::Retune);
        assert_eq!(parsed.reason, StaleReason::FingerprintDrift);
    }
}
