//! Staleness-aware re-tune queue.
//!
//! Tuned configurations rot: hardware drifts (microcode, cache
//! partitioning, a new machine inheriting an old shard) and entries
//! age past usefulness.  The scheduler scans the shard store and queues
//! re-tune tasks for (platform, kernel, workload) frontiers that are
//! stale, so the daemon (or an operator popping `retune-next`) can push
//! them back through the existing batched [`Tuner`].
//!
//! Two staleness signals, checked per frontier entry:
//!
//! * **fingerprint drift** — the shard's stored fingerprint no longer
//!   hashes to the shard's own platform key: the machine kept recording
//!   under a pinned/cached key while its hardware changed underneath.
//!   Only keys in [`Fingerprint::key`]'s derived `slug-hex16` shape
//!   whose slug matches the stored fingerprint's CPU-model are eligible
//!   — clients may record under arbitrary wire-supplied names
//!   ("remote-box"), and those can never re-hash to themselves, so
//!   treating them as drifted would re-queue them forever.  Known
//!   limitation: a hardware change that replaces the CPU *model* (the
//!   slug no longer matches either way) is undecidable from shard
//!   contents alone and is left to TTL expiry;
//! * **TTL expiry** — `recorded_at` is older than the configured TTL.
//!
//! Scans are idempotent: a (platform, kernel, workload) already queued
//! is never queued twice, and popping a task releases its slot so a
//! later scan can re-queue it if it is still stale.
//!
//! Known limitation: the scan covers *entries* only.  A shard's built
//! portfolios (`Shard::portfolios`) age too — their `built_at` and
//! centroid features go stale under the same TTL/drift signals — but
//! rebuilding one requires a full sweep, not a single re-tune, so
//! portfolio refresh is left to `portatune portfolio build` until the
//! scheduler grows a rebuild task kind (see ROADMAP open items).
//!
//! [`Tuner`]: crate::coordinator::tuner::Tuner

use std::collections::{HashSet, VecDeque};

use crate::coordinator::perfdb::Shard;
use crate::coordinator::platform::Fingerprint;
use crate::util::json::{self, Json};

/// Why a task was queued.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StaleReason {
    /// Entry older than the TTL.
    TtlExpired {
        /// Age in seconds at scan time.
        age_s: u64,
    },
    /// The platform under this key no longer matches its stored
    /// fingerprint.
    FingerprintDrift,
}

impl StaleReason {
    /// Stable wire spelling of the reason.
    pub fn as_str(&self) -> &'static str {
        match self {
            StaleReason::TtlExpired { .. } => "ttl-expired",
            StaleReason::FingerprintDrift => "fingerprint-drift",
        }
    }
}

/// One queued re-tune unit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetuneTask {
    /// Platform whose entry went stale.
    pub platform_key: String,
    /// Kernel family to re-tune.
    pub kernel: String,
    /// Workload tag to re-tune.
    pub tag: String,
    /// Why the task was queued.
    pub reason: StaleReason,
}

impl RetuneTask {
    /// Wire form for the `retune-next` reply.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("platform", json::s(&self.platform_key)),
            ("kernel", json::s(&self.kernel)),
            ("workload", json::s(&self.tag)),
            ("reason", json::s(self.reason.as_str())),
        ])
    }
}

/// Whether a platform key has [`Fingerprint::key`]'s derived shape
/// (`<slug>-<16 lowercase hex>`); only such keys can meaningfully be
/// checked for drift by re-hashing their stored fingerprint.
fn is_derived_key(key: &str) -> bool {
    let bytes = key.as_bytes();
    bytes.len() > 17
        && bytes[bytes.len() - 17] == b'-'
        && bytes[bytes.len() - 16..]
            .iter()
            .all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(b))
}

/// Whether `key` plausibly *was derived from* `fp`: derived shape AND
/// the slug prefix matches the fingerprint's sanitized CPU model.  A
/// wire-supplied name that merely looks hash-shaped (e.g.
/// `gpu-node-00a1b2c3d4e5f601`) fails the model-prefix check, so it is
/// never flagged as drifted.  (Byte comparison — `key` is an arbitrary
/// wire string, so no char-boundary slicing.)
fn key_derived_from(key: &str, fp: &Fingerprint) -> bool {
    if !is_derived_key(key) {
        return false;
    }
    let slug = crate::coordinator::platform::sanitize(&fp.cpu_model);
    key.as_bytes()[..key.len() - 17] == *slug.as_bytes()
}

/// FIFO of stale frontiers with membership dedupe.
#[derive(Debug)]
pub struct Scheduler {
    ttl_s: u64,
    queue: VecDeque<RetuneTask>,
    queued: HashSet<(String, String, String)>,
    /// Drift tasks ever queued.  Unlike TTL tasks — which re-recording
    /// resolves (fresh `recorded_at`) — a drifted shard is a historical
    /// inconsistency no re-tune can repair (the fresh record lands
    /// under the machine's *new* key), so each is delivered at most
    /// once per scheduler lifetime instead of re-queuing after every
    /// pop forever.
    drift_notified: HashSet<(String, String, String)>,
}

impl Scheduler {
    /// An empty queue with the given TTL.
    pub fn new(ttl_s: u64) -> Scheduler {
        Scheduler {
            ttl_s,
            queue: VecDeque::new(),
            queued: HashSet::new(),
            drift_notified: HashSet::new(),
        }
    }

    /// The configured staleness TTL in seconds.
    pub fn ttl_s(&self) -> u64 {
        self.ttl_s
    }

    /// Queued task count.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Scan shards against the daemon host's live fingerprint at time
    /// `now`; queue every newly-stale frontier entry.  Returns how many
    /// tasks were added.  (`host` reserved for lineage-aware drift
    /// rules; the current rule needs only shard-internal consistency.)
    pub fn scan(&mut self, shards: &[Shard], _host: &Fingerprint, now: u64) -> usize {
        let mut added = 0;
        for shard in shards {
            let drifted = match &shard.fingerprint {
                // A *derived* key that its own stored fingerprint no
                // longer hashes to: the machine changed while records
                // kept landing under the old key.  Arbitrary
                // wire-supplied keys are exempt (see module docs).
                Some(fp) => {
                    key_derived_from(&shard.platform_key, fp)
                        && fp.key() != shard.platform_key
                }
                None => false,
            };
            for entry in shard.frontier() {
                let key =
                    (shard.platform_key.clone(), entry.kernel.clone(), entry.tag.clone());
                // Drift outranks TTL but is delivered once; an
                // already-notified drifted shard still gets ordinary
                // TTL staleness checks (its entries keep aging).
                let reason = if drifted && !self.drift_notified.contains(&key) {
                    StaleReason::FingerprintDrift
                } else {
                    let age_s = now.saturating_sub(entry.recorded_at);
                    if age_s <= self.ttl_s {
                        continue;
                    }
                    StaleReason::TtlExpired { age_s }
                };
                if self.queued.insert(key.clone()) {
                    if matches!(reason, StaleReason::FingerprintDrift) {
                        self.drift_notified.insert(key);
                    }
                    self.queue.push_back(RetuneTask {
                        platform_key: shard.platform_key.clone(),
                        kernel: entry.kernel.clone(),
                        tag: entry.tag.clone(),
                        reason,
                    });
                    added += 1;
                }
            }
        }
        added
    }

    /// Pop the next task (releases its dedupe slot).
    pub fn pop(&mut self) -> Option<RetuneTask> {
        let task = self.queue.pop_front()?;
        self.queued.remove(&(
            task.platform_key.clone(),
            task.kernel.clone(),
            task.tag.clone(),
        ));
        Some(task)
    }

    /// Pop the first task belonging to `platform_key`, leaving other
    /// platforms' tasks queued.  The daemon's local re-tune worker uses
    /// this: it can only re-measure the host, and popping a foreign
    /// task would either waste a tune (the foreign shard stays stale
    /// and re-queues) or starve the external workers that poll
    /// `retune-next` for exactly those tasks.
    pub fn pop_for(&mut self, platform_key: &str) -> Option<RetuneTask> {
        let idx = self.queue.iter().position(|t| t.platform_key == platform_key)?;
        let task = self.queue.remove(idx)?;
        self.queued.remove(&(
            task.platform_key.clone(),
            task.kernel.clone(),
            task.tag.clone(),
        ));
        Some(task)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::perfdb::DbEntry;

    fn fp(l2: u64) -> Fingerprint {
        Fingerprint {
            cpu_model: "Test CPU".into(),
            num_cpus: 8,
            simd: vec!["avx2".into()],
            cache_l1d_kb: 32,
            cache_l2_kb: l2,
            cache_l3_kb: 8192,
            os: "linux".into(),
        }
    }

    fn entry(platform: &str, kernel: &str, tag: &str, recorded_at: u64) -> DbEntry {
        DbEntry {
            platform_key: platform.into(),
            kernel: kernel.into(),
            tag: tag.into(),
            best_params: Default::default(),
            best_config_id: "cfg".into(),
            best_time_s: 1e-3,
            baseline_time_s: 2e-3,
            reference_time_s: 9e-4,
            evaluations: 9,
            strategy: "exhaustive".into(),
            recorded_at,
        }
    }

    #[test]
    fn queues_ttl_expired_only_once() {
        let host = fp(1024);
        let key = host.key();
        let shard = Shard {
            platform_key: key.clone(),
            fingerprint: Some(host.clone()),
            entries: vec![entry(&key, "axpy", "n4096", 1000)],
            portfolios: Vec::new(),
        };
        let mut sched = Scheduler::new(3600);
        // Within TTL: nothing queued.
        assert_eq!(sched.scan(std::slice::from_ref(&shard), &host, 2000), 0);
        // Past TTL: queued exactly once across repeated scans.
        assert_eq!(sched.scan(std::slice::from_ref(&shard), &host, 10_000), 1);
        assert_eq!(sched.scan(std::slice::from_ref(&shard), &host, 10_000), 0);
        let task = sched.pop().unwrap();
        assert_eq!(task.kernel, "axpy");
        assert_eq!(task.reason, StaleReason::TtlExpired { age_s: 9_000 });
        assert!(sched.pop().is_none());
        // Popped slot is free again: still-stale entries re-queue.
        assert_eq!(sched.scan(&[shard], &host, 10_000), 1);
    }

    #[test]
    fn queues_drifted_fingerprint_regardless_of_age() {
        let host = fp(1024);
        let drifted_fp = fp(512); // hardware changed; key() differs
        let shard = Shard {
            // Shard still filed under the *old* key.
            platform_key: fp(1024).key(),
            fingerprint: Some(drifted_fp),
            entries: vec![entry("x", "axpy", "n4096", u64::MAX / 2)],
            portfolios: Vec::new(),
        };
        let mut sched = Scheduler::new(u64::MAX);
        assert_eq!(sched.scan(std::slice::from_ref(&shard), &host, u64::MAX / 2), 1);
        assert_eq!(sched.pop().unwrap().reason, StaleReason::FingerprintDrift);
        // Drift is unfixable by re-tuning (fresh records land under the
        // new key), so it is delivered once — not re-queued every scan.
        assert_eq!(sched.scan(&[shard], &host, u64::MAX / 2), 0);
        assert!(sched.is_empty());
    }

    #[test]
    fn wire_supplied_keys_are_never_drift_flagged() {
        // A client recorded under an arbitrary name with its fingerprint
        // attached: the name can never re-hash to itself, but that is
        // not drift — flagging it would re-queue the entry forever.
        let host = fp(1024);
        let shard = Shard {
            platform_key: "remote-box".into(),
            fingerprint: Some(fp(512)),
            entries: vec![entry("remote-box", "axpy", "n4096", 5000)],
            portfolios: Vec::new(),
        };
        let mut sched = Scheduler::new(u64::MAX);
        assert_eq!(sched.scan(&[shard], &host, 6000), 0);
        assert!(!is_derived_key("remote-box"));
        assert!(is_derived_key(&host.key()));
        assert!(!is_derived_key("ends-with-UPPER-0123456789ABCDEF"));
        // Hash-shaped wire names still fail the model-prefix check.
        assert!(is_derived_key("gpu-node-00a1b2c3d4e5f601"));
        assert!(!key_derived_from("gpu-node-00a1b2c3d4e5f601", &fp(512)));
        assert!(key_derived_from(&host.key(), &host));
    }

    #[test]
    fn fresh_matching_shards_queue_nothing() {
        let host = fp(1024);
        let key = host.key();
        let shard = Shard {
            platform_key: key.clone(),
            fingerprint: Some(host.clone()),
            entries: vec![entry(&key, "axpy", "n4096", 5000)],
            portfolios: Vec::new(),
        };
        let mut sched = Scheduler::new(3600);
        assert_eq!(sched.scan(&[shard], &host, 5100), 0);
        assert!(sched.is_empty());
    }

    #[test]
    fn pop_for_skips_foreign_platforms() {
        let host = fp(1024);
        let mut sched = Scheduler::new(3600);
        let foreign = Shard {
            platform_key: "other-box".into(),
            fingerprint: None,
            entries: vec![entry("other-box", "axpy", "n4096", 100)],
            portfolios: Vec::new(),
        };
        let mine = Shard {
            platform_key: host.key(),
            fingerprint: Some(host.clone()),
            entries: vec![entry(&host.key(), "dot", "n4096", 100)],
            portfolios: Vec::new(),
        };
        assert_eq!(sched.scan(&[foreign, mine], &host, 1_000_000), 2);
        // The host worker pops only its own task...
        let task = sched.pop_for(&host.key()).unwrap();
        assert_eq!(task.kernel, "dot");
        assert!(sched.pop_for(&host.key()).is_none());
        // ...and the foreign task stays queued for retune-next.
        assert_eq!(sched.len(), 1);
        assert_eq!(sched.pop().unwrap().platform_key, "other-box");
    }

    #[test]
    fn task_json_is_machine_readable() {
        let task = RetuneTask {
            platform_key: "p1".into(),
            kernel: "axpy".into(),
            tag: "n4096".into(),
            reason: StaleReason::FingerprintDrift,
        };
        let j = task.to_json();
        assert_eq!(j.get("reason").and_then(Json::as_str), Some("fingerprint-drift"));
        assert_eq!(j.get("kernel").and_then(Json::as_str), Some("axpy"));
    }
}
