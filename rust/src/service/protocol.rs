//! The serve wire protocol: one JSON object per line, both directions.
//!
//! Chosen for the same reason the perf DB is hand-rolled JSON: the
//! pinned dependency set has no serde/tokio, the documents are small
//! and schema-stable, and line-delimited framing works identically over
//! TCP and Unix sockets with nothing but `BufRead::read_line`.
//!
//! Requests (`op` selects the verb):
//!
//! ```text
//! {"op":"ping"}
//! {"op":"lookup","kernel":"axpy","workload":"n4096","platform":KEY?}
//! {"op":"deploy","kernel":"axpy","workload":"n4096","platform":KEY?,"fingerprint":{..}?}
//! {"op":"record","entry":{..DbEntry..},"fingerprint":{..}?,"request_id":"..."?,"spend_ms":N?}
//! {"op":"record-portfolio","portfolio":{..Portfolio..},"platform":KEY?,"fingerprint":{..}?,"spend_ms":N?}
//! {"op":"stats"}
//! {"op":"report","platform":KEY?}
//! {"op":"task-lease","kind":"retune"?,"platform":KEY?,"ttl_s":600?}
//! {"op":"task-heartbeat","lease_id":N}
//! {"op":"task-complete","lease_id":N,"request_id":"..."?}
//! {"op":"task-fail","lease_id":N,"error":"..."?}
//! {"op":"retune-next"}
//! {"op":"portfolio","kernel":"gemm","platform":KEY?,"dims":{"m":128,..}?,"fingerprint":{..}?}
//! {"op":"metrics"}
//! {"op":"shutdown"}
//! ```
//!
//! Every request may additionally carry an optional `trace_id` string
//! — an opaque client-generated correlation id, not part of any
//! `Request` variant.  It travels as a transport envelope field: the
//! daemon echoes it in the reply, stamps it on the audit log's served
//! events, and tags emitted trace spans with it, so one logical
//! operation can be followed across client, daemon, and worker (see
//! [`crate::obs::trace`]).
//!
//! `platform` defaults to the daemon host's own key.  Replies are
//! `{"ok":true,...}` or `{"ok":false,"error":"..."}`; `deploy` misses
//! answer with transfer-ranked candidates instead of an empty result
//! (see [`crate::service::server`]).  The four `task-*` ops are the
//! worker-fleet checkout protocol (see [`crate::service::scheduler`]);
//! `retune-next` survives as a back-compat alias for a default-TTL
//! lease of the next retune task.
//!
//! `request_id` (the two non-idempotent write ops, `record` and
//! `task-complete`) is an optional client-generated opaque string: the
//! daemon remembers recent ids and replays the stored reply for a
//! duplicate, so a client may retry a write whose ack was lost without
//! double-applying it (see the retry machinery in
//! [`crate::service::client`]).

use anyhow::{Context, Result};

use crate::coordinator::perfdb::DbEntry;
use crate::coordinator::platform::Fingerprint;
use crate::coordinator::portfolio::Portfolio;
use crate::service::scheduler::TaskKind;
use crate::util::json::{self, Json};

/// A parsed client request.
#[derive(Debug, Clone)]
pub enum Request {
    /// Liveness check.
    Ping,
    /// Exact read of the newest record for (platform, kernel, workload).
    Lookup {
        /// Platform key (daemon host's own when absent).
        platform: Option<String>,
        /// Kernel family.
        kernel: String,
        /// Workload tag.
        workload: String,
    },
    /// Deployment decision; misses answer with transfer candidates.
    Deploy {
        /// Platform key (daemon host's own when absent).
        platform: Option<String>,
        /// Kernel family.
        kernel: String,
        /// Workload tag.
        workload: String,
        /// The requesting platform's fingerprint — feeds the transfer
        /// engine on a miss.  Defaults to the daemon host's own.
        fingerprint: Option<Fingerprint>,
    },
    /// Write one tuning record into its platform's shard.
    Record {
        /// The record to persist.
        entry: Box<DbEntry>,
        /// Recording platform's fingerprint (stored in the shard).
        fingerprint: Option<Fingerprint>,
        /// Client-generated dedupe id: a retry carrying the same id
        /// replays the first attempt's reply instead of re-recording.
        request_id: Option<String>,
        /// Core-milliseconds of tuning work behind this record
        /// (compile + measure + sweep wall time) — accrued into the
        /// shard's core-hour ledger as spend.
        spend_ms: Option<u64>,
    },
    /// Write (or replace) a platform's variant portfolio — how a
    /// worker reports a finished portfolio-rebuild task so the
    /// daemon's portfolio cache is invalidated and the fresh
    /// `built_at` serves immediately.
    RecordPortfolio {
        /// Platform whose shard receives the portfolio (daemon host's
        /// own when absent).
        platform: Option<String>,
        /// The built portfolio.
        portfolio: Box<Portfolio>,
        /// Recording platform's fingerprint (stored in the shard).
        fingerprint: Option<Fingerprint>,
        /// Core-milliseconds the rebuild cost — ledger spend for the
        /// portfolio's kernel.
        spend_ms: Option<u64>,
    },
    /// Counter snapshot.
    Stats,
    /// The core-hour ledger: per-(platform, kernel) tuning ROI
    /// (spend, benefit, net, break-even) plus active regressions.
    Report {
        /// Restrict to one platform (all platforms when absent).
        platform: Option<String>,
    },
    /// Full telemetry registry snapshot: the `stats` counters plus
    /// every latency histogram (see [`crate::obs`]).
    Metrics,
    /// Check out the next tuning task under a lease.
    TaskLease {
        /// Take only tasks of this kind (any kind when absent).
        kind: Option<TaskKind>,
        /// Take only tasks for this platform — a worker can usually
        /// measure only its own hardware (any platform when absent).
        platform: Option<String>,
        /// Lease TTL in seconds (daemon default when absent).
        ttl_s: Option<u64>,
    },
    /// Extend a live lease by its TTL.
    TaskHeartbeat {
        /// The lease to extend.
        lease_id: u64,
    },
    /// Settle a lease: the task's results were recorded.
    TaskComplete {
        /// The lease to settle.
        lease_id: u64,
        /// Client-generated dedupe id: a retry carrying the same id
        /// replays the first attempt's reply (completion is already
        /// idempotent server-side; the id keeps the *reply* stable
        /// too, so a retry does not see `duplicate:true`).
        request_id: Option<String>,
    },
    /// Settle a lease as failed; the task requeues (bounded retries).
    TaskFail {
        /// The lease to settle.
        lease_id: u64,
        /// Worker-side error description (logged by the daemon).
        error: Option<String>,
    },
    /// Back-compat alias: lease the next retune task at the default
    /// TTL (pre-fleet pollers keep working and gain crash-proofing).
    RetuneNext,
    /// Fetch (and optionally select from) a platform's variant
    /// portfolio for a kernel.  A miss answers with the nearest
    /// platform's portfolio, transfer-ranked like `deploy`.
    Portfolio {
        /// Target platform key (daemon host's own when absent).
        platform: Option<String>,
        /// Kernel family whose portfolio is wanted.
        kernel: String,
        /// Workload dims; when present the reply includes the member
        /// the feature selector picks for them.
        dims: Option<std::collections::BTreeMap<String, i64>>,
        /// Requesting platform's fingerprint (transfer ranking on a
        /// miss, cache-geometry features for selection).
        fingerprint: Option<Fingerprint>,
    },
    /// Stop accepting connections and drain.
    Shutdown,
}

impl Request {
    /// Parse one request line (dropping any `trace_id` envelope field).
    pub fn parse_line(line: &str) -> Result<Request> {
        Self::parse_line_traced(line).map(|(req, _)| req)
    }

    /// Parse one request line, splitting off the optional `trace_id`
    /// envelope field (which is transport metadata, not request state).
    pub fn parse_line_traced(line: &str) -> Result<(Request, Option<String>)> {
        let v = json::parse(line.trim()).context("parsing request json")?;
        let trace_id = v.get("trace_id").and_then(Json::as_str).map(str::to_string);
        Ok((Self::request_from_json(&v)?, trace_id))
    }

    /// Decode a parsed request object.
    fn request_from_json(v: &Json) -> Result<Request> {
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("request missing op"))?;
        let gs = |k: &str| -> Result<String> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("{op} request missing {k}"))
        };
        let opt = |k: &str| v.get(k).and_then(Json::as_str).map(str::to_string);
        let fp = || match v.get("fingerprint") {
            Some(Json::Null) | None => Ok(None),
            Some(f) => Fingerprint::from_json(f)
                .map(Some)
                .ok_or_else(|| anyhow::anyhow!("malformed fingerprint")),
        };
        let spend = || match v.get("spend_ms") {
            Some(Json::Null) | None => Ok(None),
            Some(t) => t
                .as_u64()
                .map(Some)
                .ok_or_else(|| anyhow::anyhow!("spend_ms must be a non-negative int")),
        };
        match op {
            "ping" => Ok(Request::Ping),
            "lookup" => Ok(Request::Lookup {
                platform: opt("platform"),
                kernel: gs("kernel")?,
                workload: gs("workload")?,
            }),
            "deploy" => Ok(Request::Deploy {
                platform: opt("platform"),
                kernel: gs("kernel")?,
                workload: gs("workload")?,
                fingerprint: fp()?,
            }),
            "record" => {
                let entry = v
                    .get("entry")
                    .ok_or_else(|| anyhow::anyhow!("record request missing entry"))?;
                Ok(Request::Record {
                    entry: Box::new(DbEntry::from_json(entry)?),
                    fingerprint: fp()?,
                    request_id: opt("request_id"),
                    spend_ms: spend()?,
                })
            }
            "record-portfolio" => {
                let p = v
                    .get("portfolio")
                    .ok_or_else(|| anyhow::anyhow!("record-portfolio request missing portfolio"))?;
                Ok(Request::RecordPortfolio {
                    platform: opt("platform"),
                    portfolio: Box::new(Portfolio::from_json(p)?),
                    fingerprint: fp()?,
                    spend_ms: spend()?,
                })
            }
            "stats" => Ok(Request::Stats),
            "report" => Ok(Request::Report { platform: opt("platform") }),
            "metrics" => Ok(Request::Metrics),
            "task-lease" => {
                let kind = match v.get("kind").and_then(Json::as_str) {
                    None => None,
                    Some(s) => Some(
                        TaskKind::parse(s)
                            .ok_or_else(|| anyhow::anyhow!("unknown task kind {s}"))?,
                    ),
                };
                let ttl_s = match v.get("ttl_s") {
                    Some(Json::Null) | None => None,
                    Some(t) => Some(
                        t.as_u64()
                            .ok_or_else(|| anyhow::anyhow!("ttl_s must be a non-negative int"))?,
                    ),
                };
                Ok(Request::TaskLease { kind, platform: opt("platform"), ttl_s })
            }
            "task-heartbeat" => Ok(Request::TaskHeartbeat { lease_id: lease_id(&v, op)? }),
            "task-complete" => Ok(Request::TaskComplete {
                lease_id: lease_id(&v, op)?,
                request_id: opt("request_id"),
            }),
            "task-fail" => Ok(Request::TaskFail {
                lease_id: lease_id(&v, op)?,
                error: opt("error"),
            }),
            "retune-next" => Ok(Request::RetuneNext),
            "portfolio" => {
                let dims = match v.get("dims") {
                    Some(Json::Null) | None => None,
                    Some(d) => Some(
                        d.as_obj()
                            .ok_or_else(|| anyhow::anyhow!("portfolio dims must be an object"))?
                            .iter()
                            .map(|(k, val)| {
                                val.as_i64()
                                    .map(|x| (k.clone(), x))
                                    .ok_or_else(|| anyhow::anyhow!("non-int dim {k}"))
                            })
                            .collect::<Result<std::collections::BTreeMap<_, _>>>()?,
                    ),
                };
                Ok(Request::Portfolio {
                    platform: opt("platform"),
                    kernel: gs("kernel")?,
                    dims,
                    fingerprint: fp()?,
                })
            }
            "shutdown" => Ok(Request::Shutdown),
            other => Err(anyhow::anyhow!("unknown op {other}")),
        }
    }

    /// The wire op string this request serializes as.
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::Lookup { .. } => "lookup",
            Request::Deploy { .. } => "deploy",
            Request::Record { .. } => "record",
            Request::RecordPortfolio { .. } => "record-portfolio",
            Request::Stats => "stats",
            Request::Report { .. } => "report",
            Request::Metrics => "metrics",
            Request::TaskLease { .. } => "task-lease",
            Request::TaskHeartbeat { .. } => "task-heartbeat",
            Request::TaskComplete { .. } => "task-complete",
            Request::TaskFail { .. } => "task-fail",
            Request::RetuneNext => "retune-next",
            Request::Portfolio { .. } => "portfolio",
            Request::Shutdown => "shutdown",
        }
    }

    /// Serialize to one compact wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_line_traced(None)
    }

    /// Serialize to one wire line carrying the optional `trace_id`
    /// envelope field (see the module docs).
    pub fn to_line_traced(&self, trace_id: Option<&str>) -> String {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        if let Some(id) = trace_id {
            fields.push(("trace_id", json::s(id)));
        }
        match self {
            Request::Ping => fields.push(("op", json::s("ping"))),
            Request::Lookup { platform, kernel, workload } => {
                fields.push(("op", json::s("lookup")));
                fields.push(("kernel", json::s(kernel)));
                fields.push(("workload", json::s(workload)));
                if let Some(p) = platform {
                    fields.push(("platform", json::s(p)));
                }
            }
            Request::Deploy { platform, kernel, workload, fingerprint } => {
                fields.push(("op", json::s("deploy")));
                fields.push(("kernel", json::s(kernel)));
                fields.push(("workload", json::s(workload)));
                if let Some(p) = platform {
                    fields.push(("platform", json::s(p)));
                }
                if let Some(fp) = fingerprint {
                    fields.push(("fingerprint", fp.to_json()));
                }
            }
            Request::Record { entry, fingerprint, request_id, spend_ms } => {
                fields.push(("op", json::s("record")));
                fields.push(("entry", entry.to_json()));
                if let Some(fp) = fingerprint {
                    fields.push(("fingerprint", fp.to_json()));
                }
                if let Some(id) = request_id {
                    fields.push(("request_id", json::s(id)));
                }
                if let Some(ms) = spend_ms {
                    fields.push(("spend_ms", json::int(*ms as i64)));
                }
            }
            Request::RecordPortfolio { platform, portfolio, fingerprint, spend_ms } => {
                fields.push(("op", json::s("record-portfolio")));
                if let Some(p) = platform {
                    fields.push(("platform", json::s(p)));
                }
                fields.push(("portfolio", portfolio.to_json()));
                if let Some(fp) = fingerprint {
                    fields.push(("fingerprint", fp.to_json()));
                }
                if let Some(ms) = spend_ms {
                    fields.push(("spend_ms", json::int(*ms as i64)));
                }
            }
            Request::Stats => fields.push(("op", json::s("stats"))),
            Request::Report { platform } => {
                fields.push(("op", json::s("report")));
                if let Some(p) = platform {
                    fields.push(("platform", json::s(p)));
                }
            }
            Request::Metrics => fields.push(("op", json::s("metrics"))),
            Request::TaskLease { kind, platform, ttl_s } => {
                fields.push(("op", json::s("task-lease")));
                if let Some(k) = kind {
                    fields.push(("kind", json::s(k.as_str())));
                }
                if let Some(p) = platform {
                    fields.push(("platform", json::s(p)));
                }
                if let Some(t) = ttl_s {
                    fields.push(("ttl_s", json::int(*t as i64)));
                }
            }
            Request::TaskHeartbeat { lease_id } => {
                fields.push(("op", json::s("task-heartbeat")));
                fields.push(("lease_id", json::int(*lease_id as i64)));
            }
            Request::TaskComplete { lease_id, request_id } => {
                fields.push(("op", json::s("task-complete")));
                fields.push(("lease_id", json::int(*lease_id as i64)));
                if let Some(id) = request_id {
                    fields.push(("request_id", json::s(id)));
                }
            }
            Request::TaskFail { lease_id, error } => {
                fields.push(("op", json::s("task-fail")));
                fields.push(("lease_id", json::int(*lease_id as i64)));
                if let Some(e) = error {
                    fields.push(("error", json::s(e)));
                }
            }
            Request::RetuneNext => fields.push(("op", json::s("retune-next"))),
            Request::Portfolio { platform, kernel, dims, fingerprint } => {
                fields.push(("op", json::s("portfolio")));
                fields.push(("kernel", json::s(kernel)));
                if let Some(p) = platform {
                    fields.push(("platform", json::s(p)));
                }
                if let Some(d) = dims {
                    fields.push((
                        "dims",
                        Json::Obj(d.iter().map(|(k, v)| (k.clone(), json::int(*v))).collect()),
                    ));
                }
                if let Some(fp) = fingerprint {
                    fields.push(("fingerprint", fp.to_json()));
                }
            }
            Request::Shutdown => fields.push(("op", json::s("shutdown"))),
        }
        json::obj(fields).compact()
    }
}

/// Required `lease_id` field of the task-settlement ops.
fn lease_id(v: &Json, op: &str) -> Result<u64> {
    v.get("lease_id")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("{op} request missing lease_id"))
}

/// `{"ok":true, ...}` reply body.
pub fn reply_ok(mut fields: Vec<(&str, Json)>) -> Json {
    fields.insert(0, ("ok", Json::Bool(true)));
    json::obj(fields)
}

/// `{"ok":false,"error":...}` reply body.
pub fn reply_err(message: &str) -> Json {
    json::obj(vec![("ok", Json::Bool(false)), ("error", json::s(message))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_lines_round_trip() {
        let reqs = vec![
            Request::Ping,
            Request::Lookup {
                platform: Some("p1".into()),
                kernel: "axpy".into(),
                workload: "n4096".into(),
            },
            Request::Stats,
            Request::Metrics,
            Request::Report { platform: None },
            Request::Report { platform: Some("p1".into()) },
            Request::RetuneNext,
            Request::TaskLease { kind: None, platform: None, ttl_s: None },
            Request::TaskLease {
                kind: Some(TaskKind::PortfolioRebuild),
                platform: Some("p1".into()),
                ttl_s: Some(300),
            },
            Request::TaskHeartbeat { lease_id: 7 },
            Request::TaskComplete { lease_id: 7, request_id: None },
            Request::TaskComplete { lease_id: 7, request_id: Some("w1-42".into()) },
            Request::TaskFail { lease_id: 7, error: Some("sweep oom".into()) },
            Request::Portfolio {
                platform: None,
                kernel: "gemm".into(),
                dims: None,
                fingerprint: None,
            },
            Request::Portfolio {
                platform: Some("p1".into()),
                kernel: "gemm".into(),
                dims: Some(
                    [("m".to_string(), 128i64), ("n".to_string(), 64), ("k".to_string(), 32)]
                        .into_iter()
                        .collect(),
                ),
                fingerprint: None,
            },
            Request::Shutdown,
        ];
        for req in reqs {
            let line = req.to_line();
            assert!(!line.contains('\n'), "wire lines must be single-line");
            let back = Request::parse_line(&line).unwrap();
            assert_eq!(back.to_line(), line);
        }
    }

    #[test]
    fn deploy_carries_fingerprint() {
        let fp = Fingerprint {
            cpu_model: "Test".into(),
            num_cpus: 8,
            simd: vec!["avx2".into()],
            cache_l1d_kb: 32,
            cache_l2_kb: 1024,
            cache_l3_kb: 8192,
            os: "linux".into(),
        };
        let req = Request::Deploy {
            platform: None,
            kernel: "axpy".into(),
            workload: "n4096".into(),
            fingerprint: Some(fp.clone()),
        };
        let line = req.to_line();
        match Request::parse_line(&line).unwrap() {
            Request::Deploy { fingerprint: Some(back), .. } => assert_eq!(back, fp),
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn malformed_requests_error_not_panic() {
        assert!(Request::parse_line("").is_err());
        assert!(Request::parse_line("{}").is_err());
        assert!(Request::parse_line(r#"{"op":"warp"}"#).is_err());
        assert!(Request::parse_line(r#"{"op":"lookup"}"#).is_err());
        assert!(Request::parse_line(r#"{"op":"record","entry":{}}"#).is_err());
        assert!(Request::parse_line("not json at all").is_err());
        assert!(Request::parse_line(r#"{"op":"portfolio"}"#).is_err(), "kernel is required");
        assert!(
            Request::parse_line(r#"{"op":"portfolio","kernel":"gemm","dims":{"m":"big"}}"#)
                .is_err(),
            "dims must be integers"
        );
        assert!(
            Request::parse_line(r#"{"op":"task-lease","kind":"repaint"}"#).is_err(),
            "unknown task kinds error"
        );
        assert!(
            Request::parse_line(r#"{"op":"task-lease","ttl_s":"soon"}"#).is_err(),
            "ttl_s must be an int"
        );
        assert!(
            Request::parse_line(r#"{"op":"record","entry":{},"spend_ms":"lots"}"#).is_err(),
            "spend_ms must be an int"
        );
        assert!(
            Request::parse_line(r#"{"op":"task-heartbeat"}"#).is_err(),
            "lease_id is required"
        );
        assert!(Request::parse_line(r#"{"op":"task-complete","lease_id":-3}"#).is_err());
        assert!(Request::parse_line(r#"{"op":"record-portfolio"}"#).is_err());
        assert!(
            Request::parse_line(r#"{"op":"record-portfolio","portfolio":{"kernel":"gemm"}}"#)
                .is_err(),
            "portfolio payload must satisfy the typed parser"
        );
    }

    #[test]
    fn task_ops_round_trip_their_fields() {
        let line = r#"{"op":"task-lease","kind":"sweep","platform":"p1","ttl_s":120}"#;
        match Request::parse_line(line).unwrap() {
            Request::TaskLease { kind, platform, ttl_s } => {
                assert_eq!(kind, Some(TaskKind::Sweep));
                assert_eq!(platform.as_deref(), Some("p1"));
                assert_eq!(ttl_s, Some(120));
            }
            other => panic!("parsed {other:?}"),
        }
        match Request::parse_line(r#"{"op":"task-fail","lease_id":9}"#).unwrap() {
            Request::TaskFail { lease_id, error } => {
                assert_eq!(lease_id, 9);
                assert!(error.is_none());
            }
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn request_id_is_optional_and_round_trips() {
        match Request::parse_line(r#"{"op":"task-complete","lease_id":4}"#).unwrap() {
            Request::TaskComplete { lease_id, request_id } => {
                assert_eq!(lease_id, 4);
                assert!(request_id.is_none());
            }
            other => panic!("parsed {other:?}"),
        }
        let line = r#"{"lease_id":4,"op":"task-complete","request_id":"w2-17"}"#;
        match Request::parse_line(line).unwrap() {
            req @ Request::TaskComplete { .. } => {
                assert_eq!(req.to_line(), line, "request_id must survive serialization");
            }
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn record_portfolio_round_trips() {
        use crate::coordinator::portfolio::{PortfolioItem, FEATURE_NAMES};
        let portfolio = Portfolio {
            kernel: "gemm".into(),
            strategy: "greedy-cover".into(),
            k_max: 4,
            retained: 0.93,
            built_at: 1_700_000_000,
            feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
            items: vec![PortfolioItem {
                config: [("tile_m".to_string(), 32i64)].into_iter().collect(),
                config_id: "o1_tm32".into(),
                centroid: vec![5.0; FEATURE_NAMES.len()],
                covered: vec!["m32n32k32".into()],
            }],
        };
        let req = Request::RecordPortfolio {
            platform: Some("p1".into()),
            portfolio: Box::new(portfolio.clone()),
            fingerprint: None,
            spend_ms: Some(4200),
        };
        let line = req.to_line();
        match Request::parse_line(&line).unwrap() {
            Request::RecordPortfolio { platform, portfolio: back, spend_ms, .. } => {
                assert_eq!(platform.as_deref(), Some("p1"));
                assert_eq!(*back, portfolio);
                assert_eq!(spend_ms, Some(4200), "ledger spend must survive the wire");
            }
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn portfolio_dims_round_trip() {
        let line = r#"{"op":"portfolio","kernel":"gemm","dims":{"k":32,"m":128,"n":64}}"#;
        match Request::parse_line(line).unwrap() {
            Request::Portfolio { kernel, dims: Some(dims), platform: None, .. } => {
                assert_eq!(kernel, "gemm");
                assert_eq!(dims["m"], 128);
                assert_eq!(dims["k"], 32);
            }
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn trace_id_rides_the_envelope_not_the_request() {
        let line = Request::Ping.to_line_traced(Some("t1-abc"));
        assert_eq!(line, r#"{"op":"ping","trace_id":"t1-abc"}"#);
        let (req, trace_id) = Request::parse_line_traced(&line).unwrap();
        assert!(matches!(req, Request::Ping));
        assert_eq!(trace_id.as_deref(), Some("t1-abc"));
        // parse_line drops the envelope field without error.
        assert!(matches!(Request::parse_line(&line).unwrap(), Request::Ping));
        // Absent trace_id parses as None.
        let (_, none) = Request::parse_line_traced(r#"{"op":"ping"}"#).unwrap();
        assert!(none.is_none());
    }

    #[test]
    fn op_name_matches_the_wire_op() {
        let reqs = vec![
            Request::Ping,
            Request::Stats,
            Request::Metrics,
            Request::Report { platform: None },
            Request::RetuneNext,
            Request::Shutdown,
            Request::TaskHeartbeat { lease_id: 1 },
            Request::Lookup { platform: None, kernel: "axpy".into(), workload: "n1".into() },
        ];
        for req in reqs {
            let line = req.to_line();
            let v = json::parse(&line).unwrap();
            assert_eq!(v.get("op").and_then(Json::as_str), Some(req.op_name()));
        }
    }

    #[test]
    fn replies_have_ok_discriminant() {
        let ok = reply_ok(vec![("x", json::int(1))]);
        assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
        let err = reply_err("boom");
        assert_eq!(err.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(err.get("error").and_then(Json::as_str), Some("boom"));
    }
}
