//! The serve wire protocol: one JSON object per line, both directions.
//!
//! Chosen for the same reason the perf DB is hand-rolled JSON: the
//! pinned dependency set has no serde/tokio, the documents are small
//! and schema-stable, and line-delimited framing works identically over
//! TCP and Unix sockets with nothing but `BufRead::read_line`.
//!
//! Requests (`op` selects the verb):
//!
//! ```text
//! {"op":"ping"}
//! {"op":"lookup","kernel":"axpy","workload":"n4096","platform":KEY?}
//! {"op":"deploy","kernel":"axpy","workload":"n4096","platform":KEY?,"fingerprint":{..}?}
//! {"op":"record","entry":{..DbEntry..},"fingerprint":{..}?}
//! {"op":"stats"}
//! {"op":"retune-next"}
//! {"op":"portfolio","kernel":"gemm","platform":KEY?,"dims":{"m":128,..}?,"fingerprint":{..}?}
//! {"op":"shutdown"}
//! ```
//!
//! `platform` defaults to the daemon host's own key.  Replies are
//! `{"ok":true,...}` or `{"ok":false,"error":"..."}`; `deploy` misses
//! answer with transfer-ranked candidates instead of an empty result
//! (see [`crate::service::server`]).

use anyhow::{Context, Result};

use crate::coordinator::perfdb::DbEntry;
use crate::coordinator::platform::Fingerprint;
use crate::util::json::{self, Json};

/// A parsed client request.
#[derive(Debug, Clone)]
pub enum Request {
    /// Liveness check.
    Ping,
    /// Exact read of the newest record for (platform, kernel, workload).
    Lookup {
        /// Platform key (daemon host's own when absent).
        platform: Option<String>,
        /// Kernel family.
        kernel: String,
        /// Workload tag.
        workload: String,
    },
    /// Deployment decision; misses answer with transfer candidates.
    Deploy {
        /// Platform key (daemon host's own when absent).
        platform: Option<String>,
        /// Kernel family.
        kernel: String,
        /// Workload tag.
        workload: String,
        /// The requesting platform's fingerprint — feeds the transfer
        /// engine on a miss.  Defaults to the daemon host's own.
        fingerprint: Option<Fingerprint>,
    },
    /// Write one tuning record into its platform's shard.
    Record {
        /// The record to persist.
        entry: Box<DbEntry>,
        /// Recording platform's fingerprint (stored in the shard).
        fingerprint: Option<Fingerprint>,
    },
    /// Counter snapshot.
    Stats,
    /// Pop one task from the staleness re-tune queue.
    RetuneNext,
    /// Fetch (and optionally select from) a platform's variant
    /// portfolio for a kernel.  A miss answers with the nearest
    /// platform's portfolio, transfer-ranked like `deploy`.
    Portfolio {
        /// Target platform key (daemon host's own when absent).
        platform: Option<String>,
        /// Kernel family whose portfolio is wanted.
        kernel: String,
        /// Workload dims; when present the reply includes the member
        /// the feature selector picks for them.
        dims: Option<std::collections::BTreeMap<String, i64>>,
        /// Requesting platform's fingerprint (transfer ranking on a
        /// miss, cache-geometry features for selection).
        fingerprint: Option<Fingerprint>,
    },
    /// Stop accepting connections and drain.
    Shutdown,
}

impl Request {
    /// Parse one request line.
    pub fn parse_line(line: &str) -> Result<Request> {
        let v = json::parse(line.trim()).context("parsing request json")?;
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("request missing op"))?;
        let gs = |k: &str| -> Result<String> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("{op} request missing {k}"))
        };
        let opt = |k: &str| v.get(k).and_then(Json::as_str).map(str::to_string);
        let fp = || match v.get("fingerprint") {
            Some(Json::Null) | None => Ok(None),
            Some(f) => Fingerprint::from_json(f)
                .map(Some)
                .ok_or_else(|| anyhow::anyhow!("malformed fingerprint")),
        };
        match op {
            "ping" => Ok(Request::Ping),
            "lookup" => Ok(Request::Lookup {
                platform: opt("platform"),
                kernel: gs("kernel")?,
                workload: gs("workload")?,
            }),
            "deploy" => Ok(Request::Deploy {
                platform: opt("platform"),
                kernel: gs("kernel")?,
                workload: gs("workload")?,
                fingerprint: fp()?,
            }),
            "record" => {
                let entry = v
                    .get("entry")
                    .ok_or_else(|| anyhow::anyhow!("record request missing entry"))?;
                Ok(Request::Record {
                    entry: Box::new(DbEntry::from_json(entry)?),
                    fingerprint: fp()?,
                })
            }
            "stats" => Ok(Request::Stats),
            "retune-next" => Ok(Request::RetuneNext),
            "portfolio" => {
                let dims = match v.get("dims") {
                    Some(Json::Null) | None => None,
                    Some(d) => Some(
                        d.as_obj()
                            .ok_or_else(|| anyhow::anyhow!("portfolio dims must be an object"))?
                            .iter()
                            .map(|(k, val)| {
                                val.as_i64()
                                    .map(|x| (k.clone(), x))
                                    .ok_or_else(|| anyhow::anyhow!("non-int dim {k}"))
                            })
                            .collect::<Result<std::collections::BTreeMap<_, _>>>()?,
                    ),
                };
                Ok(Request::Portfolio {
                    platform: opt("platform"),
                    kernel: gs("kernel")?,
                    dims,
                    fingerprint: fp()?,
                })
            }
            "shutdown" => Ok(Request::Shutdown),
            other => Err(anyhow::anyhow!("unknown op {other}")),
        }
    }

    /// Serialize to one compact wire line (no trailing newline).
    pub fn to_line(&self) -> String {
        let mut fields: Vec<(&str, Json)> = Vec::new();
        match self {
            Request::Ping => fields.push(("op", json::s("ping"))),
            Request::Lookup { platform, kernel, workload } => {
                fields.push(("op", json::s("lookup")));
                fields.push(("kernel", json::s(kernel)));
                fields.push(("workload", json::s(workload)));
                if let Some(p) = platform {
                    fields.push(("platform", json::s(p)));
                }
            }
            Request::Deploy { platform, kernel, workload, fingerprint } => {
                fields.push(("op", json::s("deploy")));
                fields.push(("kernel", json::s(kernel)));
                fields.push(("workload", json::s(workload)));
                if let Some(p) = platform {
                    fields.push(("platform", json::s(p)));
                }
                if let Some(fp) = fingerprint {
                    fields.push(("fingerprint", fp.to_json()));
                }
            }
            Request::Record { entry, fingerprint } => {
                fields.push(("op", json::s("record")));
                fields.push(("entry", entry.to_json()));
                if let Some(fp) = fingerprint {
                    fields.push(("fingerprint", fp.to_json()));
                }
            }
            Request::Stats => fields.push(("op", json::s("stats"))),
            Request::RetuneNext => fields.push(("op", json::s("retune-next"))),
            Request::Portfolio { platform, kernel, dims, fingerprint } => {
                fields.push(("op", json::s("portfolio")));
                fields.push(("kernel", json::s(kernel)));
                if let Some(p) = platform {
                    fields.push(("platform", json::s(p)));
                }
                if let Some(d) = dims {
                    fields.push((
                        "dims",
                        Json::Obj(d.iter().map(|(k, v)| (k.clone(), json::int(*v))).collect()),
                    ));
                }
                if let Some(fp) = fingerprint {
                    fields.push(("fingerprint", fp.to_json()));
                }
            }
            Request::Shutdown => fields.push(("op", json::s("shutdown"))),
        }
        json::obj(fields).compact()
    }
}

/// `{"ok":true, ...}` reply body.
pub fn reply_ok(mut fields: Vec<(&str, Json)>) -> Json {
    fields.insert(0, ("ok", Json::Bool(true)));
    json::obj(fields)
}

/// `{"ok":false,"error":...}` reply body.
pub fn reply_err(message: &str) -> Json {
    json::obj(vec![("ok", Json::Bool(false)), ("error", json::s(message))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_lines_round_trip() {
        let reqs = vec![
            Request::Ping,
            Request::Lookup {
                platform: Some("p1".into()),
                kernel: "axpy".into(),
                workload: "n4096".into(),
            },
            Request::Stats,
            Request::RetuneNext,
            Request::Portfolio {
                platform: None,
                kernel: "gemm".into(),
                dims: None,
                fingerprint: None,
            },
            Request::Portfolio {
                platform: Some("p1".into()),
                kernel: "gemm".into(),
                dims: Some(
                    [("m".to_string(), 128i64), ("n".to_string(), 64), ("k".to_string(), 32)]
                        .into_iter()
                        .collect(),
                ),
                fingerprint: None,
            },
            Request::Shutdown,
        ];
        for req in reqs {
            let line = req.to_line();
            assert!(!line.contains('\n'), "wire lines must be single-line");
            let back = Request::parse_line(&line).unwrap();
            assert_eq!(back.to_line(), line);
        }
    }

    #[test]
    fn deploy_carries_fingerprint() {
        let fp = Fingerprint {
            cpu_model: "Test".into(),
            num_cpus: 8,
            simd: vec!["avx2".into()],
            cache_l1d_kb: 32,
            cache_l2_kb: 1024,
            cache_l3_kb: 8192,
            os: "linux".into(),
        };
        let req = Request::Deploy {
            platform: None,
            kernel: "axpy".into(),
            workload: "n4096".into(),
            fingerprint: Some(fp.clone()),
        };
        let line = req.to_line();
        match Request::parse_line(&line).unwrap() {
            Request::Deploy { fingerprint: Some(back), .. } => assert_eq!(back, fp),
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn malformed_requests_error_not_panic() {
        assert!(Request::parse_line("").is_err());
        assert!(Request::parse_line("{}").is_err());
        assert!(Request::parse_line(r#"{"op":"warp"}"#).is_err());
        assert!(Request::parse_line(r#"{"op":"lookup"}"#).is_err());
        assert!(Request::parse_line(r#"{"op":"record","entry":{}}"#).is_err());
        assert!(Request::parse_line("not json at all").is_err());
        assert!(Request::parse_line(r#"{"op":"portfolio"}"#).is_err(), "kernel is required");
        assert!(
            Request::parse_line(r#"{"op":"portfolio","kernel":"gemm","dims":{"m":"big"}}"#)
                .is_err(),
            "dims must be integers"
        );
    }

    #[test]
    fn portfolio_dims_round_trip() {
        let line = r#"{"op":"portfolio","kernel":"gemm","dims":{"k":32,"m":128,"n":64}}"#;
        match Request::parse_line(line).unwrap() {
            Request::Portfolio { kernel, dims: Some(dims), platform: None, .. } => {
                assert_eq!(kernel, "gemm");
                assert_eq!(dims["m"], 128);
                assert_eq!(dims["k"], 32);
            }
            other => panic!("parsed {other:?}"),
        }
    }

    #[test]
    fn replies_have_ok_discriminant() {
        let ok = reply_ok(vec![("x", json::int(1))]);
        assert_eq!(ok.get("ok").and_then(Json::as_bool), Some(true));
        let err = reply_err("boom");
        assert_eq!(err.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(err.get("error").and_then(Json::as_str), Some("boom"));
    }
}
