//! Immutable serve-path state: one [`ServeSnapshot`] per published
//! generation.
//!
//! The daemon used to guard its decision caches with mutexes, so every
//! hot `lookup`/`deploy`/`portfolio` serialized on a lock and contended
//! throughput flatlined.  Now all read-path state — the shard pool,
//! the deployable frontier, the per-kernel portfolios, the stored
//! fingerprints — is precomputed into an immutable snapshot held
//! behind `RwLock<Arc<ServeSnapshot>>` (read-mostly discipline: readers
//! clone the `Arc` under a read lock and then work lock-free; writers
//! clone-merge-publish a whole new snapshot and swap the `Arc`).
//! Readers therefore never block on a writer mutex, never observe a
//! half-merged state, and every reply can tell the client exactly
//! which generation answered it (`gen` — the read-your-writes echo).
//!
//! The same type is the payload of an offline decision bundle
//! ([`crate::service::bundle`]): `Client::from_bundle` answers
//! `deploy`/`portfolio` from a deserialized snapshot with zero daemon
//! round-trips, so reply shaping lives *here*, shared by both paths —
//! offline answers are identical to live ones by construction.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::time::Instant;

use crate::coordinator::perfdb::{DbEntry, Shard};
use crate::coordinator::platform::Fingerprint;
use crate::coordinator::portfolio::{Portfolio, PortfolioItem};
use crate::obs;
use crate::service::protocol::reply_ok;
use crate::service::transfer;
use crate::util::json::{self, Json};

/// How many transfer candidates a deploy miss returns.
pub(crate) const DEPLOY_CANDIDATES: usize = 5;

/// Where a snapshot-served answer came from — the serve path's
/// counter/audit classification, shared by the daemon and the offline
/// bundle client.
#[derive(Debug, Clone, PartialEq)]
pub enum ServedFrom {
    /// Answered from the snapshot's precomputed index (an exact hit).
    Index,
    /// Exact miss answered by transfer ranking from the named source.
    Transfer {
        /// Platform key the borrowed answer was recorded on.
        source: String,
        /// Similarity of that platform to the target, in per-mille.
        similarity_pm: u64,
    },
    /// Exact miss with no transfer candidate either.
    Miss,
}

/// One immutable, internally consistent view of the shard store:
/// everything the hot serve ops need, precomputed at publish time so
/// reads are pure hash-map probes over shared (`Arc`ed) data.
#[derive(Debug, Clone)]
pub struct ServeSnapshot {
    generation: u64,
    /// The full shard pool, sorted by platform key — the transfer
    /// ranking's candidate set.
    shards: Vec<Shard>,
    /// (platform, kernel, workload) → newest entry: the deployable
    /// frontier across every shard.
    frontier: HashMap<(String, String, String), DbEntry>,
    /// (platform, kernel) → built portfolio.
    portfolios: HashMap<(String, String), Portfolio>,
    /// platform → stored fingerprint (drives transfer ranking and
    /// portfolio selection features).
    fingerprints: HashMap<String, Fingerprint>,
    /// (platform, kernel, workload) keys the regression sentinel has
    /// flagged as of this publish — the serve-path view of live drift
    /// (record acks echo it; the `report` op lists it).
    regressing: HashSet<(String, String, String)>,
}

impl ServeSnapshot {
    /// Precompute a snapshot from a shard pool, stamped `generation`.
    pub fn build(mut shards: Vec<Shard>, generation: u64) -> ServeSnapshot {
        shards.sort_by(|a, b| a.platform_key.cmp(&b.platform_key));
        let mut frontier = HashMap::new();
        let mut portfolios = HashMap::new();
        let mut fingerprints = HashMap::new();
        for shard in &shards {
            for entry in shard.frontier() {
                frontier.insert(
                    (shard.platform_key.clone(), entry.kernel.clone(), entry.tag.clone()),
                    entry.clone(),
                );
            }
            for p in &shard.portfolios {
                portfolios.insert((shard.platform_key.clone(), p.kernel.clone()), p.clone());
            }
            if let Some(fp) = &shard.fingerprint {
                fingerprints.insert(shard.platform_key.clone(), fp.clone());
            }
        }
        ServeSnapshot {
            generation,
            shards,
            frontier,
            portfolios,
            fingerprints,
            regressing: HashSet::new(),
        }
    }

    /// The same snapshot with the sentinel's currently flagged keys
    /// attached (the daemon passes its live set at every publish; a
    /// plain [`build`](Self::build) — tests, offline bundles — starts
    /// with none).
    pub fn with_regressions(
        mut self,
        regressing: HashSet<(String, String, String)>,
    ) -> ServeSnapshot {
        self.regressing = regressing;
        self
    }

    /// Whether the sentinel had flagged (platform, kernel, workload)
    /// as regressing when this snapshot was published.
    pub fn is_regressing(&self, platform: &str, kernel: &str, tag: &str) -> bool {
        self.regressing.contains(&(
            platform.to_string(),
            kernel.to_string(),
            tag.to_string(),
        ))
    }

    /// The monotone publish counter this snapshot was stamped with.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The shard pool this snapshot was built from, sorted by platform.
    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Newest entry for (platform, kernel, workload), if tuned.
    pub fn lookup(&self, platform: &str, kernel: &str, tag: &str) -> Option<&DbEntry> {
        self.frontier.get(&(platform.to_string(), kernel.to_string(), tag.to_string()))
    }

    /// The stored portfolio for (platform, kernel), if built.
    pub fn portfolio(&self, platform: &str, kernel: &str) -> Option<&Portfolio> {
        self.portfolios.get(&(platform.to_string(), kernel.to_string()))
    }

    /// The stored fingerprint for a platform, if recorded.
    pub fn fingerprint(&self, platform: &str) -> Option<&Fingerprint> {
        self.fingerprints.get(platform)
    }

    /// Total precomputed index entries (frontier + portfolios) — the
    /// successor of the old decision-cache `lru_len` gauge.
    pub fn index_len(&self) -> usize {
        self.frontier.len() + self.portfolios.len()
    }

    /// Shape a `lookup` reply.  Pure index probe; the `gen` field tells
    /// the client which published generation answered.
    pub fn lookup_reply(&self, platform: &str, kernel: &str, workload: &str) -> (Json, ServedFrom) {
        match self.lookup(platform, kernel, workload) {
            Some(entry) => (
                reply_ok(vec![
                    ("found", Json::Bool(true)),
                    ("entry", entry.to_json()),
                    ("gen", json::int(self.generation as i64)),
                ]),
                ServedFrom::Index,
            ),
            None => (
                reply_ok(vec![
                    ("found", Json::Bool(false)),
                    ("gen", json::int(self.generation as i64)),
                ]),
                ServedFrom::Miss,
            ),
        }
    }

    /// Shape a `deploy` reply: exact frontier hit, else transfer-ranked
    /// warm-start candidates from the nearest platforms.  Ranking runs
    /// for the *target platform's* hardware: its stored shard
    /// fingerprint is authoritative (a query made on behalf of another
    /// machine carries the requester's fingerprint, which describes the
    /// wrong box); fall back to the request's fingerprint, then `host`.
    pub fn deploy_reply(
        &self,
        platform: &str,
        kernel: &str,
        workload: &str,
        request_fp: Option<&Fingerprint>,
        host: &Fingerprint,
    ) -> (Json, ServedFrom) {
        if let Some(entry) = self.lookup(platform, kernel, workload) {
            return (
                reply_ok(vec![
                    ("source", json::s("exact")),
                    ("entry", entry.to_json()),
                    ("gen", json::int(self.generation as i64)),
                ]),
                ServedFrom::Index,
            );
        }
        let rank_started = Instant::now();
        let target = self.fingerprint(platform).or(request_fp).unwrap_or(host);
        let ranked = transfer::rank_candidates(&self.shards, target, kernel, workload, platform);
        obs::metrics().transfer_rank_us.record(rank_started.elapsed().as_micros() as u64);
        let from = match ranked.first() {
            Some(best) => ServedFrom::Transfer {
                source: best.platform_key.clone(),
                similarity_pm: (best.similarity.clamp(0.0, 1.0) * 1000.0).round() as u64,
            },
            None => ServedFrom::Miss,
        };
        let candidates: Vec<Json> = ranked
            .iter()
            .take(DEPLOY_CANDIDATES)
            .map(|c| {
                json::obj(vec![
                    ("platform", json::s(&c.platform_key)),
                    ("similarity", json::num(c.similarity)),
                    ("same_workload", Json::Bool(c.same_workload)),
                    ("config_id", json::s(&c.entry.best_config_id)),
                    (
                        "params",
                        Json::Obj(
                            c.entry
                                .best_params
                                .iter()
                                .map(|(k, v)| (k.clone(), json::int(*v)))
                                .collect(),
                        ),
                    ),
                    ("speedup", json::num(c.entry.speedup())),
                ])
            })
            .collect();
        (
            reply_ok(vec![
                ("source", json::s("transfer")),
                ("count", json::int(candidates.len() as i64)),
                ("candidates", Json::Arr(candidates)),
                ("gen", json::int(self.generation as i64)),
            ]),
            from,
        )
    }

    /// Shape a `portfolio` reply: exact portfolio (with optional
    /// dims-driven member selection), else the nearest platform's
    /// portfolio by transfer ranking, else `found:false`.  Fingerprint
    /// precedence for selection and ranking matches
    /// [`deploy_reply`](Self::deploy_reply): stored, then request, then
    /// `host`.
    pub fn portfolio_reply(
        &self,
        platform: &str,
        kernel: &str,
        dims: Option<&BTreeMap<String, i64>>,
        request_fp: Option<&Fingerprint>,
        host: &Fingerprint,
    ) -> (Json, ServedFrom) {
        let target =
            self.fingerprint(platform).or(request_fp).unwrap_or(host).clone();
        if let Some(p) = self.portfolio(platform, kernel) {
            let mut fields = vec![
                ("found", Json::Bool(true)),
                ("source", json::s("exact")),
                ("platform", json::s(platform)),
                ("portfolio", p.to_json()),
            ];
            if let Some(dims) = dims {
                if let Some(item) = p.select_for_dims(dims, &target) {
                    fields.push(("selected", portfolio_item_json(item)));
                }
            }
            fields.push(("gen", json::int(self.generation as i64)));
            return (reply_ok(fields), ServedFrom::Index);
        }
        let rank_started = Instant::now();
        let ranked = transfer::rank_portfolios(&self.shards, &target, kernel, platform);
        obs::metrics().transfer_rank_us.record(rank_started.elapsed().as_micros() as u64);
        match ranked.into_iter().next() {
            Some(c) => {
                let from = ServedFrom::Transfer {
                    source: c.platform_key.clone(),
                    similarity_pm: (c.similarity.clamp(0.0, 1.0) * 1000.0).round() as u64,
                };
                let mut fields = vec![
                    ("found", Json::Bool(true)),
                    ("source", json::s("transfer")),
                    ("platform", json::s(&c.platform_key)),
                    ("similarity", json::num(c.similarity)),
                    ("portfolio", c.portfolio.to_json()),
                ];
                if let Some(dims) = dims {
                    if let Some(item) = c.portfolio.select_for_dims(dims, &target) {
                        fields.push(("selected", portfolio_item_json(item)));
                    }
                }
                fields.push(("gen", json::int(self.generation as i64)));
                (reply_ok(fields), from)
            }
            None => (
                reply_ok(vec![
                    ("found", Json::Bool(false)),
                    ("gen", json::int(self.generation as i64)),
                ]),
                ServedFrom::Miss,
            ),
        }
    }

    /// Shape a `report` reply: the core-hour ledger (per-platform,
    /// per-kernel spend / benefit / net / break-even) plus the active
    /// regressions, all from this snapshot's shards — so a live daemon
    /// and an offline bundle answer identically by construction.
    pub fn report_reply(&self, platform: Option<&str>) -> Json {
        let ms_to_s = |ms: f64| ms / 1000.0;
        let mut platforms = Vec::new();
        let (mut spend_ms, mut benefit_ms) = (0u64, 0u64);
        let (mut kernels_n, mut break_even_n) = (0u64, 0u64);
        for shard in &self.shards {
            if platform.is_some_and(|p| p != shard.platform_key) || shard.ledger.is_empty() {
                continue;
            }
            let mut kernels = Vec::new();
            for (kernel, cell) in &shard.ledger.cells {
                let regressing = self
                    .regressing
                    .iter()
                    .any(|(p, k, _)| *p == shard.platform_key && k == kernel);
                spend_ms += cell.spend_ms;
                benefit_ms += cell.benefit_ms;
                kernels_n += 1;
                if cell.break_even() {
                    break_even_n += 1;
                }
                kernels.push(json::obj(vec![
                    ("kernel", json::s(kernel)),
                    ("spend_core_seconds", json::num(ms_to_s(cell.spend_ms as f64))),
                    ("benefit_core_seconds", json::num(ms_to_s(cell.benefit_ms as f64))),
                    ("net_core_seconds", json::num(ms_to_s(cell.net_ms() as f64))),
                    ("invocations", json::int(cell.invocations as i64)),
                    ("tunes", json::int(cell.tunes as i64)),
                    ("break_even", Json::Bool(cell.break_even())),
                    (
                        "break_even_eta_s",
                        cell.break_even_eta_s().map(|s| json::int(s as i64)).unwrap_or(Json::Null),
                    ),
                    ("regressing", Json::Bool(regressing)),
                ]));
            }
            platforms.push(json::obj(vec![
                ("platform", json::s(&shard.platform_key)),
                ("kernels", Json::Arr(kernels)),
            ]));
        }
        let mut flagged: Vec<&(String, String, String)> = self
            .regressing
            .iter()
            .filter(|(p, _, _)| platform.is_none_or(|want| want == p))
            .collect();
        flagged.sort();
        let regressions: Vec<Json> = flagged
            .into_iter()
            .map(|(p, k, t)| {
                json::obj(vec![
                    ("platform", json::s(p)),
                    ("kernel", json::s(k)),
                    ("workload", json::s(t)),
                ])
            })
            .collect();
        reply_ok(vec![
            (
                "report",
                json::obj(vec![
                    ("platforms", Json::Arr(platforms)),
                    (
                        "totals",
                        json::obj(vec![
                            ("spend_core_seconds", json::num(ms_to_s(spend_ms as f64))),
                            ("benefit_core_seconds", json::num(ms_to_s(benefit_ms as f64))),
                            (
                                "net_core_seconds",
                                json::num(ms_to_s(benefit_ms as f64 - spend_ms as f64)),
                            ),
                            ("kernels", json::int(kernels_n as i64)),
                            ("break_even", json::int(break_even_n as i64)),
                            ("regressions_active", json::int(regressions.len() as i64)),
                        ]),
                    ),
                    ("regressions", Json::Arr(regressions)),
                ]),
            ),
            ("gen", json::int(self.generation as i64)),
        ])
    }
}

/// Compact wire view of a selected portfolio member (the part a deploy
/// client actually consumes: which config to run).
pub(crate) fn portfolio_item_json(item: &PortfolioItem) -> Json {
    json::obj(vec![
        ("config_id", json::s(&item.config_id)),
        (
            "params",
            Json::Obj(item.config.iter().map(|(k, v)| (k.clone(), json::int(*v))).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ledger::Ledger;
    use crate::coordinator::perfdb::unix_now;

    fn fp(simd: &[&str]) -> Fingerprint {
        Fingerprint {
            cpu_model: "Snap CPU".into(),
            num_cpus: 8,
            simd: simd.iter().map(|s| s.to_string()).collect(),
            cache_l1d_kb: 32,
            cache_l2_kb: 1024,
            cache_l3_kb: 8192,
            os: "linux".into(),
        }
    }

    fn entry(platform: &str, kernel: &str, tag: &str, id: &str, at: u64) -> DbEntry {
        DbEntry {
            platform_key: platform.into(),
            kernel: kernel.into(),
            tag: tag.into(),
            best_params: [("block_size".to_string(), 256i64)].into_iter().collect(),
            best_config_id: id.into(),
            best_time_s: 1e-3,
            baseline_time_s: 2e-3,
            reference_time_s: 9e-4,
            evaluations: 4,
            strategy: "exhaustive".into(),
            recorded_at: at,
        }
    }

    fn shard(platform: &str, fingerprint: Option<Fingerprint>, entries: Vec<DbEntry>) -> Shard {
        Shard { platform_key: platform.into(), fingerprint, entries, portfolios: Vec::new(), ledger: Ledger::default() }
    }

    #[test]
    fn frontier_index_keeps_newest_entry_per_key() {
        let now = unix_now();
        let snap = ServeSnapshot::build(
            vec![shard(
                "p1",
                None,
                vec![
                    entry("p1", "axpy", "n4096", "old", now - 100),
                    entry("p1", "axpy", "n4096", "new", now),
                    entry("p1", "dot", "n4096", "other", now),
                ],
            )],
            7,
        );
        assert_eq!(snap.generation(), 7);
        assert_eq!(snap.lookup("p1", "axpy", "n4096").unwrap().best_config_id, "new");
        assert_eq!(snap.index_len(), 2);
        assert!(snap.lookup("p1", "axpy", "n9999").is_none());
    }

    #[test]
    fn replies_echo_the_generation() {
        let snap = ServeSnapshot::build(
            vec![shard("p1", None, vec![entry("p1", "axpy", "n4096", "cfg", unix_now())])],
            42,
        );
        let (hit, from) = snap.lookup_reply("p1", "axpy", "n4096");
        assert_eq!(from, ServedFrom::Index);
        assert_eq!(hit.get("gen").and_then(Json::as_u64), Some(42));
        let (miss, from) = snap.lookup_reply("p1", "axpy", "n8192");
        assert_eq!(from, ServedFrom::Miss);
        assert_eq!(miss.get("found").and_then(Json::as_bool), Some(false));
        assert_eq!(miss.get("gen").and_then(Json::as_u64), Some(42));
    }

    #[test]
    fn deploy_miss_ranks_transfer_candidates_for_target_fingerprint() {
        let host = fp(&["avx2", "fma"]);
        let mut far = fp(&["neon"]);
        far.os = "macos".into();
        let snap = ServeSnapshot::build(
            vec![
                shard(
                    "near-p",
                    Some(fp(&["avx2", "fma"])),
                    vec![entry("near-p", "axpy", "n4096", "near_cfg", unix_now())],
                ),
                shard(
                    "far-p",
                    Some(far),
                    vec![entry("far-p", "axpy", "n4096", "far_cfg", unix_now())],
                ),
            ],
            1,
        );
        let (reply, from) =
            snap.deploy_reply("fresh", "axpy", "n4096", Some(&fp(&["avx2", "fma"])), &host);
        assert_eq!(reply.get("source").and_then(Json::as_str), Some("transfer"));
        let cands = reply.get("candidates").and_then(Json::as_arr).unwrap();
        assert_eq!(cands[0].get("config_id").and_then(Json::as_str), Some("near_cfg"));
        match from {
            ServedFrom::Transfer { source, .. } => assert_eq!(source, "near-p"),
            other => panic!("expected a transfer answer, got {other:?}"),
        }
    }

    #[test]
    fn portfolio_total_miss_reports_not_found() {
        let snap = ServeSnapshot::build(Vec::new(), 3);
        let (reply, from) = snap.portfolio_reply("p1", "gemm", None, None, &fp(&["avx2"]));
        assert_eq!(from, ServedFrom::Miss);
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(false));
        assert_eq!(reply.get("gen").and_then(Json::as_u64), Some(3));
    }
}
