//! Tuning-as-a-service: the deployment path of the paper, run as a
//! long-lived daemon instead of a one-shot CLI lookup.
//!
//! Mametjanov & Norris's sustainability argument is that empirical
//! tuning pays for itself because its results persist — "specialization
//! of programs to platforms ... across various systems and system
//! changes."  The seed repo realized that as `portatune deploy`: open a
//! JSON file, look up a key, print an artifact path.  That shape cannot
//! serve production traffic (per-process whole-file reads, last-writer
//! -wins saves, and an unknown platform gets nothing at all).  This
//! module is the production shape:
//!
//! * [`server`] — `portatune serve`: a daemon answering
//!   lookup/deploy/record over a line-delimited JSON protocol (TCP or
//!   Unix socket) from an immutable, atomically published
//!   [`snapshot::ServeSnapshot`] over the sharded store
//!   ([`crate::coordinator::perfdb::ShardedDb`], one lock-file-merged
//!   shard per platform) — readers never take a writer lock; writers
//!   clone-merge-publish a new generation — with a bounded worker-pool
//!   accept loop and a background staleness scan + re-tune worker;
//! * [`snapshot`] — the immutable serve-path state itself, including
//!   the reply shaping shared by the daemon and the offline bundle
//!   client (identical answers by construction);
//! * [`bundle`] — versioned, checksummed offline decision bundles:
//!   `portatune bundle export` packs a daemon's shards + portfolios +
//!   fingerprint into one artifact that [`client::Client::from_bundle`]
//!   answers from with zero round-trips and `portatune bundle import`
//!   merges into a fresh daemon's store;
//! * [`protocol`] — the wire format (std-only, reuses
//!   [`crate::util::json`]);
//! * [`client`] — what `portatune query` and embedders speak;
//! * [`transfer`] — fingerprint-similarity ranking for tuned entries
//!   AND variant portfolios, so a deploy or `portfolio` miss on a
//!   never-seen platform answers with the nearest platform's results
//!   (the cross-device transfer result of "A Few Fit Most", Hochgraf &
//!   Pai 2025) instead of an empty miss;
//! * [`scheduler`] — the leased [`TaskQueue`] of typed tuning tasks
//!   (retune / sweep / portfolio-rebuild) that the staleness scan
//!   feeds and the `portatune work` fleet drains: `task-lease` checks
//!   a task out under a TTL, `task-heartbeat` extends it,
//!   `task-complete`/`task-fail` settle it, and an expired lease
//!   requeues automatically so a crashed worker never loses work (the
//!   persistent runtime-service shape of Kernel Tuning Toolkit,
//!   Petrovič et al. 2019, plus portfolio maintenance from "A Few Fit
//!   Most").
//! * [`audit`] — the tamper-evident decision log: every lease /
//!   settle / requeue / record / serve answer (with its reason) is a
//!   typed, hash-chained entry; `portatune audit verify` proves the
//!   log unaltered and `portatune audit replay` re-derives a
//!   platform's decision sequence.
//! * [`sentinel`] — the regression sentinel: a windowed-EWMA drift
//!   detector over the live `record` stream that flags served configs
//!   gone slow, audits the evidence, and enqueues evidence-driven
//!   retune tasks (paired with the per-shard core-hour ledger in
//!   [`crate::coordinator::ledger`], surfaced by the `report` op).
//! * [`faults`] — the deterministic fault-injection harness behind
//!   `tests/chaos.rs`: a seeded [`FaultPlan`] fires connection drops,
//!   read/write stalls, torn shard writes, lease-settle delays, and
//!   worker crashes at named points across the serve/work path, so
//!   the recovery machinery (client retry + request-id dedupe, lease
//!   expiry, shard quarantine) is exercised on demand instead of only
//!   in production incidents.

pub mod audit;
pub mod bundle;
pub mod client;
pub mod faults;
pub mod protocol;
pub mod scheduler;
pub mod sentinel;
pub mod server;
pub mod snapshot;
pub mod transfer;

pub use audit::{AuditEntry, AuditEvent, AuditLog, ServeReason, VerifyError, VerifyReport};
pub use bundle::{parse_bundle, write_bundle, BundleMeta, OfflineBundle, BUNDLE_MAGIC};
pub use client::{Client, Endpoint, LeasedTask, RetryPolicy};
pub use faults::{FaultPlan, InjectionPoint};
pub use protocol::{reply_err, reply_ok, Request};
pub use scheduler::{
    CompleteOutcome, ExpireReport, FailOutcome, StaleReason, TaskKind, TaskQueue, TuningTask,
    DEFAULT_LEASE_TTL_S,
};
pub use sentinel::{Sentinel, SentinelConfig, SentinelEvent, SentinelKey};
pub use server::{Lru, ServeOpts, ServeStats, Server};
pub use snapshot::{ServeSnapshot, ServedFrom};
pub use transfer::{
    rank_candidates, rank_portfolios, warm_start_configs, PortfolioCandidate, TransferCandidate,
};
