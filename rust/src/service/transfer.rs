//! Fingerprint-similarity transfer: warm-start candidates for a
//! platform the store has never seen.
//!
//! The paper's sustainability claim is that tuned configurations
//! outlive one machine; "A Few Fit Most" (Hochgraf & Pai, 2025) shows a
//! small set of tuned variants transfers across devices.  This module
//! is the ranking half of that story: given every shard's
//! [`Fingerprint`] and a target platform, score similarity
//! ([`Fingerprint::similarity`]: SIMD ISA overlap, cache geometry, core
//! count, OS) and return each nearby platform's frontier entries,
//! nearest platform first.  It replaces [`PerfDb::warm_start`]'s
//! exclude-only heuristic (which ranked by recorded speedup alone and
//! treated a disjoint-ISA machine as seriously as a sibling box).
//!
//! [`PerfDb::warm_start`]: crate::coordinator::perfdb::PerfDb::warm_start

use std::collections::HashSet;

use crate::coordinator::perfdb::{DbEntry, Shard};
use crate::coordinator::platform::Fingerprint;
use crate::coordinator::portfolio::Portfolio;
use crate::coordinator::spec::Config;

/// One ranked warm-start candidate.
#[derive(Debug, Clone)]
pub struct TransferCandidate {
    /// Where the entry was recorded.
    pub platform_key: String,
    /// Similarity of that platform to the target, in [0, 1].
    pub similarity: f64,
    /// Whether the entry's workload tag matches the requested one.
    pub same_workload: bool,
    /// The borrowed tuning record.
    pub entry: DbEntry,
}

/// Similarity floor below which a platform contributes no candidates —
/// a disjoint-ISA, alien-cache machine's optimum is noise, not signal.
pub const MIN_SIMILARITY: f64 = 0.05;

/// Rank warm-start candidates for `kernel`/`tag` on a platform with
/// fingerprint `target`.
///
/// Ordering: similarity (descending), then same-workload entries before
/// other workloads of the same kernel, then recorded speedup.  Shards
/// without a stored fingerprint score [`MIN_SIMILARITY`] exactly (they
/// are admissible but rank behind every scored platform).  The target's
/// own shard (`exclude_key`) and other kernels never contribute.
/// Candidates are deduped by winning config id, keeping the
/// highest-ranked occurrence.
pub fn rank_candidates(
    shards: &[Shard],
    target: &Fingerprint,
    kernel: &str,
    tag: &str,
    exclude_key: &str,
) -> Vec<TransferCandidate> {
    let mut out: Vec<TransferCandidate> = Vec::new();
    for shard in shards {
        if shard.platform_key == exclude_key {
            continue;
        }
        let similarity = match &shard.fingerprint {
            Some(fp) => target.similarity(fp),
            None => MIN_SIMILARITY,
        };
        if similarity < MIN_SIMILARITY {
            continue;
        }
        for entry in shard.frontier() {
            if entry.kernel != kernel || entry.best_config_id == "baseline" {
                continue;
            }
            out.push(TransferCandidate {
                platform_key: shard.platform_key.clone(),
                similarity,
                same_workload: entry.tag == tag,
                entry: entry.clone(),
            });
        }
    }
    out.sort_by(|a, b| {
        b.similarity
            .total_cmp(&a.similarity)
            .then(b.same_workload.cmp(&a.same_workload))
            .then(b.entry.speedup().total_cmp(&a.entry.speedup()))
    });
    let mut seen: HashSet<String> = HashSet::new();
    out.retain(|c| seen.insert(c.entry.best_config_id.clone()));
    out
}

/// The configs to seed a tuner's warm start with, rank order preserved,
/// capped (transfer is a seeding heuristic — evaluating the whole
/// store's frontier would turn the warm start back into a search).
pub fn warm_start_configs(candidates: &[TransferCandidate], cap: usize) -> Vec<Config> {
    candidates.iter().take(cap).map(|c| c.entry.best_params.clone()).collect()
}

/// A portfolio recorded on another platform, ranked by fingerprint
/// similarity to the target — what a `portfolio` op answers with when
/// the asking platform never built one itself.
#[derive(Debug, Clone)]
pub struct PortfolioCandidate {
    /// Where the portfolio was built.
    pub platform_key: String,
    /// Similarity of that platform to the target, in [0, 1].
    pub similarity: f64,
    /// The candidate portfolio itself.
    pub portfolio: Portfolio,
}

/// Rank other platforms' portfolios for `kernel` by fingerprint
/// similarity to `target`, nearest first (ties broken by retained
/// coverage).  The same admissibility rules as entry transfer apply:
/// the target's own shard is excluded, fingerprintless shards score
/// [`MIN_SIMILARITY`] exactly, and anything below that floor is noise.
pub fn rank_portfolios(
    shards: &[Shard],
    target: &Fingerprint,
    kernel: &str,
    exclude_key: &str,
) -> Vec<PortfolioCandidate> {
    let mut out: Vec<PortfolioCandidate> = Vec::new();
    for shard in shards {
        if shard.platform_key == exclude_key {
            continue;
        }
        let similarity = match &shard.fingerprint {
            Some(fp) => target.similarity(fp),
            None => MIN_SIMILARITY,
        };
        if similarity < MIN_SIMILARITY {
            continue;
        }
        if let Some(p) = shard.portfolio(kernel) {
            out.push(PortfolioCandidate {
                platform_key: shard.platform_key.clone(),
                similarity,
                portfolio: p.clone(),
            });
        }
    }
    out.sort_by(|a, b| {
        b.similarity
            .total_cmp(&a.similarity)
            .then(b.portfolio.retained.total_cmp(&a.portfolio.retained))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(simd: &[&str], l1: u64, l2: u64, l3: u64, cores: usize) -> Fingerprint {
        Fingerprint {
            cpu_model: "test".into(),
            num_cpus: cores,
            simd: simd.iter().map(|s| s.to_string()).collect(),
            cache_l1d_kb: l1,
            cache_l2_kb: l2,
            cache_l3_kb: l3,
            os: "linux".into(),
        }
    }

    fn entry(platform: &str, kernel: &str, tag: &str, id: &str, speedup: f64) -> DbEntry {
        DbEntry {
            platform_key: platform.into(),
            kernel: kernel.into(),
            tag: tag.into(),
            best_params: [("block_size".to_string(), 1024i64)].into_iter().collect(),
            best_config_id: id.into(),
            best_time_s: 1e-3,
            baseline_time_s: 1e-3 * speedup,
            reference_time_s: 9e-4,
            evaluations: 9,
            strategy: "exhaustive".into(),
            recorded_at: 1_700_000_000,
        }
    }

    fn shard(key: &str, fp: Option<Fingerprint>, entries: Vec<DbEntry>) -> Shard {
        Shard { platform_key: key.into(), fingerprint: fp, entries, portfolios: Vec::new(), ledger: Ledger::default() }
    }

    #[test]
    fn near_platform_outranks_disjoint_isa_despite_lower_speedup() {
        let target = fp(&["sse2", "avx", "avx2"], 32, 1024, 33792, 8);
        let near = fp(&["sse2", "avx", "avx2"], 32, 512, 33792, 8);
        let far = fp(&["neon"], 128, 4096, 0, 64);
        let shards = vec![
            shard("far", Some(far), vec![entry("far", "axpy", "n4096", "far_cfg", 9.9)]),
            shard("near", Some(near), vec![entry("near", "axpy", "n4096", "near_cfg", 1.2)]),
        ];
        let ranked = rank_candidates(&shards, &target, "axpy", "n4096", "local");
        assert_eq!(ranked.len(), 2);
        assert_eq!(ranked[0].entry.best_config_id, "near_cfg");
        assert!(ranked[0].similarity > ranked[1].similarity);
    }

    #[test]
    fn excludes_own_platform_and_other_kernels() {
        let target = fp(&["avx2"], 32, 1024, 8192, 8);
        let shards = vec![
            shard(
                "local",
                Some(target.clone()),
                vec![entry("local", "axpy", "n4096", "own", 2.0)],
            ),
            shard(
                "other",
                Some(target.clone()),
                vec![
                    entry("other", "dot", "n4096", "wrong_kernel", 3.0),
                    entry("other", "axpy", "n4096", "right", 1.5),
                ],
            ),
        ];
        let ranked = rank_candidates(&shards, &target, "axpy", "n4096", "local");
        assert_eq!(ranked.len(), 1);
        assert_eq!(ranked[0].entry.best_config_id, "right");
    }

    #[test]
    fn same_workload_breaks_similarity_ties() {
        let target = fp(&["avx2"], 32, 1024, 8192, 8);
        let twin = target.clone();
        let shards = vec![shard(
            "twin",
            Some(twin),
            vec![
                entry("twin", "axpy", "n65536", "other_tag", 5.0),
                entry("twin", "axpy", "n4096", "same_tag", 1.2),
            ],
        )];
        let ranked = rank_candidates(&shards, &target, "axpy", "n4096", "local");
        assert_eq!(ranked[0].entry.best_config_id, "same_tag");
    }

    #[test]
    fn fingerprintless_shards_rank_last_but_contribute() {
        let target = fp(&["avx2"], 32, 1024, 8192, 8);
        let shards = vec![
            shard("legacy", None, vec![entry("legacy", "axpy", "n4096", "legacy_cfg", 9.0)]),
            shard(
                "scored",
                Some(target.clone()),
                vec![entry("scored", "axpy", "n4096", "scored_cfg", 1.1)],
            ),
        ];
        let ranked = rank_candidates(&shards, &target, "axpy", "n4096", "local");
        assert_eq!(ranked.len(), 2);
        assert_eq!(ranked[0].entry.best_config_id, "scored_cfg");
        assert_eq!(ranked[1].similarity, MIN_SIMILARITY);
    }

    #[test]
    fn dedupes_by_config_id_and_caps_configs() {
        let target = fp(&["avx2"], 32, 1024, 8192, 8);
        let shards = vec![
            shard(
                "a",
                Some(target.clone()),
                vec![entry("a", "axpy", "n4096", "dup", 1.5)],
            ),
            shard(
                "b",
                Some(target.clone()),
                vec![
                    entry("b", "axpy", "n4096", "dup", 1.4),
                    entry("b", "axpy", "n65536", "uniq", 1.3),
                ],
            ),
        ];
        let ranked = rank_candidates(&shards, &target, "axpy", "n4096", "local");
        assert_eq!(ranked.len(), 2, "dup config id collapses");
        let configs = warm_start_configs(&ranked, 1);
        assert_eq!(configs.len(), 1);
    }

    fn portfolio(kernel: &str, retained: f64) -> Portfolio {
        use crate::coordinator::ledger::Ledger;
        use crate::coordinator::portfolio::{PortfolioItem, FEATURE_NAMES};
        Portfolio {
            kernel: kernel.into(),
            strategy: "greedy-cover".into(),
            k_max: 4,
            retained,
            built_at: 1_700_000_000,
            feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
            items: vec![PortfolioItem {
                config: [("tile_m".to_string(), 32i64)].into_iter().collect(),
                config_id: "o1_tm32_tn32_u4".into(),
                centroid: vec![5.0; FEATURE_NAMES.len()],
                covered: vec!["m32n32k32".into()],
            }],
        }
    }

    #[test]
    fn portfolios_rank_nearest_platform_first() {
        let target = fp(&["sse2", "avx", "avx2"], 32, 1024, 33792, 8);
        let near = fp(&["sse2", "avx", "avx2"], 32, 512, 33792, 8);
        let far = fp(&["neon"], 128, 4096, 0, 64);
        let mut near_shard = shard("near", Some(near), vec![]);
        near_shard.portfolios = vec![portfolio("gemm", 0.91)];
        let mut far_shard = shard("far", Some(far), vec![]);
        far_shard.portfolios = vec![portfolio("gemm", 0.99)];
        let mut own = shard("local", Some(target.clone()), vec![]);
        own.portfolios = vec![portfolio("gemm", 1.0)];
        let mut wrong_kernel = shard("other", Some(target.clone()), vec![]);
        wrong_kernel.portfolios = vec![portfolio("axpy", 1.0)];
        let shards = vec![far_shard, near_shard, own, wrong_kernel];
        let ranked = rank_portfolios(&shards, &target, "gemm", "local");
        assert_eq!(ranked.len(), 2, "own shard and other kernels are excluded");
        assert_eq!(ranked[0].platform_key, "near");
        assert!(ranked[0].similarity > ranked[1].similarity);
    }

    #[test]
    fn fingerprintless_portfolios_rank_last_but_contribute() {
        let target = fp(&["avx2"], 32, 1024, 8192, 8);
        let mut legacy = shard("legacy", None, vec![]);
        legacy.portfolios = vec![portfolio("gemm", 0.99)];
        let mut scored = shard("scored", Some(target.clone()), vec![]);
        scored.portfolios = vec![portfolio("gemm", 0.90)];
        let ranked = rank_portfolios(&[legacy, scored], &target, "gemm", "local");
        assert_eq!(ranked.len(), 2);
        assert_eq!(ranked[0].platform_key, "scored");
        assert_eq!(ranked[1].similarity, MIN_SIMILARITY);
    }

    #[test]
    fn baseline_records_are_not_candidates() {
        let target = fp(&["avx2"], 32, 1024, 8192, 8);
        let shards = vec![shard(
            "a",
            Some(target.clone()),
            vec![entry("a", "axpy", "n4096", "baseline", 1.0)],
        )];
        assert!(rank_candidates(&shards, &target, "axpy", "n4096", "local").is_empty());
    }
}
