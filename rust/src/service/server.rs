//! The `portatune serve` daemon core.
//!
//! A [`Server`] owns a [`ShardedDb`], the host [`Fingerprint`], the
//! published [`ServeSnapshot`], per-op counters, and the leased
//! [`TaskQueue`].  Request handling is a pure function from
//! [`Request`] to a JSON reply ([`Server::handle_request`]), so the
//! same core serves TCP, Unix sockets, in-process tests, and the
//! throughput bench without touching a socket.
//!
//! Serve-path state model: readers never take a writer lock.  All hot
//! read state lives in an immutable [`ServeSnapshot`] behind
//! `RwLock<Arc<_>>` (read-mostly discipline: a reader clones the `Arc`
//! under a read lock — nanoseconds, never held across I/O — and then
//! works entirely lock-free on shared immutable data).  Writers
//! (`record`, `record-portfolio`, the re-tune worker, the periodic
//! scan) commit to disk first, then clone-merge-publish a new snapshot
//! under a dedicated publish mutex, bumping a monotone generation that
//! every reply echoes as `gen` — which is what makes read-your-writes
//! checkable: a read started after an acked write always reports a
//! generation ≥ the ack's.
//!
//! Threading model: `std` only.  The accept loop is non-blocking,
//! polls a shutdown flag, and hands prepared connections to a bounded
//! worker pool ([`ServeOpts::workers`] threads over a condvar'd accept
//! queue) — connection shed at [`ServeOpts::max_conns`] counts queued
//! plus in-service connections, and idle reaping happens inside
//! [`Server::serve_connection`] exactly as before.  Background
//! threads: a periodic staleness scan (which also republishes the
//! snapshot, bounding out-of-band-writer staleness), and — when the
//! daemon was started with a usable artifact registry — a re-tune
//! worker that drains the queue through the batched [`Tuner`].
//! External `portatune work` processes drain everything else via the
//! `task-lease`/`task-heartbeat`/`task-complete`/`task-fail` ops (see
//! [`crate::service::scheduler`]).
//!
//! Panic policy: request handling must never take the daemon down on
//! client input.  Malformed lines and bad payloads become
//! `{"ok":false}` replies in [`Request::parse_line`] / the dispatch
//! `Result`; the remaining `unwrap`-shaped hazards were lock-poison
//! unwraps on the shared state, which the module-private `lock()` /
//! `read_lock()` / `write_lock()` helpers recover from instead (every
//! critical section leaves the guarded value consistent — a published
//! snapshot is immutable, so a panicking writer can at worst leave the
//! previous generation serving).

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::hash::Hash;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, RwLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::ledger::LedgerDelta;
use crate::coordinator::perfdb::{unix_now, DbEntry, Shard, ShardedDb};
use crate::coordinator::platform::Fingerprint;
use crate::coordinator::search::Exhaustive;
use crate::coordinator::tuner::Tuner;
use crate::obs::{self, trace};
use crate::runtime::Registry;
use crate::service::audit::{AuditEvent, AuditLog, ServeReason};
use crate::service::faults::{self, InjectionPoint};
use crate::service::protocol::{reply_err, reply_ok, Request};
use crate::service::scheduler::{
    CompleteOutcome, FailOutcome, StaleReason, TaskKind, TaskQueue, TuningTask,
    DEFAULT_LEASE_TTL_S,
};
use crate::service::sentinel::{Sentinel, SentinelEvent};
use crate::service::snapshot::{ServeSnapshot, ServedFrom};
use crate::util::json::{self, Json};

/// Lock a mutex, recovering from poisoning: the guarded state (the
/// scheduler, the dedupe cache, the publish token) stays consistent
/// under panics because every critical section only mutates it through
/// its own methods — serving slightly-stale data beats killing the
/// daemon.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Take a read lock, recovering from poisoning: the guarded value is
/// an `Arc` to an immutable snapshot, so a panicking writer can never
/// leave it torn — at worst the previous generation keeps serving.
fn read_lock<T>(l: &RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Take a write lock, recovering from poisoning (see [`read_lock`]).
fn write_lock<T>(l: &RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// How long the accept loop sleeps between polls of the shutdown flag.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Read timeout on accepted connections: idle sockets wake their
/// handler this often so it can observe the shutdown flag.
const CONN_READ_TIMEOUT: Duration = Duration::from_millis(500);

/// Upper bound on a client-requested lease TTL: a typo'd `ttl_s`
/// must not pin a task in flight until daemon restart — past this the
/// lease expires and the task requeues like any other silent worker's.
const MAX_LEASE_TTL_S: u64 = 24 * 3600;

/// Reply-dedupe cache capacity: one entry per recent non-idempotent
/// request id (`record` / `task-complete`).  Sized like the
/// scheduler's settled-lease memory — far larger than any plausible
/// client retry window.
const DEDUPE_KEEP: usize = 4096;

/// A small clock-stamped LRU: `get` refreshes the stamp, `put` evicts
/// the least-recently-stamped entry when full.  Eviction is O(n) over
/// the map, which is the right trade at reply-dedupe sizes (hundreds
/// to thousands) against the pointer gymnastics of an intrusive list.
/// `cap == 0` disables storage entirely (every get misses).
#[derive(Debug)]
pub struct Lru<K: Eq + Hash + Clone, V: Clone> {
    cap: usize,
    tick: u64,
    map: HashMap<K, (u64, V)>,
}

impl<K: Eq + Hash + Clone, V: Clone> Lru<K, V> {
    /// An LRU holding at most `cap` entries (0 disables storage).
    pub fn new(cap: usize) -> Lru<K, V> {
        Lru { cap, tick: 0, map: HashMap::new() }
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Fetch (and freshness-stamp) a cached value.
    pub fn get(&mut self, key: &K) -> Option<V> {
        self.tick += 1;
        let tick = self.tick;
        let (stamp, value) = self.map.get_mut(key)?;
        *stamp = tick;
        Some(value.clone())
    }

    /// Insert a value, evicting the least-recently-stamped entry when
    /// full.
    pub fn put(&mut self, key: K, value: V) {
        if self.cap == 0 {
            return;
        }
        self.tick += 1;
        if self.map.len() >= self.cap && !self.map.contains_key(&key) {
            if let Some(oldest) =
                self.map.iter().min_by_key(|(_, (stamp, _))| *stamp).map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
        self.map.insert(key, (self.tick, value));
    }

    /// Drop one key (cache invalidation).
    pub fn remove(&mut self, key: &K) {
        self.map.remove(key);
    }

    /// Keep only entries whose key satisfies the predicate (bulk
    /// invalidation, e.g. "everything for this platform").
    pub fn retain(&mut self, mut keep: impl FnMut(&K) -> bool) {
        self.map.retain(|k, _| keep(k));
    }
}

/// Daemon configuration.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Entries older than this are queued for re-tuning.
    pub ttl_s: u64,
    /// Worker-pool size for the accept loop (0 picks a default from
    /// the machine's available parallelism, clamped to `2..=32`).
    /// Connections past the pool wait on a bounded accept queue; the
    /// queue plus in-service connections together are capped by
    /// [`ServeOpts::max_conns`].
    pub workers: usize,
    /// Lease TTL granted when a `task-lease` request names none (and
    /// backing the `retune-next` compatibility alias).
    pub lease_ttl_s: u64,
    /// Maximum concurrently-served connections (0 disables the cap).
    /// Past the cap, a new connection gets a single retryable
    /// `overloaded` error reply and is dropped (shed) instead of
    /// queueing a handler thread without bound.
    pub max_conns: usize,
    /// Per-connection idle deadline in seconds (0 disables it): a
    /// connection that completes no request for this long is closed,
    /// so a stalled or wedged client cannot pin its handler thread
    /// forever.
    pub conn_idle_s: u64,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            // 30 days: tuned configs outlive any one deploy cycle but
            // not a hardware refresh.
            ttl_s: 30 * 24 * 3600,
            workers: 0,
            lease_ttl_s: DEFAULT_LEASE_TTL_S,
            max_conns: 256,
            conn_idle_s: 300,
        }
    }
}

/// Monotonic per-op counters (reported by the `stats` op and mirrored
/// into `report::stats::serve_stats_json`).
#[derive(Debug, Default)]
struct Counters {
    lookups: AtomicU64,
    deploys: AtomicU64,
    lru_hits: AtomicU64,
    shard_reads: AtomicU64,
    records: AtomicU64,
    transfer_misses: AtomicU64,
    portfolios: AtomicU64,
    portfolio_transfers: AtomicU64,
    tasks_queued: AtomicU64,
    tasks_leased: AtomicU64,
    tasks_completed: AtomicU64,
    tasks_failed: AtomicU64,
    leases_expired: AtomicU64,
    retunes: AtomicU64,
    errors: AtomicU64,
    dedup_hits: AtomicU64,
    conns_shed: AtomicU64,
    conns_closed_idle: AtomicU64,
    snapshot_publishes: AtomicU64,
    regressions: AtomicU64,
}

/// Point-in-time snapshot of the daemon's counters (the serve-side
/// analogue of [`crate::coordinator::tuner::TuneStats`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ServeStats {
    /// `lookup` ops served.
    pub lookups: u64,
    /// `deploy` ops served.
    pub deploys: u64,
    /// Reads answered from the published snapshot's decision index
    /// (exact hits and indexed negatives alike — every read that never
    /// touched disk).  The name predates the snapshot refactor and is
    /// kept for dashboard continuity.
    pub lru_hits: u64,
    /// Shard-directory loads performed by snapshot publishes and
    /// refreshes (reads happen at publish time now, not per lookup).
    pub shard_reads: u64,
    /// `record` ops served.
    pub records: u64,
    /// Deploy misses answered via transfer ranking.
    pub transfer_misses: u64,
    /// `portfolio` ops served.
    pub portfolios: u64,
    /// `portfolio` ops that missed locally and answered via transfer.
    pub portfolio_transfers: u64,
    /// Tasks the staleness scan has queued.
    pub tasks_queued: u64,
    /// Leases handed out (`task-lease` + `retune-next` + the local
    /// re-tune worker).
    pub tasks_leased: u64,
    /// Tasks settled successfully (`task-complete`, deduplicated).
    pub tasks_completed: u64,
    /// Tasks settled as failed (`task-fail`).
    pub tasks_failed: u64,
    /// Leases whose holders went silent past their TTL (each one
    /// requeued its task).
    pub leases_expired: u64,
    /// Re-tunes the daemon's own in-process worker completed.
    pub retunes: u64,
    /// Requests that errored (malformed lines included).
    pub errors: u64,
    /// Retried non-idempotent requests answered by replaying the
    /// stored reply instead of re-executing (request-id dedupe).
    pub dedup_hits: u64,
    /// Connections shed with an `overloaded` reply at the connection
    /// cap.
    pub conns_shed: u64,
    /// Connections closed for exceeding the idle deadline.
    pub conns_closed_idle: u64,
    /// Pending (not-yet-leased) task count.
    pub tasks_pending: u64,
    /// Currently-leased task count.
    pub tasks_inflight: u64,
    /// Pending queue depth per task kind (`retune`, `sweep`,
    /// `portfolio-rebuild`).
    pub queue_depth: BTreeMap<String, u64>,
    /// Decision-index size of the published snapshot (frontier entries
    /// plus portfolios).  The name predates the snapshot refactor.
    pub lru_len: u64,
    /// Generation of the currently published [`ServeSnapshot`] — a
    /// gauge; every reply echoes it as `gen`.
    pub snapshot_gen: u64,
    /// Snapshot publishes since startup (writer commits + refreshes).
    pub snapshot_publishes: u64,
    /// Abandoned shard lock files removed this process — stolen in-band
    /// by contending writers plus swept by the periodic scan.
    pub stale_locks_reaped: u64,
    /// Quarantined (`.corrupt.<ts>`) shard corpses currently on disk —
    /// a live gauge, not a counter: pruning and operator cleanup lower
    /// it.
    pub shards_quarantined: u64,
    /// Regressions the sentinel has confirmed since startup (each one
    /// audited and answered with an evidence-driven retune task).
    pub regressions: u64,
    /// (platform, kernel, workload) keys currently flagged as
    /// regressing — a live gauge; recovery and retunes lower it.
    pub regressions_active: u64,
    /// Cumulative tuning spend across the published snapshot's
    /// ledgers, core-milliseconds (persistent: survives restarts with
    /// the shards).
    pub tuning_spend_ms: u64,
    /// Cumulative realized benefit across the published snapshot's
    /// ledgers, core-milliseconds.
    pub tuning_benefit_ms: u64,
}

/// The daemon: shard store + published snapshot + scheduler +
/// counters.
pub struct Server {
    db: ShardedDb,
    host: Fingerprint,
    host_key: String,
    opts: ServeOpts,
    /// The published read state.  Readers clone the `Arc` under a read
    /// lock (held for nanoseconds, never across I/O) and then serve
    /// entirely from the immutable snapshot; only [`Self::publish`]
    /// swaps it, under a write lock held just for the pointer store.
    snapshot: RwLock<Arc<ServeSnapshot>>,
    /// Serializes snapshot builders.  Writers hold this across their
    /// load-merge-build so two concurrent publishes cannot interleave
    /// into a lost update; readers never touch it.
    publish: Mutex<()>,
    scheduler: Mutex<TaskQueue>,
    /// Replies to recent non-idempotent requests, keyed by the
    /// client-sent request id.  A retry whose first attempt's reply
    /// was lost in flight replays the stored reply instead of
    /// re-executing (double-recording an entry, re-settling a lease).
    dedupe: Mutex<Lru<String, Json>>,
    counters: Counters,
    shutdown: AtomicBool,
    /// The tamper-evident decision log, attached once via
    /// [`Self::enable_audit`].  Optional — a daemon without one serves
    /// identically, it just leaves no trail.  Append failures bump the
    /// error counter but never fail the request being served: audit is
    /// evidence, not a write barrier.
    audit: OnceLock<Arc<AuditLog>>,
    /// The regression sentinel over the live `record` stream (see
    /// [`crate::service::sentinel`]).  Held only for the observation
    /// itself — snapshot readers answer `regressing` lock-free from
    /// the flag set baked into each published generation.
    sentinel: Mutex<Sentinel>,
    /// (platform, kernel) ledger cells already past break-even —
    /// crossing-edge state so the `BreakEven` audit event fires once
    /// per crossing, not once per record.  Seeded from the shards at
    /// startup so a restart does not re-announce old crossings.
    broke_even: Mutex<HashSet<(String, String)>>,
}

/// The ledger accrual one accepted record contributes: the caller's
/// measured tuning spend, plus realized benefit — the default-vs-best
/// gap times the invocation count this record stands for.
fn ledger_delta(entry: &DbEntry, spend_ms: u64) -> Option<LedgerDelta> {
    let gap_s = entry.baseline_time_s - entry.best_time_s;
    let benefit_ms = if gap_s.is_finite() && gap_s > 0.0 {
        (gap_s * entry.evaluations as f64 * 1000.0).round() as u64
    } else {
        0
    };
    let at = if entry.recorded_at > 0 { entry.recorded_at } else { unix_now() };
    let delta = LedgerDelta {
        kernel: entry.kernel.clone(),
        spend_ms,
        benefit_ms,
        invocations: entry.evaluations,
        at,
    };
    (delta.spend_ms > 0 || delta.benefit_ms > 0 || delta.invocations > 0).then_some(delta)
}

impl Server {
    /// A daemon core over a shard store, serving as `host`.
    pub fn new(db: ShardedDb, host: Fingerprint, opts: ServeOpts) -> Server {
        let host_key = host.key();
        let initial = ServeSnapshot::build(db.all_shards().unwrap_or_default(), 0);
        // Ledger cells already past break-even crossed in some earlier
        // process; announcing them again would duplicate the audit
        // record of the crossing.
        let broke_even: HashSet<(String, String)> = initial
            .shards()
            .iter()
            .flat_map(|s| {
                s.ledger
                    .cells
                    .iter()
                    .filter(|(_, c)| c.break_even())
                    .map(move |(k, _)| (s.platform_key.clone(), k.clone()))
            })
            .collect();
        Server {
            db,
            host,
            host_key,
            snapshot: RwLock::new(Arc::new(initial)),
            publish: Mutex::new(()),
            scheduler: Mutex::new(TaskQueue::new(opts.ttl_s)),
            dedupe: Mutex::new(Lru::new(DEDUPE_KEEP)),
            opts,
            counters: Counters::default(),
            shutdown: AtomicBool::new(false),
            audit: OnceLock::new(),
            sentinel: Mutex::new(Sentinel::default()),
            broke_even: Mutex::new(broke_even),
        }
    }

    /// Attach the audit log.  Call once, before serving; a second call
    /// is ignored (the first log wins — swapping logs mid-flight would
    /// fork the hash chain).
    pub fn enable_audit(&self, log: Arc<AuditLog>) {
        let _ = self.audit.set(log);
    }

    /// The attached audit log, if any.
    pub fn audit_log(&self) -> Option<&Arc<AuditLog>> {
        self.audit.get()
    }

    /// Append a decision to the audit log, when one is attached.
    fn audit(&self, event: AuditEvent) {
        if let Some(log) = self.audit.get() {
            if let Err(e) = log.append(event) {
                eprintln!("audit append failed: {e:#}");
                self.bump(&self.counters.errors);
            }
        }
    }

    /// The backing shard store.
    pub fn db(&self) -> &ShardedDb {
        &self.db
    }

    /// The fingerprint the daemon serves as.
    pub fn host(&self) -> &Fingerprint {
        &self.host
    }

    /// The daemon's configuration.
    pub fn opts(&self) -> &ServeOpts {
        &self.opts
    }

    /// Whether shutdown has been requested.
    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Request the daemon stop accepting connections.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    fn bump(&self, counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// The currently published snapshot.  The read lock is held only
    /// for the `Arc` clone; the caller then serves lock-free from
    /// immutable data, unaffected by concurrent publishes.
    pub fn snapshot(&self) -> Arc<ServeSnapshot> {
        Arc::clone(&read_lock(&self.snapshot))
    }

    /// Swap in a new snapshot.  Caller must hold the publish mutex.
    fn install(&self, next: ServeSnapshot) -> u64 {
        let generation = next.generation();
        *write_lock(&self.snapshot) = Arc::new(next);
        self.bump(&self.counters.snapshot_publishes);
        generation
    }

    /// Clone-merge-publish after a write to one platform's shard:
    /// re-read that shard from disk, splice it into a copy of the
    /// current snapshot's shard list, and publish at generation+1.
    /// Returns the new generation (echoed in the writer's ack, which
    /// is what gives clients read-your-writes: any read whose reply
    /// carries `gen >= ack.gen` observes the write).
    fn publish_platform(&self, platform: &str) -> Result<u64> {
        let _publishing = lock(&self.publish);
        self.bump(&self.counters.shard_reads);
        let read_started = Instant::now();
        let fresh = self.db.load(platform)?;
        obs::metrics().shard_read_us.record(read_started.elapsed().as_micros() as u64);
        let prev = self.snapshot();
        let mut shards: Vec<Shard> = prev.shards().to_vec();
        shards.retain(|s| s.platform_key != platform);
        if let Some(shard) = fresh {
            shards.push(shard);
        }
        let next = ServeSnapshot::build(shards, prev.generation() + 1)
            .with_regressions(self.regressing_set());
        Ok(self.install(next))
    }

    /// Rebuild the snapshot from the whole shard directory.  This is
    /// the coarse publish: startup imports, the periodic scan (which
    /// bounds staleness against out-of-band shard writers), and tests
    /// that write through [`Self::db`] directly use it.  Returns the
    /// new generation.
    pub fn refresh_snapshot(&self) -> Result<u64> {
        let _publishing = lock(&self.publish);
        self.bump(&self.counters.shard_reads);
        let read_started = Instant::now();
        let shards = self.db.all_shards()?;
        obs::metrics().shard_read_us.record(read_started.elapsed().as_micros() as u64);
        let generation = self.snapshot().generation() + 1;
        let next =
            ServeSnapshot::build(shards, generation).with_regressions(self.regressing_set());
        Ok(self.install(next))
    }

    /// Currently flagged sentinel keys, as the set baked into every
    /// published snapshot (readers answer `regressing` from it without
    /// touching the sentinel lock).
    fn regressing_set(&self) -> HashSet<(String, String, String)> {
        lock(&self.sentinel).regressing_keys().into_iter().collect()
    }

    /// One-shot break-even edge detection: after a write publishes,
    /// audit a `BreakEven` event iff the (platform, kernel) ledger
    /// cell is past break-even and was not already known to be.
    fn note_break_even(&self, platform: &str, kernel: &str) {
        let snap = self.snapshot();
        let Some(cell) = snap
            .shards()
            .iter()
            .find(|s| s.platform_key == platform)
            .and_then(|s| s.ledger.cell(kernel))
        else {
            return;
        };
        if !cell.break_even() {
            // A cell can sink back under water (new spend): forget the
            // crossing so the *next* one is announced again.
            lock(&self.broke_even).remove(&(platform.to_string(), kernel.to_string()));
            return;
        }
        if lock(&self.broke_even).insert((platform.to_string(), kernel.to_string())) {
            self.audit(AuditEvent::BreakEven {
                platform: platform.to_string(),
                kernel: kernel.to_string(),
                spend_ms: cell.spend_ms,
                benefit_ms: cell.benefit_ms,
            });
        }
    }

    /// Pack the published snapshot into an offline decision bundle
    /// (see [`crate::service::bundle`]): every shard's on-disk
    /// document verbatim where one exists (byte-identical round-trips)
    /// plus the host fingerprint and the snapshot generation, so
    /// offline answers carry the same `gen` a live reply would.
    pub fn export_bundle(&self) -> Result<String> {
        let snap = self.snapshot();
        let mut texts = Vec::with_capacity(snap.shards().len());
        for shard in snap.shards() {
            match self.db.export_shard_text(&shard.platform_key)? {
                Some(text) => texts.push(text),
                // Snapshot shard with no file on disk (deleted since
                // publish): re-serialize the in-memory copy.
                None => texts.push(shard.to_json_text()),
            }
        }
        let meta = crate::service::bundle::BundleMeta {
            platform: self.host_key.clone(),
            generation: snap.generation(),
            fingerprint: Some(self.host.clone()),
        };
        Ok(crate::service::bundle::write_bundle(&meta, &texts))
    }

    /// Counter snapshot (plus live queue/cache depths).
    pub fn stats(&self) -> ServeStats {
        self.drain_expired();
        let (tasks_pending, tasks_inflight, queue_depth) = {
            let q = lock(&self.scheduler);
            (
                q.len() as u64,
                q.leased_len() as u64,
                q.depth_by_kind()
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), v))
                    .collect::<BTreeMap<String, u64>>(),
            )
        };
        // Economics come from the published snapshot's ledgers, not a
        // process counter — spend and benefit persist with the shards,
        // so a restarted daemon still reports lifetime totals.
        let snap = self.snapshot();
        let (mut tuning_spend_ms, mut tuning_benefit_ms) = (0u64, 0u64);
        for shard in snap.shards() {
            let (s, b) = shard.ledger.totals();
            tuning_spend_ms = tuning_spend_ms.saturating_add(s);
            tuning_benefit_ms = tuning_benefit_ms.saturating_add(b);
        }
        ServeStats {
            lookups: self.counters.lookups.load(Ordering::Relaxed),
            deploys: self.counters.deploys.load(Ordering::Relaxed),
            lru_hits: self.counters.lru_hits.load(Ordering::Relaxed),
            shard_reads: self.counters.shard_reads.load(Ordering::Relaxed),
            records: self.counters.records.load(Ordering::Relaxed),
            transfer_misses: self.counters.transfer_misses.load(Ordering::Relaxed),
            portfolios: self.counters.portfolios.load(Ordering::Relaxed),
            portfolio_transfers: self.counters.portfolio_transfers.load(Ordering::Relaxed),
            tasks_queued: self.counters.tasks_queued.load(Ordering::Relaxed),
            tasks_leased: self.counters.tasks_leased.load(Ordering::Relaxed),
            tasks_completed: self.counters.tasks_completed.load(Ordering::Relaxed),
            tasks_failed: self.counters.tasks_failed.load(Ordering::Relaxed),
            leases_expired: self.counters.leases_expired.load(Ordering::Relaxed),
            retunes: self.counters.retunes.load(Ordering::Relaxed),
            errors: self.counters.errors.load(Ordering::Relaxed),
            dedup_hits: self.counters.dedup_hits.load(Ordering::Relaxed),
            conns_shed: self.counters.conns_shed.load(Ordering::Relaxed),
            conns_closed_idle: self.counters.conns_closed_idle.load(Ordering::Relaxed),
            tasks_pending,
            tasks_inflight,
            queue_depth,
            lru_len: snap.index_len() as u64,
            snapshot_gen: snap.generation(),
            snapshot_publishes: self.counters.snapshot_publishes.load(Ordering::Relaxed),
            stale_locks_reaped: crate::coordinator::perfdb::stale_locks_reaped(),
            shards_quarantined: self.db.quarantined_count().unwrap_or(0),
            regressions: self.counters.regressions.load(Ordering::Relaxed),
            regressions_active: lock(&self.sentinel).active() as u64,
            tuning_spend_ms,
            tuning_benefit_ms,
        }
    }

    /// Requeue every lease whose holder went silent past its TTL.
    /// Called lazily by every queue-touching op and the periodic scan
    /// — a crashed worker's task is back in the queue by the next time
    /// anyone asks for work.  Each expiry decision (requeue vs drop)
    /// lands in the audit log.
    fn drain_expired(&self) {
        let report = lock(&self.scheduler).expire_report(unix_now());
        let expired = report.requeued.len() + report.dropped.len();
        if expired > 0 {
            self.counters.leases_expired.fetch_add(expired as u64, Ordering::Relaxed);
        }
        for t in &report.requeued {
            self.audit(AuditEvent::TaskRequeued {
                kind: t.kind.as_str().to_string(),
                platform: t.platform_key.clone(),
                kernel: t.kernel.clone(),
                attempts: t.attempts as u64,
            });
        }
        for t in &report.dropped {
            self.audit(AuditEvent::TaskDropped {
                kind: t.kind.as_str().to_string(),
                platform: t.platform_key.clone(),
                kernel: t.kernel.clone(),
                attempts: t.attempts as u64,
            });
        }
    }

    /// Replay-or-execute for non-idempotent ops carrying a client
    /// request id.  A retried `record`/`task-complete` whose first
    /// attempt's *reply* was lost must not re-execute — the stored
    /// reply is replayed byte-for-byte instead.  Error outcomes are
    /// not stored, so a genuinely failed attempt can be retried for
    /// real; requests without an id always execute.
    fn deduped(
        &self,
        request_id: &Option<String>,
        exec: impl FnOnce() -> Result<Json>,
    ) -> Result<Json> {
        if let Some(id) = request_id {
            if let Some(prev) = lock(&self.dedupe).get(id) {
                self.bump(&self.counters.dedup_hits);
                return Ok(prev);
            }
        }
        let reply = exec()?;
        if let Some(id) = request_id {
            lock(&self.dedupe).put(id.clone(), reply.clone());
        }
        Ok(reply)
    }

    /// Handle one parsed request.  Pure with respect to I/O framing —
    /// every transport and the bench funnel through here.
    pub fn handle_request(&self, req: &Request) -> Json {
        self.handle_request_traced(req, None)
    }

    /// [`Self::handle_request`] with the request's wire `trace_id`
    /// (threaded into served audit events and the slow-op log).  Also
    /// the per-op latency recording point: every transport and the
    /// bench funnel through here, so the `op_latency` histograms see
    /// every request however it arrived.
    pub fn handle_request_traced(&self, req: &Request, trace_id: Option<&str>) -> Json {
        let started = Instant::now();
        let reply = match self.dispatch(req, trace_id) {
            Ok(reply) => reply,
            Err(e) => {
                self.bump(&self.counters.errors);
                reply_err(&format!("{e:#}"))
            }
        };
        let elapsed_us = started.elapsed().as_micros() as u64;
        obs::metrics().op(req.op_name()).record(elapsed_us);
        let threshold_us = obs::slow_op_us();
        if threshold_us > 0 && elapsed_us >= threshold_us {
            self.log_slow_op(req.op_name(), elapsed_us, threshold_us, trace_id);
        }
        reply
    }

    /// One structured stderr line per over-threshold request — greppable
    /// by key, joinable to the trace file by `trace_id`.
    fn log_slow_op(&self, op: &str, elapsed_us: u64, threshold_us: u64, trace_id: Option<&str>) {
        let mut fields = vec![
            ("slow_op", json::s(op)),
            ("elapsed_us", json::int(elapsed_us as i64)),
            ("threshold_us", json::int(threshold_us as i64)),
        ];
        if let Some(id) = trace_id {
            fields.push(("trace_id", json::s(id)));
        }
        eprintln!("{}", json::obj(fields).compact());
    }

    fn dispatch(&self, req: &Request, trace_id: Option<&str>) -> Result<Json> {
        match req {
            Request::Ping => Ok(reply_ok(vec![
                ("op", json::s("pong")),
                ("platform", json::s(&self.host_key)),
            ])),
            Request::Lookup { platform, kernel, workload } => {
                self.bump(&self.counters.lookups);
                let platform = platform.as_deref().unwrap_or(&self.host_key);
                let started = Instant::now();
                let snap = self.snapshot();
                let (reply, from) = snap.lookup_reply(platform, kernel, workload);
                self.bump(&self.counters.lru_hits);
                obs::metrics().lru_hit_us.record(started.elapsed().as_micros() as u64);
                self.audit(AuditEvent::Served {
                    op: "lookup".into(),
                    platform: platform.to_string(),
                    kernel: kernel.clone(),
                    workload: Some(workload.clone()),
                    reason: match from {
                        ServedFrom::Index => ServeReason::Exact,
                        _ => ServeReason::Miss,
                    },
                    trace_id: trace_id.map(str::to_string),
                });
                Ok(reply)
            }
            Request::Deploy { platform, kernel, workload, fingerprint } => {
                self.bump(&self.counters.deploys);
                let platform = platform.as_deref().unwrap_or(&self.host_key);
                let started = Instant::now();
                let snap = self.snapshot();
                let (reply, from) =
                    snap.deploy_reply(platform, kernel, workload, fingerprint.as_ref(), &self.host);
                let reason = match from {
                    ServedFrom::Index => {
                        self.bump(&self.counters.lru_hits);
                        obs::metrics().lru_hit_us.record(started.elapsed().as_micros() as u64);
                        ServeReason::Exact
                    }
                    ServedFrom::Transfer { source, similarity_pm } => {
                        self.bump(&self.counters.transfer_misses);
                        ServeReason::Transfer { source, similarity_pm }
                    }
                    ServedFrom::Miss => {
                        self.bump(&self.counters.transfer_misses);
                        ServeReason::Miss
                    }
                };
                self.audit(AuditEvent::Served {
                    op: "deploy".into(),
                    platform: platform.to_string(),
                    kernel: kernel.clone(),
                    workload: Some(workload.clone()),
                    reason,
                    trace_id: trace_id.map(str::to_string),
                });
                Ok(reply)
            }
            Request::Record { entry, fingerprint, request_id, spend_ms } => {
                self.deduped(request_id, || {
                    self.bump(&self.counters.records);
                    let entry = (**entry).clone();
                    let (platform, kernel, tag) =
                        (entry.platform_key.clone(), entry.kernel.clone(), entry.tag.clone());
                    let config = entry.best_config_id.clone();
                    // Sentinel: judge the observed cost against the
                    // best the *previous* generation had been serving
                    // — before this record can move the bar.  A record
                    // that improves the frontier instead resets the
                    // key: its old ratios were measured against a
                    // baseline that just died.
                    let prior_best = self
                        .snapshot()
                        .lookup(&platform, &kernel, &tag)
                        .map(|e| e.best_time_s);
                    let (regressing, transition) = match prior_best {
                        Some(stored) if entry.best_time_s < stored => {
                            lock(&self.sentinel).reset(&platform, &kernel, &tag);
                            (false, None)
                        }
                        Some(stored) => lock(&self.sentinel).observe(
                            &platform,
                            &kernel,
                            &tag,
                            entry.best_time_s,
                            stored,
                        ),
                        None => (false, None),
                    };
                    let delta = ledger_delta(&entry, spend_ms.unwrap_or(0));
                    self.db.record_with_ledger(fingerprint.as_ref(), entry, delta)?;
                    let generation = self.publish_platform(&platform)?;
                    self.audit(AuditEvent::RecordAccepted {
                        platform: platform.clone(),
                        kernel: kernel.clone(),
                        tag: tag.clone(),
                        config,
                    });
                    if let Some(SentinelEvent::Confirmed {
                        ratio_pm,
                        window_n,
                        window_mean_pm,
                        window_max_pm,
                    }) = transition
                    {
                        // Confirmed drift: audit the evidence, count
                        // it, and answer with an evidence-driven
                        // retune rather than waiting for the TTL scan.
                        self.bump(&self.counters.regressions);
                        self.audit(AuditEvent::Regression {
                            platform: platform.clone(),
                            kernel: kernel.clone(),
                            workload: tag.clone(),
                            ratio_pm,
                            window_n,
                            window_mean_pm,
                            window_max_pm,
                        });
                        let task = TuningTask {
                            kind: TaskKind::Retune,
                            platform_key: platform.clone(),
                            kernel: kernel.clone(),
                            tag: Some(tag.clone()),
                            reason: StaleReason::Regression { ratio_pm },
                            attempts: 0,
                        };
                        if lock(&self.scheduler).enqueue(task) {
                            self.bump(&self.counters.tasks_queued);
                            self.audit(AuditEvent::TaskEnqueued {
                                kind: TaskKind::Retune.as_str().to_string(),
                                platform: platform.clone(),
                                kernel: kernel.clone(),
                                tag: Some(tag.clone()),
                                reason: "regression".into(),
                            });
                        }
                    }
                    self.note_break_even(&platform, &kernel);
                    Ok(reply_ok(vec![
                        ("recorded", Json::Bool(true)),
                        ("regressing", Json::Bool(regressing)),
                        ("gen", json::int(generation as i64)),
                    ]))
                })
            }
            Request::RecordPortfolio { platform, portfolio, fingerprint, spend_ms } => {
                self.bump(&self.counters.records);
                let platform = platform.as_deref().unwrap_or(&self.host_key);
                // A portfolio rebuild reports pure spend: the sweep's
                // cost accrues now, its benefit only as live records
                // arrive against the rebuilt frontier.
                let delta = spend_ms.filter(|ms| *ms > 0).map(|ms| LedgerDelta {
                    kernel: portfolio.kernel.clone(),
                    spend_ms: ms,
                    benefit_ms: 0,
                    invocations: 0,
                    at: unix_now(),
                });
                self.db.record_portfolio_with_ledger(
                    platform,
                    fingerprint.as_ref(),
                    (**portfolio).clone(),
                    delta,
                )?;
                let generation = self.publish_platform(platform)?;
                self.audit(AuditEvent::RecordAccepted {
                    platform: platform.to_string(),
                    kernel: portfolio.kernel.clone(),
                    tag: "*".into(),
                    config: format!("portfolio[{}]", portfolio.items.len()),
                });
                self.note_break_even(platform, &portfolio.kernel);
                Ok(reply_ok(vec![
                    ("recorded", Json::Bool(true)),
                    ("platform", json::s(platform)),
                    ("kernel", json::s(&portfolio.kernel)),
                    ("gen", json::int(generation as i64)),
                ]))
            }
            Request::Report { platform } => {
                Ok(self.snapshot().report_reply(platform.as_deref()))
            }
            Request::Stats => {
                Ok(reply_ok(vec![(
                    "stats",
                    crate::report::stats::serve_stats_json(&self.stats()),
                )]))
            }
            Request::Metrics => Ok(reply_ok(vec![
                ("counters", crate::report::stats::serve_stats_json(&self.stats())),
                ("histograms", obs::metrics().to_json()),
            ])),
            Request::Portfolio { platform, kernel, dims, fingerprint } => {
                self.bump(&self.counters.portfolios);
                let platform = platform.as_deref().unwrap_or(&self.host_key);
                let started = Instant::now();
                let snap = self.snapshot();
                let (reply, from) = snap.portfolio_reply(
                    platform,
                    kernel,
                    dims.as_ref(),
                    fingerprint.as_ref(),
                    &self.host,
                );
                let reason = match from {
                    ServedFrom::Index => {
                        self.bump(&self.counters.lru_hits);
                        obs::metrics().lru_hit_us.record(started.elapsed().as_micros() as u64);
                        ServeReason::Exact
                    }
                    ServedFrom::Transfer { source, similarity_pm } => {
                        self.bump(&self.counters.portfolio_transfers);
                        ServeReason::Transfer { source, similarity_pm }
                    }
                    ServedFrom::Miss => ServeReason::Miss,
                };
                self.audit(AuditEvent::Served {
                    op: "portfolio".into(),
                    platform: platform.to_string(),
                    kernel: kernel.clone(),
                    workload: None,
                    reason,
                    trace_id: trace_id.map(str::to_string),
                });
                Ok(reply)
            }
            Request::TaskLease { kind, platform, ttl_s } => {
                self.drain_expired();
                let ttl = ttl_s.unwrap_or(self.opts.lease_ttl_s).min(MAX_LEASE_TTL_S);
                self.lease_reply(*kind, platform.as_deref(), ttl)
            }
            Request::TaskHeartbeat { lease_id } => {
                self.drain_expired();
                match lock(&self.scheduler).heartbeat(*lease_id, unix_now()) {
                    Some(ttl) => Ok(reply_ok(vec![
                        ("extended", Json::Bool(true)),
                        ("ttl_s", json::int(ttl as i64)),
                    ])),
                    // Not an error reply: the worker must learn "you
                    // lost the lease, stop" — a protocol failure would
                    // be indistinguishable from a flaky connection.
                    None => Ok(reply_ok(vec![("extended", Json::Bool(false))])),
                }
            }
            Request::TaskComplete { lease_id, request_id } => {
                self.deduped(request_id, || {
                    self.drain_expired();
                    let outcome = lock(&self.scheduler).complete(*lease_id);
                    match outcome {
                        CompleteOutcome::Settled => {
                            self.bump(&self.counters.tasks_completed);
                            self.audit(AuditEvent::TaskCompleted { lease_id: *lease_id });
                            Ok(reply_ok(vec![
                                ("settled", Json::Bool(true)),
                                ("duplicate", Json::Bool(false)),
                            ]))
                        }
                        CompleteOutcome::Duplicate => Ok(reply_ok(vec![
                            ("settled", Json::Bool(true)),
                            ("duplicate", Json::Bool(true)),
                        ])),
                        CompleteOutcome::Unknown => {
                            Err(anyhow::anyhow!("unknown lease {lease_id}"))
                        }
                    }
                })
            }
            Request::TaskFail { lease_id, error } => {
                self.drain_expired();
                if let Some(msg) = error {
                    eprintln!("task lease {lease_id} failed on worker: {msg}");
                }
                let outcome = lock(&self.scheduler).fail(*lease_id);
                if matches!(outcome, FailOutcome::Requeued | FailOutcome::Dropped) {
                    self.audit(AuditEvent::TaskFailed {
                        lease_id: *lease_id,
                        error: error.clone().unwrap_or_default(),
                    });
                }
                match outcome {
                    FailOutcome::Requeued => {
                        self.bump(&self.counters.tasks_failed);
                        Ok(reply_ok(vec![("requeued", Json::Bool(true))]))
                    }
                    FailOutcome::Dropped => {
                        self.bump(&self.counters.tasks_failed);
                        Ok(reply_ok(vec![
                            ("requeued", Json::Bool(false)),
                            ("dropped", Json::Bool(true)),
                        ]))
                    }
                    FailOutcome::Duplicate => Ok(reply_ok(vec![
                        ("requeued", Json::Bool(false)),
                        ("duplicate", Json::Bool(true)),
                    ])),
                    FailOutcome::Unknown => Err(anyhow::anyhow!("unknown lease {lease_id}")),
                }
            }
            Request::RetuneNext => {
                // Back-compat alias: a default-TTL lease of the next
                // retune task.  The old fire-and-forget pop lost the
                // task forever if the poller died before recording;
                // now a dead poller's lease expires and the task
                // requeues.  Old callers ignore the extra lease
                // fields; new ones may heartbeat/complete them.
                self.drain_expired();
                self.lease_reply(Some(TaskKind::Retune), None, self.opts.lease_ttl_s)
            }
            Request::Shutdown => {
                self.request_shutdown();
                Ok(reply_ok(vec![("stopping", Json::Bool(true))]))
            }
        }
    }

    /// Lease the next matching task and shape the wire reply shared by
    /// `task-lease` and the `retune-next` alias.
    fn lease_reply(
        &self,
        kind: Option<TaskKind>,
        platform: Option<&str>,
        ttl_s: u64,
    ) -> Result<Json> {
        let leased = lock(&self.scheduler).lease(kind, platform, ttl_s, unix_now());
        match leased {
            Some((lease_id, task)) => {
                self.bump(&self.counters.tasks_leased);
                self.audit(AuditEvent::TaskLeased {
                    lease_id,
                    kind: task.kind.as_str().to_string(),
                    platform: task.platform_key.clone(),
                    kernel: task.kernel.clone(),
                });
                Ok(reply_ok(vec![
                    ("found", Json::Bool(true)),
                    ("lease_id", json::int(lease_id as i64)),
                    ("ttl_s", json::int(ttl_s.max(1) as i64)),
                    ("task", task.to_json()),
                ]))
            }
            None => Ok(reply_ok(vec![("found", Json::Bool(false))])),
        }
    }

    /// Handle one raw wire line → one reply line (no trailing newline).
    ///
    /// The wire telemetry point: splits off the `trace_id` envelope
    /// field, emits one `request:<op>` span covering decode + dispatch,
    /// and echoes the id back in the reply so the client can correlate.
    pub fn handle_line(&self, line: &str) -> String {
        let started = Instant::now();
        let mut span = trace::span("request", "server");
        let (mut reply, trace_id) = match Request::parse_line_traced(line) {
            Ok((req, trace_id)) => {
                if let Some(s) = span.as_mut() {
                    s.set_name(format!("request:{}", req.op_name()));
                }
                (self.handle_request_traced(&req, trace_id.as_deref()), trace_id)
            }
            Err(e) => {
                self.bump(&self.counters.errors);
                // Unparseable lines get their own latency label: a
                // flood of garbage shows up as `op="error"` traffic.
                obs::metrics().op("error").record(started.elapsed().as_micros() as u64);
                (reply_err(&format!("{e:#}")), None)
            }
        };
        if let Some(id) = &trace_id {
            if let Json::Obj(map) = &mut reply {
                map.insert("trace_id".into(), json::s(id));
            }
        }
        if let Some(s) = span {
            s.finish(trace_id.as_deref());
        }
        reply.compact()
    }

    /// Drive one connection: read request lines, write reply lines.
    /// Transport-agnostic (tests drive it with in-memory buffers).
    ///
    /// Socket transports set a read timeout (see [`run_tcp`]); timeouts
    /// surface here as `WouldBlock`/`TimedOut` errors, which are *not*
    /// disconnects — the loop re-checks the shutdown flag and keeps
    /// waiting, so an idle open connection can never pin the daemon
    /// past a shutdown request.  Lines are accumulated as *bytes*
    /// (`read_until`), not via `read_line`: the latter's UTF-8 guard
    /// discards partially-read data when a timeout splits a multi-byte
    /// character, corrupting the in-flight request.
    ///
    /// A connection that completes no request within the configured
    /// idle deadline ([`ServeOpts::conn_idle_s`]) is closed — the
    /// read timeout wakes this loop often enough to notice — so a
    /// stalled client (wedged process, half-open TCP peer) cannot pin
    /// a handler thread forever.
    ///
    /// [`run_tcp`]: Self::run_tcp
    pub fn serve_connection(&self, mut reader: impl BufRead, mut writer: impl Write) {
        let conn_span = trace::span("conn", "server");
        let mut buf: Vec<u8> = Vec::new();
        let mut last_activity = std::time::Instant::now();
        loop {
            if self.is_shutdown() {
                break;
            }
            faults::stall(InjectionPoint::ServerReadStall);
            match reader.read_until(b'\n', &mut buf) {
                Ok(0) => break, // EOF
                Ok(_) => {
                    last_activity = std::time::Instant::now();
                    let reply = {
                        let text = String::from_utf8_lossy(&buf);
                        let trimmed = text.trim();
                        if trimmed.is_empty() {
                            None
                        } else {
                            Some(self.handle_line(trimmed))
                        }
                    };
                    buf.clear();
                    if let Some(reply) = reply {
                        if faults::hit(InjectionPoint::ServerReplyDrop) {
                            // Fault injection: the request executed
                            // but its reply dies with the connection —
                            // exactly a daemon failure between execute
                            // and respond.  Retrying clients must
                            // recover via request-id dedupe.
                            break;
                        }
                        if writer
                            .write_all(reply.as_bytes())
                            .and_then(|_| writer.write_all(b"\n"))
                            .and_then(|_| writer.flush())
                            .is_err()
                        {
                            break;
                        }
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    // Read timeout: partial bytes stay in `buf`; loop
                    // to re-check the shutdown flag and idle deadline.
                    let idle_s = self.opts.conn_idle_s;
                    if idle_s > 0 && last_activity.elapsed() >= Duration::from_secs(idle_s) {
                        self.bump(&self.counters.conns_closed_idle);
                        break;
                    }
                }
                Err(_) => break,
            }
        }
        if let Some(s) = conn_span {
            s.finish(None);
        }
    }

    fn serve_split_stream<S: Read + Write>(&self, read_half: S, write_half: S) {
        self.serve_connection(BufReader::new(read_half), write_half);
    }

    /// One periodic staleness scan; returns how many tasks were queued.
    /// Also requeues expired leases — the scan thread is the heartbeat
    /// that guarantees a crashed worker's task resurfaces even when no
    /// other worker is polling — and republishes the snapshot from
    /// disk, which bounds read staleness against out-of-band shard
    /// writers (`db-migrate`, another machine's tuner) by the scan
    /// interval.
    pub fn scan_once(&self) -> Result<usize> {
        self.drain_expired();
        // Sweep abandoned shard locks first: a corpse would otherwise
        // cost every writer below a full stale-lock wait.
        if let Err(e) = self.db.reap_stale_locks() {
            eprintln!("stale-lock sweep failed: {e:#}");
            self.bump(&self.counters.errors);
        }
        self.refresh_snapshot()?;
        let snap = self.snapshot();
        let added = lock(&self.scheduler).scan_report(snap.shards(), &self.host, unix_now());
        self.counters.tasks_queued.fetch_add(added.len() as u64, Ordering::Relaxed);
        for t in &added {
            self.audit(AuditEvent::TaskEnqueued {
                kind: t.kind.as_str().to_string(),
                platform: t.platform_key.clone(),
                kernel: t.kernel.clone(),
                tag: t.tag.clone(),
                reason: t.reason.as_str().to_string(),
            });
        }
        Ok(added.len())
    }

    /// Background staleness scanner (checks the shutdown flag every
    /// poll interval, scans every `interval`).
    pub fn spawn_scan(self: Arc<Self>, interval: Duration) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            while !self.is_shutdown() {
                if self.scan_once().is_err() {
                    self.bump(&self.counters.errors);
                }
                let mut slept = Duration::ZERO;
                while slept < interval && !self.is_shutdown() {
                    std::thread::sleep(Duration::from_millis(50));
                    slept += Duration::from_millis(50);
                }
            }
        })
    }

    /// Background re-tune worker: drains the *host's* retune tasks
    /// through the batched [`Tuner`] and records fresh entries under
    /// the host's current fingerprint.  Foreign platforms' tasks and
    /// the kernel-wide kinds (sweep, portfolio-rebuild) remain queued
    /// for the external `portatune work` fleet — this worker owns an
    /// artifact registry, not a native sweep pipeline.  Checkout goes
    /// through the same lease machinery as the wire ops (a generous
    /// TTL: the tune is a single blocking call with nothing to
    /// heartbeat from), so its completions and failures show up in the
    /// task counters.  A per-(kernel, workload) cooldown — a quarter
    /// of the TTL, at least a minute — bounds the tuning rate even if
    /// a recording failure leaves a task re-queue-able, while still
    /// allowing the periodic refresh the TTL exists for.
    ///
    /// The worker builds its own [`Registry`] *inside* the thread via
    /// `make_registry`: backend executable types are not `Send` under
    /// the real-runtime feature, so nothing runtime-owned may cross the
    /// spawn boundary.  If construction fails (no artifacts, stub
    /// runtime), the worker logs once and exits — the daemon keeps
    /// serving, it just cannot re-measure.
    pub fn spawn_retune_worker(
        self: Arc<Self>,
        make_registry: impl FnOnce() -> Result<Registry> + Send + 'static,
        batch: usize,
    ) -> std::thread::JoinHandle<()> {
        let cooldown = Duration::from_secs((self.opts.ttl_s / 4).max(60));
        std::thread::spawn(move || {
            let registry = match make_registry() {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("re-tune worker exiting: {e:#}");
                    self.bump(&self.counters.errors);
                    return;
                }
            };
            let mut last_retuned: HashMap<(String, String), std::time::Instant> = HashMap::new();
            // A tune is one blocking call with no heartbeat
            // opportunity; lease long enough that a slow exhaustive
            // pass cannot expire out from under an in-process worker.
            let lease_ttl = self.opts.lease_ttl_s.max(3600);
            while !self.is_shutdown() {
                // Only the host's own retune tasks: foreign shards and
                // kernel-wide tasks stay queued for the external fleet
                // — this daemon cannot re-measure another machine, and
                // a local tune would be recorded under the host's key
                // anyway, leaving the foreign shard stale and
                // re-queuing.
                self.drain_expired();
                let leased = lock(&self.scheduler).lease(
                    Some(TaskKind::Retune),
                    Some(&self.host_key),
                    lease_ttl,
                    unix_now(),
                );
                let Some((lease_id, task)) = leased else {
                    std::thread::sleep(Duration::from_millis(100));
                    continue;
                };
                self.bump(&self.counters.tasks_leased);
                self.audit(AuditEvent::TaskLeased {
                    lease_id,
                    kind: task.kind.as_str().to_string(),
                    platform: task.platform_key.clone(),
                    kernel: task.kernel.clone(),
                });
                let Some(tag) = task.tag.clone() else {
                    // Retune tasks always carry a workload; a tagless
                    // one is a queue bug — drop it rather than loop.
                    let _ = lock(&self.scheduler).fail(lease_id);
                    self.bump(&self.counters.tasks_failed);
                    self.bump(&self.counters.errors);
                    self.audit(AuditEvent::TaskFailed {
                        lease_id,
                        error: "retune task lacks a workload tag".into(),
                    });
                    continue;
                };
                let work_key = (task.kernel.clone(), tag.clone());
                if last_retuned.get(&work_key).is_some_and(|t| t.elapsed() < cooldown) {
                    // Within cooldown: defer (not complete — a
                    // completion would mark the identity resolved at
                    // its current stamp and the scan would never bring
                    // it back); the next scan requeues it.
                    let _ = lock(&self.scheduler).defer(lease_id);
                    continue;
                }
                last_retuned.insert(work_key, std::time::Instant::now());
                let mut tuner = Tuner::new(&registry);
                tuner.batch = batch.max(1);
                let mut strategy = Exhaustive::new();
                let tune_started = std::time::Instant::now();
                match tuner.tune(&task.kernel, &tag, &mut strategy, usize::MAX) {
                    Ok(outcome) => {
                        // Ledger spend: the tuner's own accounting of
                        // compile + measure time, falling back to wall
                        // clock when the stub runtime reports none.
                        let worked_ms = outcome.stats.compile_ms + outcome.stats.measure_ms;
                        let spend_ms = if worked_ms.is_finite() && worked_ms >= 1.0 {
                            worked_ms.round() as u64
                        } else {
                            (tune_started.elapsed().as_millis() as u64).max(1)
                        };
                        let entry = tuner.entry_for(&outcome);
                        let (platform, kernel, tag) =
                            (entry.platform_key.clone(), entry.kernel.clone(), entry.tag.clone());
                        let config = entry.best_config_id.clone();
                        let delta = ledger_delta(&entry, spend_ms);
                        if self
                            .db
                            .record_with_ledger(Some(&outcome.platform), entry, delta)
                            .is_ok()
                        {
                            // A fresh tune is a new baseline: the
                            // sentinel's old ratios no longer apply.
                            lock(&self.sentinel).reset(&platform, &kernel, &tag);
                            if self.publish_platform(&platform).is_err() {
                                self.bump(&self.counters.errors);
                            }
                            self.note_break_even(&platform, &kernel);
                            self.bump(&self.counters.retunes);
                            self.audit(AuditEvent::RecordAccepted {
                                platform: platform.clone(),
                                kernel: kernel.clone(),
                                tag: tag.clone(),
                                config,
                            });
                            if lock(&self.scheduler).complete(lease_id)
                                == CompleteOutcome::Settled
                            {
                                self.bump(&self.counters.tasks_completed);
                                self.audit(AuditEvent::TaskCompleted { lease_id });
                            }
                        } else {
                            let _ = lock(&self.scheduler).fail(lease_id);
                            self.bump(&self.counters.tasks_failed);
                            self.bump(&self.counters.errors);
                            self.audit(AuditEvent::TaskFailed {
                                lease_id,
                                error: "recording the tuned entry failed".into(),
                            });
                        }
                    }
                    Err(e) => {
                        let _ = lock(&self.scheduler).fail(lease_id);
                        self.bump(&self.counters.tasks_failed);
                        self.bump(&self.counters.errors);
                        self.audit(AuditEvent::TaskFailed {
                            lease_id,
                            error: format!("{e:#}"),
                        });
                    }
                }
            }
        })
    }

    /// The shared accept loop (transport supplied as a non-blocking
    /// `accept` closure).  Prepared connections go to a bounded worker
    /// pool over a condvar'd queue — a fixed number of handler threads
    /// instead of thread-per-connection, so contended throughput is
    /// set by pool width and a connection flood cannot pile up thread
    /// stacks.  Queued plus in-service connections are capped by
    /// [`ServeOpts::max_conns`]; past the cap a new connection is shed
    /// with one retryable `overloaded` reply.  Connections carry a
    /// read timeout ([`ServeStream::prepare`]) so handler loops notice
    /// the shutdown flag even when a client holds the socket open
    /// idle.
    fn run_accept_loop<S: ServeStream>(
        self: Arc<Self>,
        mut accept: impl FnMut() -> std::io::Result<S>,
    ) -> Result<()> {
        let pool: Arc<ConnQueue<S>> = Arc::new(ConnQueue {
            ready: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            inflight: AtomicUsize::new(0),
        });
        let worker_count = if self.opts.workers > 0 {
            self.opts.workers
        } else {
            // Serving is line parsing + hash probes — CPU-bound — so
            // size to the machine; the clamp keeps one-core boxes able
            // to overlap a stalled reader with live traffic and huge
            // boxes from hoarding idle threads.
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 32)
        };
        let mut workers = Vec::with_capacity(worker_count);
        for _ in 0..worker_count {
            let srv = Arc::clone(&self);
            let pool = Arc::clone(&pool);
            workers.push(std::thread::spawn(move || srv.run_pool_worker(&pool)));
        }
        while !self.is_shutdown() {
            match accept() {
                Ok(mut stream) => {
                    stream.prepare();
                    let inflight = pool.inflight.load(Ordering::SeqCst);
                    if self.opts.max_conns > 0 && inflight >= self.opts.max_conns {
                        // Shed load: a bounded pool beats unbounded
                        // queueing.  The refused client gets one
                        // retryable `overloaded` reply (see
                        // `client::RetryPolicy`).  Reply + close happen
                        // on a short detached thread that also drains
                        // the client's in-flight request bytes —
                        // closing with unread data can reset the
                        // connection and tear the reply away before the
                        // client reads it — so the accept loop itself
                        // never blocks on a shed connection.
                        self.bump(&self.counters.conns_shed);
                        let line =
                            reply_err(&format!("overloaded: {inflight} connections in flight"))
                                .compact();
                        std::thread::spawn(move || {
                            let _ = stream
                                .write_all(line.as_bytes())
                                .and_then(|_| stream.write_all(b"\n"))
                                .and_then(|_| stream.flush());
                            // Bounded drain: one read timeout at most,
                            // and a peer streaming data cannot pin the
                            // thread past a few buffers.
                            let mut sink = [0u8; 1024];
                            for _ in 0..16 {
                                match stream.read(&mut sink) {
                                    Ok(n) if n > 0 => {}
                                    _ => break,
                                }
                            }
                        });
                        continue;
                    }
                    pool.inflight.fetch_add(1, Ordering::SeqCst);
                    lock(&pool.ready).push_back(stream);
                    pool.available.notify_one();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => {
                    // Persistent accept errors (EMFILE under fd
                    // exhaustion, etc.) return immediately — back off
                    // instead of busy-spinning a core on the counter.
                    self.bump(&self.counters.errors);
                    std::thread::sleep(ACCEPT_POLL * 10);
                }
            }
        }
        // Graceful drain: accepting has stopped; wake every worker.
        // Workers pop the queue *before* checking the shutdown flag,
        // so already-accepted connections still get a handler (their
        // loops then observe shutdown within one read timeout and
        // finish the current request).  Then flush a final stats
        // snapshot to the log so a restart never discards the counters
        // silently.
        pool.available.notify_all();
        for h in workers {
            let _ = h.join();
        }
        eprintln!(
            "portatune serve: drained on shutdown; final stats: {}",
            crate::report::stats::serve_stats_json(&self.stats()).compact()
        );
        Ok(())
    }

    /// One pool worker: pop the next prepared connection (pop first,
    /// check shutdown second — so the queue drains on shutdown), serve
    /// it to completion, release its inflight slot.  A killed client
    /// surfaces as EOF or a hard read error inside
    /// [`Self::serve_connection`], which returns — the worker moves on
    /// to the next connection rather than wedging.
    fn run_pool_worker<S: ServeStream>(&self, pool: &ConnQueue<S>) {
        loop {
            let next = {
                let mut ready = lock(&pool.ready);
                loop {
                    if let Some(stream) = ready.pop_front() {
                        break Some(stream);
                    }
                    if self.is_shutdown() {
                        break None;
                    }
                    // Timed wait: a missed notify (shed race, spurious
                    // shutdown ordering) costs one timeout, not a hang.
                    ready = pool
                        .available
                        .wait_timeout(ready, CONN_READ_TIMEOUT)
                        .unwrap_or_else(|poisoned| poisoned.into_inner())
                        .0;
                }
            };
            let Some(stream) = next else { break };
            match stream.split_read_half() {
                Ok(read_half) => self.serve_split_stream(read_half, stream),
                Err(_) => self.bump(&self.counters.errors),
            }
            pool.inflight.fetch_sub(1, Ordering::SeqCst);
        }
    }

    /// Accept loop over TCP.  Returns when shutdown is requested.
    pub fn run_tcp(self: Arc<Self>, listener: TcpListener) -> Result<()> {
        listener.set_nonblocking(true)?;
        self.run_accept_loop(move || listener.accept().map(|(stream, _peer)| stream))
    }

    /// Accept loop over a Unix socket.  Returns when shutdown is
    /// requested; the caller owns socket-file cleanup.
    #[cfg(unix)]
    pub fn run_unix(self: Arc<Self>, listener: std::os::unix::net::UnixListener) -> Result<()> {
        listener.set_nonblocking(true)?;
        self.run_accept_loop(move || listener.accept().map(|(stream, _peer)| stream))
    }

    /// The full telemetry surface rendered as Prometheus text format:
    /// every `ServeStats` counter/gauge (counters as
    /// `portatune_<name>_total`, gauges bare, `queue_depth` labeled by
    /// kind) followed by every registry histogram (see
    /// [`crate::obs::Metrics::prometheus_text`]).
    pub fn prometheus_text(&self) -> String {
        // The live-depth fields of `ServeStats`; everything else in the
        // snapshot is a monotonic counter.
        const GAUGES: &[&str] = &[
            "tasks_pending",
            "tasks_inflight",
            "lru_len",
            "snapshot_gen",
            "shards_quarantined",
            "regressions_active",
        ];
        let stats = crate::report::stats::serve_stats_json(&self.stats());
        let mut out = String::new();
        if let Some(map) = stats.as_obj() {
            for (key, val) in map {
                match val {
                    Json::Num(n) => {
                        if GAUGES.contains(&key.as_str()) {
                            out.push_str(&format!("# TYPE portatune_{key} gauge\n"));
                            out.push_str(&format!("portatune_{key} {n}\n"));
                        } else {
                            out.push_str(&format!("# TYPE portatune_{key}_total counter\n"));
                            out.push_str(&format!("portatune_{key}_total {n}\n"));
                        }
                    }
                    Json::Obj(by_kind) => {
                        out.push_str(&format!("# TYPE portatune_{key} gauge\n"));
                        for (kind, depth) in by_kind {
                            if let Some(n) = depth.as_f64() {
                                out.push_str(&format!(
                                    "portatune_{key}{{kind=\"{kind}\"}} {n}\n"
                                ));
                            }
                        }
                    }
                    _ => {}
                }
            }
        }
        out.push_str(&obs::metrics().prometheus_text());
        out
    }

    /// Minimal HTTP/1.1 responder behind `--metrics-addr`: every GET
    /// (scrapers hit `/metrics`, but any path works) gets the
    /// Prometheus page and the connection closes.  Same non-blocking
    /// accept + shutdown-poll discipline as the wire accept loop; one
    /// request is served at a time — a scrape is one small read and
    /// one buffered write, and metrics must never compete with serving
    /// for threads.
    pub fn run_metrics_http(self: Arc<Self>, listener: TcpListener) -> Result<()> {
        listener.set_nonblocking(true)?;
        while !self.is_shutdown() {
            match listener.accept() {
                Ok((mut stream, _peer)) => {
                    let _ = stream.set_nonblocking(false);
                    let _ = stream.set_read_timeout(Some(CONN_READ_TIMEOUT));
                    // Best-effort: consume the request head so closing
                    // with unread data cannot RST the response away.
                    let mut head = [0u8; 1024];
                    let _ = stream.read(&mut head);
                    let body = self.prometheus_text();
                    let response = format!(
                        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; \
                         charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
                        body.len(),
                        body
                    );
                    let _ = stream.write_all(response.as_bytes()).and_then(|_| stream.flush());
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL);
                }
                Err(_) => {
                    self.bump(&self.counters.errors);
                    std::thread::sleep(ACCEPT_POLL * 10);
                }
            }
        }
        Ok(())
    }
}

/// Accept-queue state shared between the accept loop and its worker
/// pool: prepared connections wait here until a worker picks them up.
struct ConnQueue<S> {
    /// Prepared connections awaiting a worker.
    ready: Mutex<VecDeque<S>>,
    /// Signaled once per push (and broadcast at shutdown).
    available: Condvar,
    /// Queued plus in-service connections — the value
    /// [`ServeOpts::max_conns`] sheds against (a connection counts
    /// from accept until its handler returns).
    inflight: AtomicUsize,
}

/// The per-transport surface the accept loop needs: post-accept socket
/// options and a second handle for the read half.
trait ServeStream: Read + Write + Send + Sized + 'static {
    fn prepare(&self);
    fn split_read_half(&self) -> std::io::Result<Self>;
}

impl ServeStream for std::net::TcpStream {
    fn prepare(&self) {
        let _ = self.set_nonblocking(false);
        let _ = self.set_nodelay(true);
        let _ = self.set_read_timeout(Some(CONN_READ_TIMEOUT));
    }

    fn split_read_half(&self) -> std::io::Result<Self> {
        self.try_clone()
    }
}

#[cfg(unix)]
impl ServeStream for std::os::unix::net::UnixStream {
    fn prepare(&self) {
        let _ = self.set_nonblocking(false);
        let _ = self.set_read_timeout(Some(CONN_READ_TIMEOUT));
    }

    fn split_read_half(&self) -> std::io::Result<Self> {
        self.try_clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp() -> Fingerprint {
        Fingerprint {
            cpu_model: "Srv CPU".into(),
            num_cpus: 8,
            simd: vec!["avx2".into(), "fma".into()],
            cache_l1d_kb: 32,
            cache_l2_kb: 1024,
            cache_l3_kb: 8192,
            os: "linux".into(),
        }
    }

    fn entry(platform: &str, kernel: &str, tag: &str, id: &str) -> DbEntry {
        DbEntry {
            platform_key: platform.into(),
            kernel: kernel.into(),
            tag: tag.into(),
            best_params: [("block_size".to_string(), 256i64)].into_iter().collect(),
            best_config_id: id.into(),
            best_time_s: 1e-3,
            baseline_time_s: 2e-3,
            reference_time_s: 9e-4,
            evaluations: 4,
            strategy: "exhaustive".into(),
            recorded_at: unix_now(),
        }
    }

    fn test_server(name: &str) -> (Server, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("portatune-srv-{name}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let db = ShardedDb::open(&dir).unwrap();
        (Server::new(db, fp(), ServeOpts::default()), dir)
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut lru: Lru<u32, u32> = Lru::new(2);
        lru.put(1, 10);
        lru.put(2, 20);
        assert_eq!(lru.get(&1), Some(10)); // refresh 1
        lru.put(3, 30); // evicts 2
        assert_eq!(lru.get(&2), None);
        assert_eq!(lru.get(&1), Some(10));
        assert_eq!(lru.get(&3), Some(30));
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn lru_cap_zero_stores_nothing() {
        let mut lru: Lru<u32, u32> = Lru::new(0);
        lru.put(1, 10);
        assert_eq!(lru.get(&1), None);
        assert!(lru.is_empty());
    }

    #[test]
    fn record_then_lookup_round_trips() {
        let (srv, dir) = test_server("roundtrip");
        let rec = Request::Record {
            request_id: None,
            entry: Box::new(entry("p1", "axpy", "n4096", "b256_u1")),
            fingerprint: Some(fp()),
            spend_ms: None,
        };
        assert_eq!(srv.handle_request(&rec).get("ok").and_then(Json::as_bool), Some(true));
        let look = Request::Lookup {
            platform: Some("p1".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
        };
        let reply = srv.handle_request(&look);
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
        assert_eq!(
            reply.get("entry").and_then(|e| e.get("best_config_id")).and_then(Json::as_str),
            Some("b256_u1")
        );
        // Both lookups are pure snapshot-index probes; the only shard
        // read was the record's publish.
        let _ = srv.handle_request(&look);
        let stats = srv.stats();
        assert_eq!(stats.lookups, 2);
        assert_eq!(stats.lru_hits, 2);
        assert_eq!(stats.shard_reads, 1);
        assert_eq!(stats.records, 1);
        assert_eq!(stats.snapshot_gen, 1);
        assert_eq!(stats.snapshot_publishes, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn record_invalidates_cached_negative() {
        let (srv, dir) = test_server("invalidate");
        let look = Request::Lookup {
            platform: Some("p1".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
        };
        // Miss gets cached...
        assert_eq!(srv.handle_request(&look).get("found").and_then(Json::as_bool), Some(false));
        // ...but a record must bust it.
        let rec = Request::Record {
            request_id: None,
            entry: Box::new(entry("p1", "axpy", "n4096", "fresh")),
            fingerprint: None,
            spend_ms: None,
        };
        srv.handle_request(&rec);
        assert_eq!(srv.handle_request(&look).get("found").and_then(Json::as_bool), Some(true));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn deploy_miss_returns_transfer_candidates_nearest_first() {
        let (srv, dir) = test_server("transfer");
        // Two recorded platforms: one near-identical to the requester,
        // one alien.
        let near_fp = fp();
        let mut far_fp = fp();
        far_fp.simd = vec!["neon".into()];
        far_fp.cache_l2_kb = 512;
        far_fp.os = "macos".into();
        srv.handle_request(&Request::Record {
            request_id: None,
            entry: Box::new(entry("near-p", "axpy", "n4096", "near_cfg")),
            fingerprint: Some(near_fp),
            spend_ms: None,
        });
        srv.handle_request(&Request::Record {
            request_id: None,
            entry: Box::new(entry("far-p", "axpy", "n4096", "far_cfg")),
            fingerprint: Some(far_fp),
            spend_ms: None,
        });
        let reply = srv.handle_request(&Request::Deploy {
            platform: Some("fresh-platform".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
            fingerprint: Some(fp()), // requester looks like near-p
        });
        assert_eq!(reply.get("source").and_then(Json::as_str), Some("transfer"));
        let cands = reply.get("candidates").and_then(Json::as_arr).unwrap();
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[0].get("config_id").and_then(Json::as_str), Some("near_cfg"));
        assert!(
            cands[0].get("similarity").and_then(Json::as_f64).unwrap()
                > cands[1].get("similarity").and_then(Json::as_f64).unwrap()
        );
        assert_eq!(srv.stats().transfer_misses, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn deploy_ranks_for_target_platforms_stored_fingerprint() {
        let (srv, dir) = test_server("target-fp");
        let arm = Fingerprint {
            cpu_model: "ARM Box".into(),
            num_cpus: 8,
            simd: vec!["neon".into()],
            cache_l1d_kb: 64,
            cache_l2_kb: 512,
            cache_l3_kb: 0,
            os: "linux".into(),
        };
        // The target platform is known (shard with ARM fingerprint) but
        // has no entry for the requested kernel — only for another one.
        srv.handle_request(&Request::Record {
            request_id: None,
            entry: Box::new(entry("arm-target", "dot", "n4096", "unrelated")),
            fingerprint: Some(arm.clone()),
            spend_ms: None,
        });
        // Candidate pool: an ARM sibling and an x86 box, both tuned for
        // the requested kernel.
        let mut arm_sibling = arm.clone();
        arm_sibling.cache_l2_kb = 1024;
        srv.handle_request(&Request::Record {
            request_id: None,
            entry: Box::new(entry("arm-sibling", "axpy", "n4096", "arm_cfg")),
            fingerprint: Some(arm_sibling),
            spend_ms: None,
        });
        srv.handle_request(&Request::Record {
            request_id: None,
            entry: Box::new(entry("x86-box", "axpy", "n4096", "x86_cfg")),
            fingerprint: Some(fp()), // avx2 x86 — matches the *requester*
            spend_ms: None,
        });
        // Query made on behalf of arm-target from an x86 machine: the
        // requester's fingerprint must NOT drive the ranking.
        let reply = srv.handle_request(&Request::Deploy {
            platform: Some("arm-target".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
            fingerprint: Some(fp()),
        });
        assert_eq!(reply.get("source").and_then(Json::as_str), Some("transfer"));
        let cands = reply.get("candidates").and_then(Json::as_arr).unwrap();
        assert_eq!(
            cands[0].get("config_id").and_then(Json::as_str),
            Some("arm_cfg"),
            "ranking must follow the target's stored ARM fingerprint, not the x86 requester"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn deploy_exact_hit_short_circuits_transfer() {
        let (srv, dir) = test_server("exact");
        srv.handle_request(&Request::Record {
            request_id: None,
            entry: Box::new(entry("p1", "axpy", "n4096", "mine")),
            fingerprint: None,
            spend_ms: None,
        });
        let reply = srv.handle_request(&Request::Deploy {
            platform: Some("p1".into()),
            kernel: "axpy".into(),
            workload: "n4096".into(),
            fingerprint: None,
        });
        assert_eq!(reply.get("source").and_then(Json::as_str), Some("exact"));
        assert_eq!(srv.stats().transfer_misses, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn test_portfolio(kernel: &str) -> crate::coordinator::portfolio::Portfolio {
        use crate::coordinator::portfolio::{Portfolio, PortfolioItem, FEATURE_NAMES};
        Portfolio {
            kernel: kernel.into(),
            strategy: "greedy-cover".into(),
            k_max: 4,
            retained: 0.93,
            built_at: unix_now(),
            feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
            items: vec![
                PortfolioItem {
                    config: [
                        ("loop_order".to_string(), 1i64),
                        ("tile_m".to_string(), 32i64),
                    ]
                    .into_iter()
                    .collect(),
                    config_id: "small_cfg".into(),
                    centroid: vec![4.0, 4.0, 4.0, 1.0, -6.0],
                    covered: vec!["m16n16k16".into()],
                },
                PortfolioItem {
                    config: [
                        ("loop_order".to_string(), 1i64),
                        ("tile_m".to_string(), 128i64),
                    ]
                    .into_iter()
                    .collect(),
                    config_id: "large_cfg".into(),
                    centroid: vec![9.0, 9.0, 9.0, 1.0, 2.0],
                    covered: vec!["m512n512k512".into()],
                },
            ],
        }
    }

    #[test]
    fn portfolio_exact_hit_selects_by_dims() {
        let (srv, dir) = test_server("portfolio-exact");
        srv.db().record_portfolio("p1", Some(&fp()), test_portfolio("gemm")).unwrap();
        // Out-of-band write (straight through the db): publish it.
        srv.refresh_snapshot().unwrap();
        let reply = srv.handle_request(&Request::Portfolio {
            platform: Some("p1".into()),
            kernel: "gemm".into(),
            dims: Some(
                [("m".to_string(), 512i64), ("n".to_string(), 512), ("k".to_string(), 512)]
                    .into_iter()
                    .collect(),
            ),
            fingerprint: None,
        });
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(reply.get("source").and_then(Json::as_str), Some("exact"));
        assert_eq!(
            reply
                .get("portfolio")
                .and_then(|p| p.get("kernel"))
                .and_then(Json::as_str),
            Some("gemm")
        );
        assert_eq!(
            reply
                .get("selected")
                .and_then(|s| s.get("config_id"))
                .and_then(Json::as_str),
            Some("large_cfg"),
            "a 512^3 workload must select the large-shape member"
        );
        let stats = srv.stats();
        assert_eq!(stats.portfolios, 1);
        assert_eq!(stats.portfolio_transfers, 0);
        assert_eq!(stats.shard_reads, 1, "only the publish read the shard");
        // A second identical op is another pure snapshot-index probe.
        let reply = srv.handle_request(&Request::Portfolio {
            platform: Some("p1".into()),
            kernel: "gemm".into(),
            dims: None,
            fingerprint: None,
        });
        assert_eq!(reply.get("source").and_then(Json::as_str), Some("exact"));
        let stats = srv.stats();
        assert_eq!(stats.shard_reads, 1, "serving must not re-read the shard");
        assert_eq!(stats.lru_hits, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn record_invalidates_cached_portfolio_fingerprint() {
        let (srv, dir) = test_server("portfolio-inval");
        srv.db().record_portfolio("p1", Some(&fp()), test_portfolio("gemm")).unwrap();
        srv.refresh_snapshot().unwrap();
        let req = Request::Portfolio {
            platform: Some("p1".into()),
            kernel: "gemm".into(),
            dims: None,
            fingerprint: None,
        };
        let _ = srv.handle_request(&req); // pure snapshot probe
        assert_eq!(srv.stats().shard_reads, 1);
        // A record op may rewrite the shard's fingerprint (which drives
        // portfolio selection) — its publish must re-read the shard so
        // the next portfolio op sees the fresh state.
        srv.handle_request(&Request::Record {
            request_id: None,
            entry: Box::new(entry("p1", "axpy", "n4096", "whatever")),
            fingerprint: Some(fp()),
            spend_ms: None,
        });
        let _ = srv.handle_request(&req);
        assert_eq!(
            srv.stats().shard_reads,
            2,
            "the record's publish must re-read the shard exactly once"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn portfolio_miss_transfers_from_nearest_platform() {
        let (srv, dir) = test_server("portfolio-transfer");
        let near_fp = fp();
        let mut far_fp = fp();
        far_fp.simd = vec!["neon".into()];
        far_fp.os = "macos".into();
        srv.db().record_portfolio("near-p", Some(&near_fp), test_portfolio("gemm")).unwrap();
        srv.db().record_portfolio("far-p", Some(&far_fp), test_portfolio("gemm")).unwrap();
        srv.refresh_snapshot().unwrap();
        let reply = srv.handle_request(&Request::Portfolio {
            platform: Some("fresh-platform".into()),
            kernel: "gemm".into(),
            dims: None,
            fingerprint: Some(fp()), // requester looks like near-p
        });
        assert_eq!(reply.get("source").and_then(Json::as_str), Some("transfer"));
        assert_eq!(reply.get("platform").and_then(Json::as_str), Some("near-p"));
        assert!(reply.get("similarity").and_then(Json::as_f64).unwrap() > 0.5);
        assert_eq!(srv.stats().portfolio_transfers, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn portfolio_total_miss_reports_not_found() {
        let (srv, dir) = test_server("portfolio-none");
        let reply = srv.handle_request(&Request::Portfolio {
            platform: None,
            kernel: "gemm".into(),
            dims: None,
            fingerprint: None,
        });
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(false));
        assert_eq!(
            srv.stats().portfolio_transfers,
            0,
            "a total miss is not a transfer answer"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wire_lines_and_shutdown() {
        let (srv, dir) = test_server("wire");
        let reply = srv.handle_line(r#"{"op":"ping"}"#);
        assert!(reply.contains(r#""ok":true"#));
        let reply = srv.handle_line("garbage");
        assert!(reply.contains(r#""ok":false"#));
        assert!(!srv.is_shutdown());
        let reply = srv.handle_line(r#"{"op":"shutdown"}"#);
        assert!(reply.contains(r#""stopping":true"#));
        assert!(srv.is_shutdown());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn handle_line_echoes_trace_id() {
        let (srv, dir) = test_server("trace-echo");
        let reply = srv.handle_line(r#"{"op":"ping","trace_id":"t-echo-1"}"#);
        let v = json::parse(&reply).unwrap();
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("trace_id").and_then(Json::as_str), Some("t-echo-1"));
        // Untraced requests get untraced replies.
        let bare = json::parse(&srv.handle_line(r#"{"op":"ping"}"#)).unwrap();
        assert!(bare.get("trace_id").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn metrics_op_returns_counters_and_histograms() {
        let (srv, dir) = test_server("metrics-op");
        // Traffic through the latency-recording entry point.
        let _ = srv.handle_request(&Request::Ping);
        let reply = srv.handle_request(&Request::Metrics);
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
        assert!(
            reply.get("counters").and_then(|c| c.get("lookups")).is_some(),
            "counters must be the serve_stats_json shape"
        );
        let ping = reply
            .get("histograms")
            .and_then(|h| h.get("op_latency_us"))
            .and_then(|o| o.get("ping"))
            .expect("per-op latency histograms in the payload");
        assert!(ping.get("count").and_then(Json::as_u64).unwrap_or(0) >= 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prometheus_page_covers_every_stats_key() {
        let (srv, dir) = test_server("prom-page");
        let _ = srv.handle_request(&Request::Ping);
        let page = srv.prometheus_text();
        let stats = crate::report::stats::serve_stats_json(&srv.stats());
        for key in stats.as_obj().unwrap().keys() {
            assert!(
                page.contains(&format!("portatune_{key}")),
                "stats key {key} missing from the Prometheus page"
            );
        }
        assert!(page.contains("# TYPE portatune_lookups_total counter"));
        assert!(page.contains("# TYPE portatune_tasks_pending gauge"));
        assert!(
            page.contains("portatune_op_latency_seconds_bucket"),
            "registry histograms must render too"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_connection_over_buffers() {
        let (srv, dir) = test_server("buffers");
        let input = b"{\"op\":\"ping\"}\n\n{\"op\":\"stats\"}\n".to_vec();
        let mut output: Vec<u8> = Vec::new();
        srv.serve_connection(std::io::Cursor::new(input), &mut output);
        let text = String::from_utf8(output).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "blank lines are skipped: {text}");
        assert!(lines[0].contains("pong"));
        assert!(lines[1].contains("stats"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scan_once_queues_and_retune_next_leases() {
        let (srv, dir) = test_server("scan");
        let mut stale = entry("p1", "axpy", "n4096", "old");
        stale.recorded_at = 1000; // ancient
        srv.db().record(None, stale).unwrap();
        let added = srv.scan_once().unwrap();
        assert_eq!(added, 1);
        let stats = srv.stats();
        assert_eq!(stats.tasks_pending, 1);
        assert_eq!(stats.tasks_queued, 1);
        assert_eq!(stats.queue_depth["retune"], 1);
        // retune-next is now a lease: the reply carries the task in
        // the legacy shape PLUS a lease id.
        let reply = srv.handle_request(&Request::RetuneNext);
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
        assert_eq!(
            reply.get("task").and_then(|t| t.get("reason")).and_then(Json::as_str),
            Some("ttl-expired")
        );
        assert_eq!(
            reply.get("task").and_then(|t| t.get("workload")).and_then(Json::as_str),
            Some("n4096")
        );
        let lease_id = reply.get("lease_id").and_then(Json::as_u64).unwrap();
        // The task is in flight, not re-leasable...
        let reply = srv.handle_request(&Request::RetuneNext);
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(false));
        let stats = srv.stats();
        assert_eq!(stats.tasks_inflight, 1);
        assert_eq!(stats.tasks_leased, 1);
        // ...heartbeats extend it, and completion settles it.
        let reply = srv.handle_request(&Request::TaskHeartbeat { lease_id });
        assert_eq!(reply.get("extended").and_then(Json::as_bool), Some(true));
        let reply = srv.handle_request(&Request::TaskComplete { lease_id, request_id: None });
        assert_eq!(reply.get("settled").and_then(Json::as_bool), Some(true));
        assert_eq!(reply.get("duplicate").and_then(Json::as_bool), Some(false));
        // Double-complete is idempotent and does NOT double-count.
        let reply = srv.handle_request(&Request::TaskComplete { lease_id, request_id: None });
        assert_eq!(reply.get("duplicate").and_then(Json::as_bool), Some(true));
        let stats = srv.stats();
        assert_eq!(stats.tasks_completed, 1);
        assert_eq!(stats.tasks_inflight, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn task_lease_filters_and_fail_requeues() {
        let (srv, dir) = test_server("lease-filter");
        let mut stale = entry("p1", "axpy", "n4096", "old");
        stale.recorded_at = 1000;
        srv.db().record(None, stale).unwrap();
        assert_eq!(srv.scan_once().unwrap(), 1);
        // Platform filter: a worker for another box gets nothing.
        let reply = srv.handle_request(&Request::TaskLease {
            kind: None,
            platform: Some("other-box".into()),
            ttl_s: None,
        });
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(false));
        // Kind filter: no sweep tasks queued.
        let reply = srv.handle_request(&Request::TaskLease {
            kind: Some(TaskKind::Sweep),
            platform: None,
            ttl_s: None,
        });
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(false));
        // Unfiltered lease takes it; fail requeues it for a retry.
        let reply = srv.handle_request(&Request::TaskLease {
            kind: None,
            platform: Some("p1".into()),
            ttl_s: Some(60),
        });
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
        let lease_id = reply.get("lease_id").and_then(Json::as_u64).unwrap();
        let reply = srv.handle_request(&Request::TaskFail {
            lease_id,
            error: Some("worker had no artifacts".into()),
        });
        assert_eq!(reply.get("requeued").and_then(Json::as_bool), Some(true));
        let stats = srv.stats();
        assert_eq!(stats.tasks_failed, 1);
        assert_eq!(stats.tasks_pending, 1);
        // Settling an unknown lease is an error reply, not a panic.
        let reply = srv
            .handle_request(&Request::TaskComplete { lease_id: 999_999, request_id: None });
        assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(false));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn record_portfolio_op_invalidates_the_portfolio_cache() {
        let (srv, dir) = test_server("record-portfolio");
        let mut old = test_portfolio("gemm");
        old.built_at = 1000;
        srv.db().record_portfolio("p1", Some(&fp()), old).unwrap();
        srv.refresh_snapshot().unwrap();
        let req = Request::Portfolio {
            platform: Some("p1".into()),
            kernel: "gemm".into(),
            dims: None,
            fingerprint: None,
        };
        let reply = srv.handle_request(&req);
        assert_eq!(
            reply.get("portfolio").and_then(|p| p.get("built_at")).and_then(Json::as_u64),
            Some(1000)
        );
        // A worker reports a rebuilt portfolio through the wire op...
        let fresh = test_portfolio("gemm");
        let fresh_built_at = fresh.built_at;
        let reply = srv.handle_request(&Request::RecordPortfolio {
            platform: Some("p1".into()),
            portfolio: Box::new(fresh),
            fingerprint: Some(fp()),
            spend_ms: None,
        });
        assert_eq!(reply.get("recorded").and_then(Json::as_bool), Some(true));
        // ...and the very next portfolio op serves the fresh build —
        // the wire op published a new snapshot generation.
        let reply = srv.handle_request(&req);
        assert_eq!(
            reply.get("portfolio").and_then(|p| p.get("built_at")).and_then(Json::as_u64),
            Some(fresh_built_at)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_portfolio_flows_to_rebuild_task_and_rebuild_resolves_it() {
        let (srv, dir) = test_server("stale-portfolio");
        let mut aged = test_portfolio("gemm");
        aged.built_at = 1000; // ancient
        let platform = srv.host().key();
        srv.db().record_portfolio(&platform, Some(&fp()), aged).unwrap();
        assert_eq!(srv.scan_once().unwrap(), 1);
        let reply = srv.handle_request(&Request::TaskLease {
            kind: None,
            platform: Some(platform.clone()),
            ttl_s: Some(60),
        });
        assert_eq!(reply.get("found").and_then(Json::as_bool), Some(true));
        let task = reply.get("task").unwrap();
        assert_eq!(task.get("kind").and_then(Json::as_str), Some("portfolio-rebuild"));
        assert_eq!(task.get("kernel").and_then(Json::as_str), Some("gemm"));
        let lease_id = reply.get("lease_id").and_then(Json::as_u64).unwrap();
        // The worker reports the rebuild and completes the lease.
        srv.handle_request(&Request::RecordPortfolio {
            platform: Some(platform.clone()),
            portfolio: Box::new(test_portfolio("gemm")),
            fingerprint: Some(fp()),
            spend_ms: None,
        });
        let reply = srv.handle_request(&Request::TaskComplete { lease_id, request_id: None });
        assert_eq!(reply.get("settled").and_then(Json::as_bool), Some(true));
        // Fresh build -> the next scan queues nothing.
        assert_eq!(srv.scan_once().unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn request_id_dedupes_replayed_records() {
        let (srv, dir) = test_server("dedupe");
        let rec = Request::Record {
            request_id: Some("cli-1".into()),
            entry: Box::new(entry("p1", "axpy", "n4096", "b256_u1")),
            fingerprint: None,
            spend_ms: None,
        };
        let first = srv.handle_request(&rec);
        assert_eq!(first.get("ok").and_then(Json::as_bool), Some(true));
        // A retry with the same id replays the stored reply without
        // re-executing the record.
        let second = srv.handle_request(&rec);
        assert_eq!(second, first);
        let stats = srv.stats();
        assert_eq!(stats.records, 1, "a replayed record must not re-execute");
        assert_eq!(stats.dedup_hits, 1);
        // A different id is a different request.
        let other = Request::Record {
            request_id: Some("cli-2".into()),
            entry: Box::new(entry("p1", "axpy", "n8192", "b128_u2")),
            fingerprint: None,
            spend_ms: None,
        };
        srv.handle_request(&other);
        assert_eq!(srv.stats().records, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn request_id_replays_task_complete_reply() {
        let (srv, dir) = test_server("dedupe-complete");
        let mut stale = entry("p1", "axpy", "n4096", "old");
        stale.recorded_at = 1000;
        srv.db().record(None, stale).unwrap();
        assert_eq!(srv.scan_once().unwrap(), 1);
        let reply = srv.handle_request(&Request::RetuneNext);
        let lease_id = reply.get("lease_id").and_then(Json::as_u64).unwrap();
        let req = Request::TaskComplete { lease_id, request_id: Some("w1-1".into()) };
        let first = srv.handle_request(&req);
        assert_eq!(first.get("duplicate").and_then(Json::as_bool), Some(false));
        // A replayed complete (lost reply, same id) gets the SAME
        // reply back — `duplicate:false`, not the scheduler's
        // duplicate path — so the worker cannot tell its first
        // attempt's reply was lost.
        let second = srv.handle_request(&req);
        assert_eq!(second, first);
        assert_eq!(srv.stats().tasks_completed, 1);
        assert_eq!(srv.stats().dedup_hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
