//! The append-only audit log writer.
//!
//! Appends are one `write_all` of `line + '\n'` to a file opened in
//! append mode — a crash mid-append leaves at most one torn, newline-
//! less tail, which re-open discards (truncates) and verification
//! tolerates.  After each successful append the sidecar head file is
//! republished atomically (unique tmp + rename) so truncation of the
//! published log is detectable.
//!
//! One `AuditLog` serializes all in-process writers behind a mutex:
//! entries from concurrent server threads interleave *between* entries,
//! never inside one, and the chain stays intact by construction.

use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::coordinator::perfdb::unix_now;
use crate::util::json::{self, Json};

use super::entry::{AuditEntry, AuditEvent, GENESIS_HASH};
use super::verify::scan_content;

/// The sidecar head path for a log at `log` (`<log>.head`).
pub fn head_path(log: &Path) -> PathBuf {
    let mut name = log.as_os_str().to_os_string();
    name.push(".head");
    PathBuf::from(name)
}

struct WriterState {
    file: std::fs::File,
    next_seq: u64,
    prev_hash: String,
}

/// A chained, crash-safe audit log open for appending.
pub struct AuditLog {
    path: PathBuf,
    state: Mutex<WriterState>,
    appended: AtomicU64,
}

impl std::fmt::Debug for AuditLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AuditLog").field("path", &self.path).finish()
    }
}

impl AuditLog {
    /// Open `path` for appending, creating it (and its parent
    /// directory) if absent.  An existing log is scanned: a torn tail
    /// is truncated away and the chain resumes from the last complete
    /// entry; a log whose *prefix* fails verification is refused —
    /// appending to a tampered log would only launder it.
    pub fn open(path: &Path) -> Result<AuditLog> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let content = match std::fs::read(path) {
            Ok(c) => c,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
        };
        let scan = scan_content(&content)
            .map_err(|e| anyhow::anyhow!("refusing to append to {}: {e}", path.display()))?;
        if scan.torn_tail {
            // Crash recovery: drop the partial tail so the next append
            // starts on a clean line boundary.
            let f = OpenOptions::new()
                .write(true)
                .open(path)
                .with_context(|| format!("opening {} for recovery", path.display()))?;
            f.set_len(scan.valid_len)
                .with_context(|| format!("truncating torn tail of {}", path.display()))?;
        }
        let (next_seq, prev_hash) = match scan.entries.last() {
            Some(last) => (last.seq + 1, last.hash.clone()),
            None => (0, GENESIS_HASH.to_string()),
        };
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Ok(AuditLog {
            path: path.to_path_buf(),
            state: Mutex::new(WriterState { file, next_seq, prev_hash }),
            appended: AtomicU64::new(0),
        })
    }

    /// The log file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Entries appended through this handle (not the whole file).
    pub fn appended(&self) -> u64 {
        self.appended.load(Ordering::Relaxed)
    }

    /// Append `event` stamped with the current wall clock.
    pub fn append(&self, event: AuditEvent) -> Result<u64> {
        self.append_at(unix_now(), event)
    }

    /// Append `event` stamped with `ts` (the simulation passes its own
    /// clock so logs stay bit-identical per seed).  Returns the entry's
    /// sequence number.
    pub fn append_at(&self, ts: u64, event: AuditEvent) -> Result<u64> {
        let mut state = self
            .state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let entry = AuditEntry::new(state.next_seq, ts, state.prev_hash.clone(), event);
        let mut line = entry.to_line();
        line.push('\n');
        state
            .file
            .write_all(line.as_bytes())
            .with_context(|| format!("appending to {}", self.path.display()))?;
        state
            .file
            .flush()
            .with_context(|| format!("flushing {}", self.path.display()))?;
        state.next_seq = entry.seq + 1;
        state.prev_hash = entry.hash.clone();
        // Republish the head.  A crash between the append above and
        // this rename leaves the head one entry behind, which the
        // verifier tolerates as the crash window.
        let head = head_path(&self.path);
        let tmp = head.with_extension(format!("head.tmp.{}", std::process::id()));
        let doc = Json::Obj(
            [
                ("hash".to_string(), json::s(&entry.hash)),
                ("seq".to_string(), json::int(entry.seq as i64)),
            ]
            .into_iter()
            .collect(),
        );
        std::fs::write(&tmp, doc.compact())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &head)
            .with_context(|| format!("publishing {}", head.display()))?;
        self.appended.fetch_add(1, Ordering::Relaxed);
        Ok(entry.seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::audit::verify::verify_log;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "portatune-audit-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn ev(lease_id: u64) -> AuditEvent {
        AuditEvent::TaskCompleted { lease_id }
    }

    #[test]
    fn appends_verify_and_resume_across_reopen() {
        let dir = tmp_dir("reopen");
        let path = dir.join("audit.log");
        {
            let log = AuditLog::open(&path).unwrap();
            for i in 0..5 {
                assert_eq!(log.append_at(100 + i, ev(i)).unwrap(), i);
            }
        }
        let report = verify_log(&path).unwrap();
        assert_eq!(report.entries, 5);
        assert!(report.head_present);
        assert_eq!(report.head_lag, 0);
        // Re-open continues the same chain.
        let log = AuditLog::open(&path).unwrap();
        assert_eq!(log.append_at(200, ev(99)).unwrap(), 5);
        assert_eq!(verify_log(&path).unwrap().entries, 6);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_discarded_on_reopen() {
        let dir = tmp_dir("torn");
        let path = dir.join("audit.log");
        {
            let log = AuditLog::open(&path).unwrap();
            for i in 0..3 {
                log.append_at(100, ev(i)).unwrap();
            }
        }
        // Simulate a crash mid-append: partial, newline-less tail.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"event\":{\"lease_id\":77,\"type\":\"task-com").unwrap();
        drop(f);
        let report = verify_log(&path).unwrap();
        assert_eq!(report.entries, 3);
        assert!(report.torn_tail);
        // Re-open truncates the tail and the chain continues cleanly.
        let log = AuditLog::open(&path).unwrap();
        assert_eq!(log.append_at(101, ev(3)).unwrap(), 3);
        let report = verify_log(&path).unwrap();
        assert_eq!(report.entries, 4);
        assert!(!report.torn_tail);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tampered_log_is_refused_for_append() {
        let dir = tmp_dir("tamper");
        let path = dir.join("audit.log");
        {
            let log = AuditLog::open(&path).unwrap();
            for i in 0..3 {
                log.append_at(100, ev(i)).unwrap();
            }
        }
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[10] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert!(AuditLog::open(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn head_lag_from_a_crash_window_is_tolerated() {
        let dir = tmp_dir("headlag");
        let path = dir.join("audit.log");
        let log = AuditLog::open(&path).unwrap();
        for i in 0..4 {
            log.append_at(100, ev(i)).unwrap();
        }
        // Roll the head back one entry, as if the process died between
        // appending entry 3 and republishing the head.
        let head = head_path(&path);
        let entries = crate::service::audit::verify::read_verified(&path).unwrap();
        let doc = Json::Obj(
            [
                ("hash".to_string(), json::s(&entries[2].hash)),
                ("seq".to_string(), json::int(2)),
            ]
            .into_iter()
            .collect(),
        );
        std::fs::write(&head, doc.compact()).unwrap();
        let report = verify_log(&path).unwrap();
        assert_eq!(report.entries, 4);
        assert_eq!(report.head_lag, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
