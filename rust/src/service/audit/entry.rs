//! Typed audit events and the hash-chained entry framing.
//!
//! Every consequential decision the daemon (or the fleet simulation)
//! makes becomes one [`AuditEvent`]; the writer wraps it into an
//! [`AuditEntry`] carrying a sequence number, a timestamp, the hash of
//! the previous entry, and its own hash over a canonical encoding.
//! Canonical means: the entry is serialized through [`Json::Obj`]
//! (BTreeMap-backed, so key order is fixed) and [`Json::compact`] (no
//! whitespace), so the same logical entry always hashes identically.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};
use crate::util::sha256;

/// The `prev` value of the first entry in a log.
pub const GENESIS_HASH: &str =
    "0000000000000000000000000000000000000000000000000000000000000000";

/// Why a deploy/lookup/portfolio answer was what it was.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeReason {
    /// The platform's own shard held a tuned entry (or portfolio).
    Exact,
    /// Served from the daemon's decision LRU (originally an exact hit).
    LruCache,
    /// Transferred from the nearest fingerprinted platform.
    Transfer {
        /// Platform key the answer was borrowed from.
        source: String,
        /// Fingerprint similarity to the source, in permille (0..=1000)
        /// — integer so the hashed encoding is exact.
        similarity_pm: u64,
    },
    /// Nothing to serve; the caller was told to explore/tune.
    Miss,
}

impl ServeReason {
    /// Stable wire spelling of the reason.
    pub fn as_str(&self) -> &'static str {
        match self {
            ServeReason::Exact => "exact",
            ServeReason::LruCache => "lru-cache",
            ServeReason::Transfer { .. } => "transfer",
            ServeReason::Miss => "miss",
        }
    }
}

/// One consequential decision, typed.
///
/// Task-lifecycle variants mirror the scheduler's transitions; `Served`
/// and `RecordAccepted` mirror the data plane.  All fields are plain
/// strings/integers so the canonical JSON encoding is exact (no
/// floats).
#[derive(Debug, Clone, PartialEq)]
pub enum AuditEvent {
    /// A tuning task entered the queue.
    TaskEnqueued {
        /// Task kind (`retune` / `sweep` / `portfolio-rebuild`).
        kind: String,
        /// Platform the task tunes for.
        platform: String,
        /// Kernel family.
        kernel: String,
        /// Workload tag, when the task is workload-scoped.
        tag: Option<String>,
        /// Why it was queued (staleness reason, "client-miss", ...).
        reason: String,
    },
    /// A worker leased a task.
    TaskLeased {
        /// Lease id granted.
        lease_id: u64,
        /// Task kind.
        kind: String,
        /// Platform the task tunes for.
        platform: String,
        /// Kernel family.
        kernel: String,
    },
    /// A leased task completed and settled.
    TaskCompleted {
        /// The settling lease.
        lease_id: u64,
    },
    /// A leased task failed (reported via `task-fail`).
    TaskFailed {
        /// The settling lease.
        lease_id: u64,
        /// The reported error text.
        error: String,
    },
    /// A lease expired and its task was requeued.
    TaskRequeued {
        /// Task kind.
        kind: String,
        /// Platform the task tunes for.
        platform: String,
        /// Kernel family.
        kernel: String,
        /// Attempts consumed so far (after the increment).
        attempts: u64,
    },
    /// A lease expired and its task was dropped (attempt budget spent).
    TaskDropped {
        /// Task kind.
        kind: String,
        /// Platform the task tunes for.
        platform: String,
        /// Kernel family.
        kernel: String,
        /// Attempts consumed when the task was abandoned.
        attempts: u64,
    },
    /// A tuning result was accepted into the shard store.
    RecordAccepted {
        /// Platform shard the entry landed in.
        platform: String,
        /// Kernel family.
        kernel: String,
        /// Workload tag.
        tag: String,
        /// Winning config id.
        config: String,
    },
    /// The regression sentinel confirmed a served config has gone slow
    /// on live hardware.  All evidence is integer permille so the
    /// hashed encoding is exact.
    Regression {
        /// Platform whose config regressed.
        platform: String,
        /// Kernel family.
        kernel: String,
        /// Workload tag.
        workload: String,
        /// Smoothed observed/stored cost ratio at confirmation,
        /// permille (1300 = running 1.3× the stored best).
        ratio_pm: u64,
        /// Samples in the evidence window.
        window_n: u64,
        /// Mean ratio over the evidence window, permille.
        window_mean_pm: u64,
        /// Worst ratio in the evidence window, permille.
        window_max_pm: u64,
    },
    /// A (platform, kernel) ledger cell crossed break-even: realized
    /// benefit caught up with tuning spend (see
    /// [`crate::coordinator::ledger`]).
    BreakEven {
        /// Platform whose ledger crossed.
        platform: String,
        /// Kernel family.
        kernel: String,
        /// Cumulative tuning spend at the crossing, core-milliseconds.
        spend_ms: u64,
        /// Cumulative realized benefit at the crossing,
        /// core-milliseconds.
        benefit_ms: u64,
    },
    /// A deploy/lookup/portfolio answer left the daemon.
    Served {
        /// The wire op (`lookup` / `deploy` / `portfolio`).
        op: String,
        /// Platform the answer was for.
        platform: String,
        /// Kernel family.
        kernel: String,
        /// Workload tag, when the op is workload-scoped.
        workload: Option<String>,
        /// Why this answer: exact / lru-cache / transfer / miss.
        reason: ServeReason,
        /// The request's wire `trace_id`, when the client sent one —
        /// links this decision to the emitted trace spans.
        trace_id: Option<String>,
    },
}

impl AuditEvent {
    /// Stable event-type tag used in the serialized form.
    pub fn kind(&self) -> &'static str {
        match self {
            AuditEvent::TaskEnqueued { .. } => "task-enqueued",
            AuditEvent::TaskLeased { .. } => "task-leased",
            AuditEvent::TaskCompleted { .. } => "task-completed",
            AuditEvent::TaskFailed { .. } => "task-failed",
            AuditEvent::TaskRequeued { .. } => "task-requeued",
            AuditEvent::TaskDropped { .. } => "task-dropped",
            AuditEvent::RecordAccepted { .. } => "record-accepted",
            AuditEvent::Regression { .. } => "regression",
            AuditEvent::BreakEven { .. } => "break-even",
            AuditEvent::Served { .. } => "served",
        }
    }

    /// JSON form (one object; key order canonical via `BTreeMap`).
    pub fn to_json(&self) -> Json {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("type".into(), json::s(self.kind()));
        match self {
            AuditEvent::TaskEnqueued { kind, platform, kernel, tag, reason } => {
                o.insert("kind".into(), json::s(kind));
                o.insert("platform".into(), json::s(platform));
                o.insert("kernel".into(), json::s(kernel));
                if let Some(tag) = tag {
                    o.insert("tag".into(), json::s(tag));
                }
                o.insert("reason".into(), json::s(reason));
            }
            AuditEvent::TaskLeased { lease_id, kind, platform, kernel } => {
                o.insert("lease_id".into(), json::int(*lease_id as i64));
                o.insert("kind".into(), json::s(kind));
                o.insert("platform".into(), json::s(platform));
                o.insert("kernel".into(), json::s(kernel));
            }
            AuditEvent::TaskCompleted { lease_id } => {
                o.insert("lease_id".into(), json::int(*lease_id as i64));
            }
            AuditEvent::TaskFailed { lease_id, error } => {
                o.insert("lease_id".into(), json::int(*lease_id as i64));
                o.insert("error".into(), json::s(error));
            }
            AuditEvent::TaskRequeued { kind, platform, kernel, attempts }
            | AuditEvent::TaskDropped { kind, platform, kernel, attempts } => {
                o.insert("kind".into(), json::s(kind));
                o.insert("platform".into(), json::s(platform));
                o.insert("kernel".into(), json::s(kernel));
                o.insert("attempts".into(), json::int(*attempts as i64));
            }
            AuditEvent::RecordAccepted { platform, kernel, tag, config } => {
                o.insert("platform".into(), json::s(platform));
                o.insert("kernel".into(), json::s(kernel));
                o.insert("tag".into(), json::s(tag));
                o.insert("config".into(), json::s(config));
            }
            AuditEvent::Regression {
                platform,
                kernel,
                workload,
                ratio_pm,
                window_n,
                window_mean_pm,
                window_max_pm,
            } => {
                o.insert("platform".into(), json::s(platform));
                o.insert("kernel".into(), json::s(kernel));
                o.insert("workload".into(), json::s(workload));
                o.insert("ratio_pm".into(), json::int(*ratio_pm as i64));
                o.insert("window_n".into(), json::int(*window_n as i64));
                o.insert("window_mean_pm".into(), json::int(*window_mean_pm as i64));
                o.insert("window_max_pm".into(), json::int(*window_max_pm as i64));
            }
            AuditEvent::BreakEven { platform, kernel, spend_ms, benefit_ms } => {
                o.insert("platform".into(), json::s(platform));
                o.insert("kernel".into(), json::s(kernel));
                o.insert("spend_ms".into(), json::int(*spend_ms as i64));
                o.insert("benefit_ms".into(), json::int(*benefit_ms as i64));
            }
            AuditEvent::Served { op, platform, kernel, workload, reason, trace_id } => {
                o.insert("op".into(), json::s(op));
                o.insert("platform".into(), json::s(platform));
                o.insert("kernel".into(), json::s(kernel));
                if let Some(w) = workload {
                    o.insert("workload".into(), json::s(w));
                }
                o.insert("reason".into(), json::s(reason.as_str()));
                if let ServeReason::Transfer { source, similarity_pm } = reason {
                    o.insert("source".into(), json::s(source));
                    o.insert("similarity_pm".into(), json::int(*similarity_pm as i64));
                }
                // Absent when the client sent none: an untraced Served
                // event encodes (and hashes) byte-identically to the
                // pre-trace format.
                if let Some(id) = trace_id {
                    o.insert("trace_id".into(), json::s(id));
                }
            }
        }
        Json::Obj(o)
    }

    /// Parse the JSON form back into the typed event.
    pub fn from_json(j: &Json) -> Result<AuditEvent> {
        let get = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("event lacks string field {k:?}"))
        };
        let get_u64 = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("event lacks integer field {k:?}"))
        };
        let opt = |k: &str| j.get(k).and_then(Json::as_str).map(str::to_string);
        let ty = get("type")?;
        Ok(match ty.as_str() {
            "task-enqueued" => AuditEvent::TaskEnqueued {
                kind: get("kind")?,
                platform: get("platform")?,
                kernel: get("kernel")?,
                tag: opt("tag"),
                reason: get("reason")?,
            },
            "task-leased" => AuditEvent::TaskLeased {
                lease_id: get_u64("lease_id")?,
                kind: get("kind")?,
                platform: get("platform")?,
                kernel: get("kernel")?,
            },
            "task-completed" => AuditEvent::TaskCompleted { lease_id: get_u64("lease_id")? },
            "task-failed" => {
                AuditEvent::TaskFailed { lease_id: get_u64("lease_id")?, error: get("error")? }
            }
            "task-requeued" => AuditEvent::TaskRequeued {
                kind: get("kind")?,
                platform: get("platform")?,
                kernel: get("kernel")?,
                attempts: get_u64("attempts")?,
            },
            "task-dropped" => AuditEvent::TaskDropped {
                kind: get("kind")?,
                platform: get("platform")?,
                kernel: get("kernel")?,
                attempts: get_u64("attempts")?,
            },
            "record-accepted" => AuditEvent::RecordAccepted {
                platform: get("platform")?,
                kernel: get("kernel")?,
                tag: get("tag")?,
                config: get("config")?,
            },
            "regression" => AuditEvent::Regression {
                platform: get("platform")?,
                kernel: get("kernel")?,
                workload: get("workload")?,
                ratio_pm: get_u64("ratio_pm")?,
                window_n: get_u64("window_n")?,
                window_mean_pm: get_u64("window_mean_pm")?,
                window_max_pm: get_u64("window_max_pm")?,
            },
            "break-even" => AuditEvent::BreakEven {
                platform: get("platform")?,
                kernel: get("kernel")?,
                spend_ms: get_u64("spend_ms")?,
                benefit_ms: get_u64("benefit_ms")?,
            },
            "served" => {
                let reason = match get("reason")?.as_str() {
                    "exact" => ServeReason::Exact,
                    "lru-cache" => ServeReason::LruCache,
                    "transfer" => ServeReason::Transfer {
                        source: get("source")?,
                        similarity_pm: get_u64("similarity_pm")?,
                    },
                    "miss" => ServeReason::Miss,
                    other => return Err(anyhow!("unknown serve reason {other:?}")),
                };
                AuditEvent::Served {
                    op: get("op")?,
                    platform: get("platform")?,
                    kernel: get("kernel")?,
                    workload: opt("workload"),
                    reason,
                    trace_id: opt("trace_id"),
                }
            }
            other => return Err(anyhow!("unknown audit event type {other:?}")),
        })
    }

    /// The platform key the event concerns, if any (replay filtering).
    pub fn platform(&self) -> Option<&str> {
        match self {
            AuditEvent::TaskEnqueued { platform, .. }
            | AuditEvent::TaskLeased { platform, .. }
            | AuditEvent::TaskRequeued { platform, .. }
            | AuditEvent::TaskDropped { platform, .. }
            | AuditEvent::RecordAccepted { platform, .. }
            | AuditEvent::Regression { platform, .. }
            | AuditEvent::BreakEven { platform, .. }
            | AuditEvent::Served { platform, .. } => Some(platform),
            AuditEvent::TaskCompleted { .. } | AuditEvent::TaskFailed { .. } => None,
        }
    }

    /// One human-oriented line for `audit replay`.
    pub fn describe(&self) -> String {
        match self {
            AuditEvent::TaskEnqueued { kind, platform, kernel, tag, reason } => {
                let tag = tag.as_deref().unwrap_or("-");
                format!("enqueue {kind} {kernel}/{tag} for {platform} ({reason})")
            }
            AuditEvent::TaskLeased { lease_id, kind, platform, kernel } => {
                format!("lease #{lease_id} {kind} {kernel} for {platform}")
            }
            AuditEvent::TaskCompleted { lease_id } => format!("complete #{lease_id}"),
            AuditEvent::TaskFailed { lease_id, error } => {
                format!("fail #{lease_id}: {error}")
            }
            AuditEvent::TaskRequeued { kind, platform, kernel, attempts } => {
                format!("requeue {kind} {kernel} for {platform} (attempt {attempts})")
            }
            AuditEvent::TaskDropped { kind, platform, kernel, attempts } => {
                format!("drop {kind} {kernel} for {platform} after {attempts} attempt(s)")
            }
            AuditEvent::RecordAccepted { platform, kernel, tag, config } => {
                format!("record {kernel}/{tag} = {config} for {platform}")
            }
            AuditEvent::Regression {
                platform, kernel, workload, ratio_pm, window_n, ..
            } => {
                format!(
                    "regression {kernel}/{workload} on {platform}: \
                     {ratio_pm}‰ of stored best over {window_n} samples"
                )
            }
            AuditEvent::BreakEven { platform, kernel, spend_ms, benefit_ms } => {
                format!(
                    "break-even {kernel} on {platform}: \
                     benefit {benefit_ms}ms ≥ spend {spend_ms}ms"
                )
            }
            AuditEvent::Served { op, platform, kernel, workload, reason, .. } => {
                let w = workload.as_deref().unwrap_or("-");
                let why = match reason {
                    ServeReason::Transfer { source, similarity_pm } => {
                        format!("transfer from {source} (similarity {similarity_pm}‰)")
                    }
                    other => other.as_str().to_string(),
                };
                format!("serve {op} {kernel}/{w} to {platform}: {why}")
            }
        }
    }
}

/// One framed, chained log entry.
#[derive(Debug, Clone, PartialEq)]
pub struct AuditEntry {
    /// Zero-based position in the log.
    pub seq: u64,
    /// Unix seconds (real clock in the daemon, sim clock in the sim).
    pub ts: u64,
    /// Hex SHA-256 of the previous entry's canonical preimage
    /// ([`GENESIS_HASH`] for the first entry).
    pub prev: String,
    /// Hex SHA-256 of this entry's canonical preimage.
    pub hash: String,
    /// The decision itself.
    pub event: AuditEvent,
}

impl AuditEntry {
    /// Build a chained entry: computes the hash over the canonical
    /// preimage (`{event,prev,seq,ts}` compact JSON).
    pub fn new(seq: u64, ts: u64, prev: String, event: AuditEvent) -> AuditEntry {
        let hash = sha256::hex_digest(preimage(seq, ts, &prev, &event).as_bytes());
        AuditEntry { seq, ts, prev, hash, event }
    }

    /// Serialized log line (no trailing newline).
    pub fn to_line(&self) -> String {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("event".into(), self.event.to_json());
        o.insert("hash".into(), json::s(&self.hash));
        o.insert("prev".into(), json::s(&self.prev));
        o.insert("seq".into(), json::int(self.seq as i64));
        o.insert("ts".into(), json::int(self.ts as i64));
        Json::Obj(o).compact()
    }

    /// Parse one log line (does *not* check the chain — that is the
    /// verifier's job; this only requires well-formedness).
    pub fn parse_line(line: &str) -> Result<AuditEntry> {
        let j = json::parse(line).map_err(|e| anyhow!("bad entry json: {e}"))?;
        let seq = j.get("seq").and_then(Json::as_u64).context("entry lacks seq")?;
        let ts = j.get("ts").and_then(Json::as_u64).context("entry lacks ts")?;
        let prev = j
            .get("prev")
            .and_then(Json::as_str)
            .context("entry lacks prev")?
            .to_string();
        let hash = j
            .get("hash")
            .and_then(Json::as_str)
            .context("entry lacks hash")?
            .to_string();
        let event = AuditEvent::from_json(j.get("event").context("entry lacks event")?)?;
        Ok(AuditEntry { seq, ts, prev, hash, event })
    }

    /// Recompute the hash this entry *should* carry.
    pub fn expected_hash(&self) -> String {
        sha256::hex_digest(preimage(self.seq, self.ts, &self.prev, &self.event).as_bytes())
    }
}

/// The canonical hashed preimage: everything except the hash itself.
fn preimage(seq: u64, ts: u64, prev: &str, event: &AuditEvent) -> String {
    let mut o: BTreeMap<String, Json> = BTreeMap::new();
    o.insert("event".into(), event.to_json());
    o.insert("prev".into(), json::s(prev));
    o.insert("seq".into(), json::int(seq as i64));
    o.insert("ts".into(), json::int(ts as i64));
    Json::Obj(o).compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn events() -> Vec<AuditEvent> {
        vec![
            AuditEvent::TaskEnqueued {
                kind: "sweep".into(),
                platform: "p-0".into(),
                kernel: "gemm".into(),
                tag: None,
                reason: "ttl-expired".into(),
            },
            AuditEvent::TaskLeased {
                lease_id: 7,
                kind: "sweep".into(),
                platform: "p-0".into(),
                kernel: "gemm".into(),
            },
            AuditEvent::TaskCompleted { lease_id: 7 },
            AuditEvent::TaskFailed { lease_id: 9, error: "kernel exploded".into() },
            AuditEvent::TaskRequeued {
                kind: "retune".into(),
                platform: "p-1".into(),
                kernel: "axpy".into(),
                attempts: 2,
            },
            AuditEvent::TaskDropped {
                kind: "retune".into(),
                platform: "p-1".into(),
                kernel: "axpy".into(),
                attempts: 3,
            },
            AuditEvent::RecordAccepted {
                platform: "p-0".into(),
                kernel: "gemm".into(),
                tag: "m64n64k64".into(),
                config: "o1_tm32".into(),
            },
            AuditEvent::Regression {
                platform: "p-0".into(),
                kernel: "gemm".into(),
                workload: "m64n64k64".into(),
                ratio_pm: 1480,
                window_n: 6,
                window_mean_pm: 1455,
                window_max_pm: 1620,
            },
            AuditEvent::BreakEven {
                platform: "p-0".into(),
                kernel: "gemm".into(),
                spend_ms: 42_000,
                benefit_ms: 43_750,
            },
            AuditEvent::Served {
                op: "deploy".into(),
                platform: "p-2".into(),
                kernel: "gemm".into(),
                workload: Some("m64n64k64".into()),
                reason: ServeReason::Transfer { source: "p-0".into(), similarity_pm: 875 },
                trace_id: Some("tc0ffee-1-0".into()),
            },
            AuditEvent::Served {
                op: "lookup".into(),
                platform: "p-0".into(),
                kernel: "gemm".into(),
                workload: Some("m64n64k64".into()),
                reason: ServeReason::Exact,
                trace_id: None,
            },
        ]
    }

    #[test]
    fn untraced_served_encodes_without_a_trace_field() {
        // Back-compat: a Served event with no trace_id must serialize
        // (and therefore hash) exactly as the pre-trace format did.
        let ev = AuditEvent::Served {
            op: "lookup".into(),
            platform: "p-0".into(),
            kernel: "gemm".into(),
            workload: None,
            reason: ServeReason::Miss,
            trace_id: None,
        };
        let line = ev.to_json().compact();
        assert!(!line.contains("trace_id"), "absent id must not appear: {line}");
        let traced = AuditEvent::Served {
            op: "lookup".into(),
            platform: "p-0".into(),
            kernel: "gemm".into(),
            workload: None,
            reason: ServeReason::Miss,
            trace_id: Some("t1-2-3".into()),
        };
        assert!(traced.to_json().compact().contains("\"trace_id\":\"t1-2-3\""));
        assert_eq!(AuditEvent::from_json(&traced.to_json()).unwrap(), traced);
    }

    #[test]
    fn every_event_round_trips() {
        for ev in events() {
            let parsed = AuditEvent::from_json(&ev.to_json()).unwrap();
            assert_eq!(parsed, ev);
        }
    }

    #[test]
    fn entry_line_round_trips_and_hash_is_stable() {
        let ev = events().remove(0);
        let e = AuditEntry::new(0, 1_700_000_000, GENESIS_HASH.into(), ev);
        assert_eq!(e.hash, e.expected_hash());
        let parsed = AuditEntry::parse_line(&e.to_line()).unwrap();
        assert_eq!(parsed, e);
        assert_eq!(parsed.expected_hash(), e.hash);
    }

    #[test]
    fn hash_covers_every_field() {
        let ev = || events().remove(2);
        let base = AuditEntry::new(3, 100, GENESIS_HASH.into(), ev());
        assert_ne!(AuditEntry::new(4, 100, GENESIS_HASH.into(), ev()).hash, base.hash);
        assert_ne!(AuditEntry::new(3, 101, GENESIS_HASH.into(), ev()).hash, base.hash);
        assert_ne!(AuditEntry::new(3, 100, base.hash.clone(), ev()).hash, base.hash);
        assert_ne!(
            AuditEntry::new(3, 100, GENESIS_HASH.into(), AuditEvent::TaskCompleted {
                lease_id: 8
            })
            .hash,
            base.hash
        );
    }

    #[test]
    fn describe_mentions_the_decision() {
        let lines: Vec<String> = events().iter().map(AuditEvent::describe).collect();
        assert!(lines.iter().any(|l| l.contains("transfer from p-0")));
        assert!(lines.iter().any(|l| l.contains("exact")));
        assert!(lines.iter().any(|l| l.contains("requeue")));
    }
}
