//! Tamper-evident audit log for tuning decisions.
//!
//! At fleet scale an operator has to be able to answer "*why* did this
//! platform get that config?" and "did anyone rewrite history?".  This
//! module makes every consequential decision — task lease / complete /
//! fail / requeue, record accepted, deploy/lookup/portfolio answers
//! with their reason (exact hit, LRU cache, transfer from platform X,
//! miss) — a typed [`AuditEvent`] appended to a hash-chained log:
//!
//! * **[`entry`]** — the event types and the framed [`AuditEntry`]:
//!   `{event, hash, prev, seq, ts}` per line, compact canonical JSON,
//!   `hash = SHA-256(preimage)` and `prev` = the previous entry's hash
//!   (genesis: 64 zeros).
//! * **[`writer`]** — [`AuditLog`]: append-only, crash-safe (single
//!   `write_all` per entry, torn tails truncated on re-open), sidecar
//!   head file republished atomically after each append so tail
//!   truncation is detectable.
//! * **[`verify`]** — [`verify_log`] walks the chain and fails with the
//!   exact entry index on any alteration; [`read_verified`] feeds
//!   `portatune audit replay`.
//!
//! The daemon threads entries through `server.rs` / `scheduler.rs`, the
//! fleet worker writes its own local log, and the fleet simulation
//! (`crate::sim`) verifies its log after every run — each layer
//! exercises the other.

pub mod entry;
pub mod verify;
pub mod writer;

pub use entry::{AuditEntry, AuditEvent, ServeReason, GENESIS_HASH};
pub use verify::{read_verified, verify_log, VerifyError, VerifyReport};
pub use writer::{head_path, AuditLog};
