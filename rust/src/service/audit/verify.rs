//! Chain verification: detect tampering and truncation, precisely.
//!
//! The verifier walks the log line by line, recomputing every entry's
//! hash over its canonical preimage and checking the `prev` linkage and
//! sequence numbering.  Failures carry the exact entry index, so a
//! flipped byte in entry 17 reports *entry 17*, not "chain bad".
//!
//! Truncation needs one extra commitment: a chain that simply stops is
//! internally consistent.  The writer therefore maintains a sidecar
//! *head* file (`<log>.head`, written atomically via tmp + rename)
//! recording the latest entry's `(seq, hash)`; a log shorter than its
//! head is truncated.  The head may lag the log by appends made in the
//! crash window between appending and re-publishing the head — that lag
//! is tolerated (and reported), the reverse is not.
//!
//! Framing tolerance: a final line without a terminating newline is a
//! *torn tail* (a writer died mid-append).  It is never counted as an
//! entry — the writer discards it on re-open — and verification of the
//! complete prefix proceeds normally.

use std::path::Path;

use crate::util::json::{self, Json};

use super::entry::{AuditEntry, GENESIS_HASH};
use super::writer::head_path;

/// Why verification failed.  Every variant that concerns a specific
/// entry names its zero-based index.
#[derive(Debug, Clone, PartialEq)]
pub enum VerifyError {
    /// The log (or head) could not be read.
    Io(String),
    /// Entry `index` is not a well-formed framed entry.
    Malformed {
        /// Zero-based entry index.
        index: u64,
        /// Parser detail.
        detail: String,
    },
    /// Entry `index` carries the wrong sequence number.
    SeqMismatch {
        /// Zero-based entry index (the expected sequence number).
        index: u64,
        /// The sequence number actually stored.
        found: u64,
    },
    /// Entry `index`'s stored hash does not match its recomputed hash —
    /// some byte of the entry was altered.
    HashMismatch {
        /// Zero-based entry index.
        index: u64,
    },
    /// Entry `index`'s `prev` does not match the previous entry's hash.
    ChainBreak {
        /// Zero-based entry index.
        index: u64,
    },
    /// The log ends before the entry the head file committed to —
    /// the tail was truncated.
    Truncated {
        /// Index of the first missing entry (== number of complete
        /// entries present).
        index: u64,
        /// The sequence number the head file committed to.
        head_seq: u64,
    },
    /// The head file's hash disagrees with the entry it points at.
    HeadMismatch {
        /// The head's committed sequence number.
        head_seq: u64,
    },
    /// The head file exists but is not well-formed.
    HeadMalformed(String),
}

impl VerifyError {
    /// The entry index the failure pins down, when it concerns one.
    /// For [`VerifyError::Truncated`] this is the first missing index.
    pub fn index(&self) -> Option<u64> {
        match self {
            VerifyError::Malformed { index, .. }
            | VerifyError::SeqMismatch { index, .. }
            | VerifyError::HashMismatch { index }
            | VerifyError::ChainBreak { index }
            | VerifyError::Truncated { index, .. } => Some(*index),
            _ => None,
        }
    }
}

impl std::fmt::Display for VerifyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VerifyError::Io(e) => write!(f, "audit log unreadable: {e}"),
            VerifyError::Malformed { index, detail } => {
                write!(f, "entry {index} is malformed: {detail}")
            }
            VerifyError::SeqMismatch { index, found } => {
                write!(f, "entry {index} carries sequence number {found}")
            }
            VerifyError::HashMismatch { index } => {
                write!(f, "entry {index} was altered (stored hash does not match contents)")
            }
            VerifyError::ChainBreak { index } => {
                write!(f, "entry {index} does not chain to its predecessor")
            }
            VerifyError::Truncated { index, head_seq } => write!(
                f,
                "log truncated at entry {index}: head commits to sequence {head_seq}"
            ),
            VerifyError::HeadMismatch { head_seq } => {
                write!(f, "head hash disagrees with entry {head_seq}")
            }
            VerifyError::HeadMalformed(e) => write!(f, "head file malformed: {e}"),
        }
    }
}

impl std::error::Error for VerifyError {}

/// What a successful verification found.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyReport {
    /// Complete, chain-verified entries.
    pub entries: u64,
    /// Whether a torn (partial, newline-less) tail was discarded.
    pub torn_tail: bool,
    /// Whether the sidecar head file was present.
    pub head_present: bool,
    /// Entries past the head's commitment (the crash window), when the
    /// head was present.
    pub head_lag: u64,
}

/// The verified scan shared by the verifier and the writer's re-open
/// recovery.
pub(crate) struct Scan {
    /// Every complete entry, in order, chain-verified.
    pub entries: Vec<AuditEntry>,
    /// Byte length of the valid prefix (complete entries + newlines).
    pub valid_len: u64,
    /// Whether trailing torn bytes follow the valid prefix.
    pub torn_tail: bool,
}

/// Walk raw log content, verifying framing, sequence, per-entry hashes,
/// and prev-linkage.  Fails at the first bad entry.
pub(crate) fn scan_content(content: &[u8]) -> Result<Scan, VerifyError> {
    let mut entries = Vec::new();
    let mut valid_len: u64 = 0;
    let mut prev_hash = GENESIS_HASH.to_string();
    let mut rest = content;
    let mut torn_tail = false;
    while !rest.is_empty() {
        let Some(nl) = rest.iter().position(|&b| b == b'\n') else {
            // No terminating newline: a writer died mid-append.  The
            // partial tail is discarded, never counted.
            torn_tail = true;
            break;
        };
        let index = entries.len() as u64;
        let line_bytes = &rest[..nl];
        let line = std::str::from_utf8(line_bytes).map_err(|e| VerifyError::Malformed {
            index,
            detail: format!("not utf-8: {e}"),
        })?;
        let entry = AuditEntry::parse_line(line)
            .map_err(|e| VerifyError::Malformed { index, detail: format!("{e:#}") })?;
        if entry.seq != index {
            return Err(VerifyError::SeqMismatch { index, found: entry.seq });
        }
        if entry.hash != entry.expected_hash() {
            return Err(VerifyError::HashMismatch { index });
        }
        if entry.prev != prev_hash {
            return Err(VerifyError::ChainBreak { index });
        }
        prev_hash = entry.hash.clone();
        entries.push(entry);
        valid_len += nl as u64 + 1;
        rest = &rest[nl + 1..];
    }
    Ok(Scan { entries, valid_len, torn_tail })
}

/// Verify the chain in `path` (and its sidecar head, when present).
///
/// A missing log file is an error; a missing head file downgrades
/// truncation detection (reported via
/// [`head_present`](VerifyReport::head_present)) but the chain itself
/// is still checked.
pub fn verify_log(path: &Path) -> Result<VerifyReport, VerifyError> {
    let (scan, report) = verified_scan(path)?;
    drop(scan);
    Ok(report)
}

/// Verify `path` and return its entries (the replay input).
pub fn read_verified(path: &Path) -> Result<Vec<AuditEntry>, VerifyError> {
    let (scan, _) = verified_scan(path)?;
    Ok(scan.entries)
}

fn verified_scan(path: &Path) -> Result<(Scan, VerifyReport), VerifyError> {
    let content = std::fs::read(path)
        .map_err(|e| VerifyError::Io(format!("{}: {e}", path.display())))?;
    let scan = scan_content(&content)?;
    let mut report = VerifyReport {
        entries: scan.entries.len() as u64,
        torn_tail: scan.torn_tail,
        head_present: false,
        head_lag: 0,
    };
    let head = head_path(path);
    if head.exists() {
        let text = std::fs::read_to_string(&head)
            .map_err(|e| VerifyError::Io(format!("{}: {e}", head.display())))?;
        let j = json::parse(&text).map_err(|e| VerifyError::HeadMalformed(e.to_string()))?;
        let head_seq = j
            .get("seq")
            .and_then(Json::as_u64)
            .ok_or_else(|| VerifyError::HeadMalformed("head lacks seq".into()))?;
        let head_hash = j
            .get("hash")
            .and_then(Json::as_str)
            .ok_or_else(|| VerifyError::HeadMalformed("head lacks hash".into()))?;
        report.head_present = true;
        match scan.entries.get(head_seq as usize) {
            None => {
                return Err(VerifyError::Truncated {
                    index: scan.entries.len() as u64,
                    head_seq,
                })
            }
            Some(e) if e.hash != head_hash => {
                return Err(VerifyError::HeadMismatch { head_seq })
            }
            Some(_) => {}
        }
        report.head_lag = scan.entries.len() as u64 - 1 - head_seq;
    }
    Ok((scan, report))
}
