//! Deterministic fault injection for the serve/work path.
//!
//! Distributed-tuning failures — dropped connections, half-written
//! shard files, workers dying mid-lease — are rare in the wild and
//! impossible to reproduce on demand, which makes the recovery paths
//! the least-tested code in the daemon.  This module turns those
//! failures into a *seeded schedule*: a [`FaultPlan`] names injection
//! points (see [`InjectionPoint`]) threaded through `server.rs`,
//! `client.rs`, `scheduler.rs`, `worker/mod.rs`, and `perfdb.rs`, and
//! decides per occurrence whether the fault fires.  The same seed
//! always produces the same per-point decision sequence, so a chaos
//! run that loses a task is replayable exactly.
//!
//! Design constraints:
//!
//! * **Off by default, zero-cost when off** — every hook first checks
//!   one relaxed atomic bool; no plan installed means no lock, no RNG,
//!   no branch beyond that load.
//! * **Deterministic per point** — the decision for the Nth occurrence
//!   of a point is a pure function of `(seed, point, N)`, independent
//!   of thread interleaving across *different* points.  (Near a
//!   `max_hits` cap, racing threads may disagree about *which* of two
//!   simultaneous occurrences consumes the final budget slot, but the
//!   total never exceeds the cap.)
//! * **Bounded** — every point carries a `max_hits` budget, so a
//!   faulted system quiesces: bounded client retries eventually
//!   succeed, and chaos tests terminate.
//!
//! Configuration is a spec string (CLI `--faults`, env
//! `PORTATUNE_FAULTS`) of comma-separated `point:probability[:max_hits]`
//! clauses, e.g. `server.reply-drop:0.2:5,shard.torn-write:1.0:2`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Seed used when a spec is given without an explicit seed.
pub const DEFAULT_SEED: u64 = 0x00C0_FFEE;

/// How long a stall-type fault ([`stall`]) sleeps when it fires.  Short
/// enough to keep chaos tests fast, long enough to trip the server's
/// per-connection read deadline and the client's socket timeouts.
pub const STALL: Duration = Duration::from_millis(50);

/// Environment variable holding the fault spec string.
pub const ENV_SPEC: &str = "PORTATUNE_FAULTS";

/// Environment variable holding the schedule seed (u64).
pub const ENV_SEED: &str = "PORTATUNE_FAULT_SEED";

/// Named places in the serve/work path where a fault can fire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectionPoint {
    /// Client drops the connection after connect, before the request
    /// line is written (spec name `client.connect-drop`).
    ClientConnectDrop,
    /// Client stalls between writing the request and reading the reply
    /// (spec name `client.read-stall`) — exercises the server's idle
    /// deadline and the client's socket read timeout.
    ClientReadStall,
    /// Server drops the connection instead of writing the reply (spec
    /// name `server.reply-drop`) — the op executed, the ack is lost.
    ServerReplyDrop,
    /// Server stalls before reading the next request line (spec name
    /// `server.read-stall`).
    ServerReadStall,
    /// Shard commit writes a truncated document to the temp file and
    /// fails before the rename (spec name `shard.torn-write`) — the
    /// published shard is untouched, the writer sees an error.
    ShardTornWrite,
    /// Scheduler delays settling a lease inside complete/fail (spec
    /// name `lease.settle-delay`).
    LeaseSettleDelay,
    /// Worker "crashes" between executing a task and reporting the
    /// outcome (spec name `worker.crash`) — neither `task-complete`
    /// nor `task-fail` is sent; only lease expiry recovers the task.
    WorkerCrash,
}

/// Every injection point, in index order.
pub const ALL_POINTS: [InjectionPoint; 7] = [
    InjectionPoint::ClientConnectDrop,
    InjectionPoint::ClientReadStall,
    InjectionPoint::ServerReplyDrop,
    InjectionPoint::ServerReadStall,
    InjectionPoint::ShardTornWrite,
    InjectionPoint::LeaseSettleDelay,
    InjectionPoint::WorkerCrash,
];

impl InjectionPoint {
    /// Stable spec-string spelling of the point.
    pub fn as_str(&self) -> &'static str {
        match self {
            InjectionPoint::ClientConnectDrop => "client.connect-drop",
            InjectionPoint::ClientReadStall => "client.read-stall",
            InjectionPoint::ServerReplyDrop => "server.reply-drop",
            InjectionPoint::ServerReadStall => "server.read-stall",
            InjectionPoint::ShardTornWrite => "shard.torn-write",
            InjectionPoint::LeaseSettleDelay => "lease.settle-delay",
            InjectionPoint::WorkerCrash => "worker.crash",
        }
    }

    /// Parse a spec-string spelling back into a point.
    pub fn parse(s: &str) -> Option<InjectionPoint> {
        ALL_POINTS.iter().copied().find(|p| p.as_str() == s)
    }

    fn index(&self) -> usize {
        match self {
            InjectionPoint::ClientConnectDrop => 0,
            InjectionPoint::ClientReadStall => 1,
            InjectionPoint::ServerReplyDrop => 2,
            InjectionPoint::ServerReadStall => 3,
            InjectionPoint::ShardTornWrite => 4,
            InjectionPoint::LeaseSettleDelay => 5,
            InjectionPoint::WorkerCrash => 6,
        }
    }
}

const POINT_COUNT: usize = ALL_POINTS.len();

/// One point's schedule: fire with this probability, at most this often.
#[derive(Debug, Clone, Copy)]
struct PointPlan {
    probability: f64,
    max_hits: u64,
}

/// A seeded, bounded schedule of faults over the named injection
/// points.  Install one globally with [`install`]; hooks consult it
/// through [`hit`]/[`stall`].
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    points: [Option<PointPlan>; POINT_COUNT],
    occurrences: [AtomicU64; POINT_COUNT],
    fired: [AtomicU64; POINT_COUNT],
}

impl FaultPlan {
    /// Parse a spec string (`point:probability[:max_hits]`, comma
    /// separated) into a plan with the given seed.
    pub fn from_spec(spec: &str, seed: u64) -> Result<FaultPlan> {
        let mut points: [Option<PointPlan>; POINT_COUNT] = [None; POINT_COUNT];
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let mut parts = clause.split(':');
            let name = parts.next().unwrap_or("");
            let point = InjectionPoint::parse(name).ok_or_else(|| {
                let known: Vec<&str> = ALL_POINTS.iter().map(|p| p.as_str()).collect();
                anyhow::anyhow!("unknown injection point {name:?} (known: {known:?})")
            })?;
            let prob: f64 = match parts.next() {
                Some(p) => p
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad probability in fault clause {clause:?}"))?,
                None => bail!("fault clause {clause:?} lacks a probability"),
            };
            if !(0.0..=1.0).contains(&prob) {
                bail!("probability out of [0,1] in fault clause {clause:?}");
            }
            let max_hits: u64 = match parts.next() {
                Some(h) => h
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad max_hits in fault clause {clause:?}"))?,
                None => u64::MAX,
            };
            if parts.next().is_some() {
                bail!("trailing fields in fault clause {clause:?}");
            }
            points[point.index()] = Some(PointPlan { probability: prob, max_hits });
        }
        Ok(FaultPlan {
            seed,
            points,
            occurrences: std::array::from_fn(|_| AtomicU64::new(0)),
            fired: std::array::from_fn(|_| AtomicU64::new(0)),
        })
    }

    /// Build a plan from `PORTATUNE_FAULTS` / `PORTATUNE_FAULT_SEED`.
    /// Returns `Ok(None)` when the spec variable is unset or empty.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        let spec = match std::env::var(ENV_SPEC) {
            Ok(s) if !s.trim().is_empty() => s,
            _ => return Ok(None),
        };
        let seed = match std::env::var(ENV_SEED) {
            Ok(s) => s
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad {ENV_SEED}: {s:?} (want u64)"))?,
            Err(_) => DEFAULT_SEED,
        };
        Ok(Some(FaultPlan::from_spec(&spec, seed)?))
    }

    /// The seed the schedule was built with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Decide whether this occurrence of `point` faults.  Counts the
    /// occurrence either way; never fires more than the point's
    /// `max_hits` budget.
    pub fn decide(&self, point: InjectionPoint) -> bool {
        let i = point.index();
        let Some(plan) = self.points[i] else { return false };
        let n = self.occurrences[i].fetch_add(1, Ordering::Relaxed);
        if self.fired[i].load(Ordering::Relaxed) >= plan.max_hits {
            return false;
        }
        let mut rng = Rng::new(mix(self.seed, i as u64, n));
        if rng.next_f64() >= plan.probability {
            return false;
        }
        // Claim a budget slot; back off if a racing occurrence took the
        // last one between the load above and here.
        self.fired[i].fetch_add(1, Ordering::Relaxed) < plan.max_hits
    }

    /// How many times `point` has fired so far.
    pub fn fired(&self, point: InjectionPoint) -> u64 {
        let i = point.index();
        self.fired[i].load(Ordering::Relaxed).min(self.points[i].map_or(0, |p| p.max_hits))
    }

    /// How many times `point` has been consulted so far.
    pub fn occurrences(&self, point: InjectionPoint) -> u64 {
        self.occurrences[point.index()].load(Ordering::Relaxed)
    }
}

/// Mix (seed, point, occurrence) into an RNG seed: SplitMix-style odd
/// multipliers keep nearby inputs decorrelated.
fn mix(seed: u64, point: u64, n: u64) -> u64 {
    let mut x = seed
        ^ point.wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ n.wrapping_mul(0x2545_f491_4f6c_dd1d);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn slot() -> &'static Mutex<Option<Arc<FaultPlan>>> {
    static SLOT: OnceLock<Mutex<Option<Arc<FaultPlan>>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Install `plan` as the process-wide fault schedule (replacing any
/// previous one) and return a handle for inspecting its counters.
pub fn install(plan: FaultPlan) -> Arc<FaultPlan> {
    let plan = Arc::new(plan);
    *slot().lock().unwrap_or_else(std::sync::PoisonError::into_inner) = Some(Arc::clone(&plan));
    ENABLED.store(true, Ordering::SeqCst);
    plan
}

/// Install a plan from the environment, if one is configured.
pub fn install_from_env() -> Result<Option<Arc<FaultPlan>>> {
    Ok(FaultPlan::from_env()?.map(install))
}

/// Remove the process-wide fault schedule; all hooks become no-ops.
pub fn clear() {
    ENABLED.store(false, Ordering::SeqCst);
    *slot().lock().unwrap_or_else(std::sync::PoisonError::into_inner) = None;
}

/// Whether a fault schedule is currently installed.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The currently installed schedule, if any.
pub fn active() -> Option<Arc<FaultPlan>> {
    if !enabled() {
        return None;
    }
    slot().lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone()
}

/// Consult the installed schedule at `point`.  The zero-cost path:
/// with no schedule installed this is one relaxed atomic load.
pub fn hit(point: InjectionPoint) -> bool {
    match active() {
        Some(plan) => plan.decide(point),
        None => false,
    }
}

/// Like [`hit`], but a firing fault sleeps [`STALL`] instead of being
/// returned to the caller for explicit handling.  Returns whether the
/// stall happened.
pub fn stall(point: InjectionPoint) -> bool {
    if hit(point) {
        std::thread::sleep(STALL);
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trips_every_point_name() {
        for p in ALL_POINTS {
            assert_eq!(InjectionPoint::parse(p.as_str()), Some(p));
        }
        assert_eq!(InjectionPoint::parse("bogus"), None);
    }

    #[test]
    fn spec_parse_errors_are_loud() {
        assert!(FaultPlan::from_spec("bogus:0.5", 1).is_err());
        assert!(FaultPlan::from_spec("worker.crash", 1).is_err());
        assert!(FaultPlan::from_spec("worker.crash:1.5", 1).is_err());
        assert!(FaultPlan::from_spec("worker.crash:0.5:x", 1).is_err());
        assert!(FaultPlan::from_spec("worker.crash:0.5:1:junk", 1).is_err());
        assert!(FaultPlan::from_spec("", 1).is_ok());
    }

    #[test]
    fn same_seed_same_schedule() {
        let spec = "server.reply-drop:0.3,worker.crash:0.5:10";
        let a = FaultPlan::from_spec(spec, 42).unwrap();
        let b = FaultPlan::from_spec(spec, 42).unwrap();
        for _ in 0..1000 {
            assert_eq!(
                a.decide(InjectionPoint::ServerReplyDrop),
                b.decide(InjectionPoint::ServerReplyDrop)
            );
            assert_eq!(a.decide(InjectionPoint::WorkerCrash), b.decide(InjectionPoint::WorkerCrash));
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let a = FaultPlan::from_spec("server.reply-drop:0.5", 1).unwrap();
        let b = FaultPlan::from_spec("server.reply-drop:0.5", 2).unwrap();
        let same = (0..256)
            .filter(|_| {
                a.decide(InjectionPoint::ServerReplyDrop)
                    == b.decide(InjectionPoint::ServerReplyDrop)
            })
            .count();
        assert!(same < 256, "independent seeds produced identical schedules");
    }

    #[test]
    fn max_hits_bounds_firing() {
        let plan = FaultPlan::from_spec("shard.torn-write:1.0:3", 7).unwrap();
        let fired = (0..100).filter(|_| plan.decide(InjectionPoint::ShardTornWrite)).count();
        assert_eq!(fired, 3);
        assert_eq!(plan.fired(InjectionPoint::ShardTornWrite), 3);
        assert_eq!(plan.occurrences(InjectionPoint::ShardTornWrite), 100);
    }

    #[test]
    fn unconfigured_point_never_fires() {
        let plan = FaultPlan::from_spec("worker.crash:1.0", 7).unwrap();
        assert!(!plan.decide(InjectionPoint::ClientConnectDrop));
    }

    #[test]
    fn global_hooks_are_inert_without_a_plan() {
        // Other tests in this binary may install plans; serialize by
        // clearing first (the global is process-wide by design).
        clear();
        assert!(!enabled());
        assert!(!hit(InjectionPoint::WorkerCrash));
        assert!(!stall(InjectionPoint::LeaseSettleDelay));
        let plan = install(FaultPlan::from_spec("worker.crash:1.0:1", 3).unwrap());
        assert!(hit(InjectionPoint::WorkerCrash));
        assert!(!hit(InjectionPoint::WorkerCrash), "budget of 1 exhausted");
        assert_eq!(plan.fired(InjectionPoint::WorkerCrash), 1);
        clear();
        assert!(!hit(InjectionPoint::WorkerCrash));
    }

    #[test]
    fn env_plan_requires_spec() {
        // No env mutation here (racy across threads): absent spec var
        // is the common case in the test environment.
        if std::env::var(ENV_SPEC).is_err() {
            assert!(FaultPlan::from_env().unwrap().is_none());
        }
    }
}
