//! Deterministic fleet simulation: the real scheduling, storage, and
//! transfer engines driven by a synthetic fleet on a virtual clock.
//!
//! The chaos suite (`tests/chaos.rs`) exercises the daemon over real
//! sockets with wall-clock timing — strong on protocol faults, weak on
//! *scale* and *repeatability*.  This module is the complement: a
//! single-threaded discrete-event loop that drives the real
//! [`TaskQueue`], [`ShardedDb`], and transfer/portfolio ranking against
//! a synthetic population of fingerprints, with Poisson query traffic,
//! fingerprint drift, and worker churn from a seeded [`FaultPlan`].
//! Everything — platform genesis, task durations, crashes, traffic —
//! derives from one seed, and the clock is a plain `u64`, so a run is
//! bit-reproducible: same seed, same decision sequence, same audit log
//! bytes.  `benches/fleet_sim.rs` turns the report into a CI gate.
//!
//! What the simulation measures:
//!
//! - **convergence time**: sim-seconds from the first scan until every
//!   initially-stale identity has been refreshed — how long the fleet
//!   takes to work off a cold backlog.  (The queue itself keeps
//!   churning afterwards: refreshed data re-ages past the TTL, which
//!   is the steady state, not a failure.)
//! - **duplicate-work rate**: executions that finish only to learn the
//!   task was already settled by someone else (the lease expired
//!   mid-run and the requeued copy won), over all finished executions.
//! - **staleness at serve**: age (`now - recorded_at`) of every entry
//!   actually served to lookup traffic, accumulated in a *local*
//!   [`Histogram`](crate::obs::Histogram) (the shared telemetry bucket
//!   scheme — p50/p95/p99 are bucket upper bounds, ≤25% above the true
//!   value; a local instance, not the process registry, keeps two runs
//!   of the same seed bit-identical).
//! - **regression detection latency**: a seeded subset of platforms
//!   suffers a mid-run hardware slowdown; periodic telemetry feeds a
//!   sim-local [`Sentinel`] (same thresholds as the daemon) and the
//!   report carries sim-seconds from each injected slowdown to its
//!   confirmed detection — plus a false-positive count the bench gates
//!   at exactly zero (stationary platforms only ever report ±5% noise,
//!   which must never fire).
//! - **tuning economics**: every simulated execution bills its
//!   core-milliseconds into the real shard [`Ledger`] (write-through,
//!   like entries), so the run ends with a spend/benefit total the
//!   bench can assert is non-trivial and consistent with the mirror.
//!
//! Every consequential decision goes through a real [`AuditLog`]
//! stamped with the sim clock, and [`run`] verifies the chain before
//! returning — the simulation cannot report success over a log that
//! would not survive `portatune audit verify`.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::ledger::{Ledger, LedgerDelta};
use crate::coordinator::perfdb::{DbEntry, Shard, ShardedDb};
use crate::coordinator::platform::Fingerprint;
use crate::coordinator::portfolio::{Portfolio, PortfolioItem, FEATURE_NAMES};
use crate::obs::Histogram;
use crate::service::audit::{verify_log, AuditEvent, AuditLog, ServeReason};
use crate::service::faults::{FaultPlan, InjectionPoint};
use crate::service::scheduler::{
    CompleteOutcome, StaleReason, TaskIdentity, TaskKind, TaskQueue, TuningTask,
};
use crate::service::sentinel::{Sentinel, SentinelConfig, SentinelEvent};
use crate::service::transfer;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Everything a simulation run is parameterized by.  All durations are
/// sim-seconds; nothing here reads the wall clock.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Synthetic platform population size.
    pub platforms: usize,
    /// Simulated workers draining the task queue.
    pub workers: usize,
    /// Sim-seconds to run after the warm-up offset.
    pub duration_s: u64,
    /// Master seed: population, traffic, durations, and churn all
    /// derive from it.
    pub seed: u64,
    /// Staleness TTL the scan enforces (entries are seeded older than
    /// this, so the whole population is a cold backlog at t0).
    pub ttl_s: u64,
    /// Worker-lease TTL.
    pub lease_ttl_s: u64,
    /// Scan cadence.
    pub scan_every_s: u64,
    /// Mean lookup arrivals per sim-second (Poisson).
    pub traffic_per_s: f64,
    /// How many platforms drift (fingerprint changes under a stable
    /// key) during the run.
    pub drift_platforms: usize,
    /// How many platforms suffer a mid-run hardware slowdown (served
    /// configs genuinely get slower; the sentinel must catch it).
    pub slow_platforms: usize,
    /// Slowdown severity, permille (1700 = costs inflate 1.7×) —
    /// safely past the sentinel's 1300‰ firing bar.
    pub slow_factor_pm: u64,
    /// Cadence of the fleet's cost telemetry: every platform reports
    /// one observed cost per tracked (kernel, workload) this often.
    pub telemetry_every_s: u64,
    /// Per-lease probability that the leasing worker crashes before
    /// settling (routed through the real [`FaultPlan`]).
    pub crash_prob: f64,
    /// Directory for the real shard store the sim writes through to.
    /// **Recreated from scratch** at the start of every run.
    pub db_dir: PathBuf,
    /// Path for the hash-chained audit log of every decision.  Also
    /// recreated per run.
    pub audit_path: PathBuf,
}

impl SimConfig {
    /// The CI-gated configuration: a 1000-platform fleet drained by 8
    /// workers under churn, sized to converge within the run.
    pub fn fleet(root: &std::path::Path, seed: u64) -> SimConfig {
        SimConfig {
            platforms: 1000,
            workers: 8,
            duration_s: 7200,
            seed,
            ttl_s: 600,
            lease_ttl_s: 60,
            scan_every_s: 60,
            traffic_per_s: 2.0,
            drift_platforms: 10,
            slow_platforms: 10,
            slow_factor_pm: 1700,
            telemetry_every_s: 30,
            crash_prob: 0.05,
            db_dir: root.join("shards"),
            audit_path: root.join("audit.log"),
        }
    }

    /// A smoke-sized variant (fast enough for unit tests and
    /// `BENCH_QUICK=1`): same mechanics, smaller fleet.
    pub fn smoke(root: &std::path::Path, seed: u64) -> SimConfig {
        SimConfig {
            platforms: 60,
            workers: 4,
            duration_s: 900,
            drift_platforms: 2,
            slow_platforms: 5,
            ..SimConfig::fleet(root, seed)
        }
    }
}

/// What a finished run reports — the bench serializes this as the
/// machine-readable `JSON:` tail and gates on it.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SimReport {
    /// Seed the run derived from.
    pub seed: u64,
    /// Population size actually simulated.
    pub platforms: usize,
    /// Worker count actually simulated.
    pub workers: usize,
    /// Sim-seconds simulated.
    pub duration_s: u64,
    /// Tasks the scan enqueued (initial backlog + drift + re-scans).
    pub tasks_enqueued: u64,
    /// Tasks requeued by lease expiry (crashes and slow executions).
    pub tasks_requeued: u64,
    /// Tasks dropped after exhausting their attempt budget.
    pub tasks_dropped: u64,
    /// Executions workers finished (including ones that turned out to
    /// be duplicates).
    pub executions: u64,
    /// Executions that settled their task.
    pub completions: u64,
    /// Executions wasted: the task was already settled by another
    /// worker when this one reported back.
    pub duplicates: u64,
    /// `duplicates / executions` (0 when nothing executed).
    pub duplicate_rate: f64,
    /// Sim-seconds from the first scan until every initially-stale
    /// identity had been refreshed; `None` if the run ended first.
    pub convergence_s: Option<u64>,
    /// Lookup + portfolio queries served.
    pub serves: u64,
    /// Serves answered from the asking platform's own data.
    pub exact_hits: u64,
    /// Serves answered by cross-platform transfer.
    pub transfers: u64,
    /// Serves with nothing to offer.
    pub misses: u64,
    /// Median age of served lookup entries, sim-seconds (histogram
    /// bucket upper bound: at most 25% above the true median).
    pub staleness_p50_s: u64,
    /// 95th-percentile age of served lookup entries (bucket bound).
    pub staleness_p95_s: u64,
    /// 99th-percentile age of served lookup entries (bucket bound).
    pub staleness_p99_s: u64,
    /// Entries appended to the audit log (verified before reporting).
    pub audit_entries: u64,
    /// Platforms the run slowed down mid-flight.
    pub slow_platforms: usize,
    /// Sentinel confirmations (one per key that crossed the bar).
    pub regressions_detected: u64,
    /// Confirmations on platforms that were never slowed — the bench
    /// gates this at exactly zero.
    pub regression_false_positives: u64,
    /// Mean sim-seconds from an injected slowdown to its platform's
    /// first confirmed detection (0 when nothing was detected).
    pub detection_latency_mean_s: f64,
    /// Worst detection latency across slowed platforms, sim-seconds.
    pub detection_latency_max_s: u64,
    /// Slowed platforms whose regression was never confirmed (their
    /// entries were re-tuned on the slow hardware before the sentinel
    /// accumulated enough evidence — stored best already honest).
    pub slowdowns_undetected: u64,
    /// Core-milliseconds of tuning spend accumulated in the on-disk
    /// ledgers (write-through verified against the mirror).
    pub ledger_spend_ms: u64,
    /// Core-milliseconds of realized benefit in the on-disk ledgers.
    pub ledger_benefit_ms: u64,
}

impl SimReport {
    /// JSON view — the bench's `JSON:` tail.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("seed", json::int(self.seed as i64)),
            ("platforms", json::int(self.platforms as i64)),
            ("workers", json::int(self.workers as i64)),
            ("duration_s", json::int(self.duration_s as i64)),
            ("tasks_enqueued", json::int(self.tasks_enqueued as i64)),
            ("tasks_requeued", json::int(self.tasks_requeued as i64)),
            ("tasks_dropped", json::int(self.tasks_dropped as i64)),
            ("executions", json::int(self.executions as i64)),
            ("completions", json::int(self.completions as i64)),
            ("duplicates", json::int(self.duplicates as i64)),
            ("duplicate_rate", json::num(self.duplicate_rate)),
            (
                "convergence_s",
                self.convergence_s.map(|s| json::int(s as i64)).unwrap_or(Json::Null),
            ),
            ("serves", json::int(self.serves as i64)),
            ("exact_hits", json::int(self.exact_hits as i64)),
            ("transfers", json::int(self.transfers as i64)),
            ("misses", json::int(self.misses as i64)),
            ("staleness_p50_s", json::int(self.staleness_p50_s as i64)),
            ("staleness_p95_s", json::int(self.staleness_p95_s as i64)),
            ("staleness_p99_s", json::int(self.staleness_p99_s as i64)),
            ("audit_entries", json::int(self.audit_entries as i64)),
            ("slow_platforms", json::int(self.slow_platforms as i64)),
            ("regressions_detected", json::int(self.regressions_detected as i64)),
            (
                "regression_false_positives",
                json::int(self.regression_false_positives as i64),
            ),
            ("detection_latency_mean_s", json::num(self.detection_latency_mean_s)),
            ("detection_latency_max_s", json::int(self.detection_latency_max_s as i64)),
            ("slowdowns_undetected", json::int(self.slowdowns_undetected as i64)),
            ("ledger_spend_ms", json::int(self.ledger_spend_ms as i64)),
            ("ledger_benefit_ms", json::int(self.ledger_benefit_ms as i64)),
        ])
    }
}

/// What one simulated worker is doing.
enum WorkerState {
    Idle,
    Busy { lease_id: u64, task: TuningTask, started: u64, done_at: u64 },
    Crashed { until: u64 },
}

/// Per-platform bookkeeping alongside the shard mirror: its current
/// (possibly drifted) fingerprint and what traffic can ask it for.
struct PlatMeta {
    fp: Fingerprint,
    pairs: Vec<(String, String)>,
}

/// The non-native (kernel, workload) menu platforms are seeded from.
const WORKLOADS: [(&str, &str); 3] =
    [("axpy", "n4096"), ("dot", "n1024"), ("stencil3", "r1024")];

/// Sim-seconds a simulated execution takes, by task kind.  Every 211th
/// execution is pathologically slow (outlives its lease), which is the
/// seeded source of duplicate work the bench gates at ≤ 1%.
fn exec_secs(kind: TaskKind, rng: &mut Rng, serial: u64, lease_ttl_s: u64) -> u64 {
    if serial % 211 == 210 {
        return lease_ttl_s + 15;
    }
    match kind {
        TaskKind::Retune => 5 + rng.gen_range(10) as u64,
        TaskKind::Sweep => 8 + rng.gen_range(12) as u64,
        TaskKind::PortfolioRebuild => 10 + rng.gen_range(15) as u64,
    }
}

/// A synthetic fingerprint for population index `i` — eight hardware
/// families with per-machine cache/core variation, so transfer ranking
/// has genuine neighborhoods to find.
fn synth_fp(i: usize, rng: &mut Rng) -> Fingerprint {
    const SIMD: [&[&str]; 8] = [
        &["sse2"],
        &["sse2", "avx"],
        &["sse2", "avx", "avx2"],
        &["avx2", "fma"],
        &["avx2", "avx512f"],
        &["neon"],
        &["neon", "sve"],
        &["avx2", "fma", "avx512f"],
    ];
    let family = i % SIMD.len();
    Fingerprint {
        cpu_model: format!("SimCPU f{family} m{i}"),
        num_cpus: [4usize, 8, 16, 32, 64][rng.gen_range(5)],
        simd: SIMD[family].iter().map(|s| s.to_string()).collect(),
        cache_l1d_kb: [32u64, 48, 64][rng.gen_range(3)],
        cache_l2_kb: [512u64, 1024, 2048][rng.gen_range(3)],
        cache_l3_kb: [4096u64, 8192, 16384, 32768][rng.gen_range(4)],
        os: if family >= 5 { "darwin".into() } else { "linux".into() },
    }
}

/// A synthetic tuning record.
fn synth_entry(
    platform_key: &str,
    kernel: &str,
    tag: &str,
    config_id: &str,
    recorded_at: u64,
    rng: &mut Rng,
) -> DbEntry {
    let best = 0.5e-3 + rng.next_f64() * 2e-3;
    DbEntry {
        platform_key: platform_key.into(),
        kernel: kernel.into(),
        tag: tag.into(),
        best_params: [("block_size".to_string(), [128i64, 256, 512][rng.gen_range(3)])]
            .into_iter()
            .collect(),
        best_config_id: config_id.into(),
        best_time_s: best,
        baseline_time_s: best * (1.5 + rng.next_f64()),
        reference_time_s: best * 0.9,
        evaluations: 8,
        strategy: "sim".into(),
        recorded_at,
    }
}

/// A minimal but well-formed gemm portfolio (feature contract intact,
/// so selection on it works like the real thing).
fn synth_portfolio(built_at: u64, rng: &mut Rng) -> Portfolio {
    let tile = [32i64, 64, 128][rng.gen_range(3)];
    Portfolio {
        kernel: "gemm".into(),
        strategy: "sim".into(),
        k_max: 2,
        retained: 0.9 + rng.next_f64() * 0.09,
        built_at,
        feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
        items: vec![PortfolioItem {
            config: [("tile_m".to_string(), tile)].into_iter().collect(),
            config_id: format!("sim_t{tile}"),
            centroid: vec![8.0, 8.0, 8.0, 1.0, 0.0],
            covered: vec!["m256_n256_k256".into()],
        }],
    }
}

/// Knuth Poisson sampler — deterministic given the shared [`Rng`].
fn poisson(lambda: f64, rng: &mut Rng) -> u64 {
    let l = (-lambda).exp();
    let mut k = 0u64;
    let mut p = 1.0;
    loop {
        p *= rng.next_f64();
        if p <= l {
            return k;
        }
        k += 1;
    }
}

/// Similarity as audit-friendly permille (no floats in the log).
fn sim_pm(similarity: f64) -> u64 {
    (similarity.clamp(0.0, 1.0) * 1000.0).round() as u64
}

/// The whole simulated world: real engines plus synthetic population.
struct Fleet<'a> {
    cfg: &'a SimConfig,
    db: ShardedDb,
    audit: AuditLog,
    plan: FaultPlan,
    rng: Rng,
    mirror: Vec<Shard>,
    meta: Vec<PlatMeta>,
    index: BTreeMap<String, usize>,
    initial: BTreeSet<TaskIdentity>,
    queue: TaskQueue,
    workers: Vec<WorkerState>,
    host: Fingerprint,
    drifts: BTreeMap<u64, Vec<usize>>,
    /// Slowdown schedule: sim-second → platform indexes that get slow.
    slow_events: BTreeMap<u64, Vec<usize>>,
    /// Platforms currently slow and when each slowdown began.
    slow_since: BTreeMap<usize, u64>,
    /// Slowed platforms whose regression has been confirmed (first
    /// confirmation per platform is the one that counts for latency).
    detected: BTreeSet<usize>,
    detection_latencies: Vec<u64>,
    /// The daemon's detector, run sim-locally on the telemetry stream
    /// (same thresholds, so detection ticks match what a live fleet
    /// would see).
    sentinel: Sentinel,
    report: SimReport,
    /// Served-entry ages, in the shared telemetry bucket scheme.  A
    /// local instance — recording into the process-global registry
    /// would be shared with concurrent tests and break the sim's
    /// bit-reproducibility contract.
    staleness: Histogram,
    executions_started: u64,
    alien_serial: usize,
    start: u64,
}

impl<'a> Fleet<'a> {
    /// Build the world: seed the population (every entry stale at t0),
    /// write it through to the real store, and schedule drift events.
    fn new(cfg: &'a SimConfig) -> Result<Fleet<'a>> {
        let mut rng = Rng::new(cfg.seed);
        std::fs::remove_dir_all(&cfg.db_dir).ok();
        std::fs::remove_file(&cfg.audit_path).ok();
        std::fs::remove_file(crate::service::audit::head_path(&cfg.audit_path)).ok();
        if let Some(parent) = cfg.audit_path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let db = ShardedDb::open(&cfg.db_dir)?;
        let audit = AuditLog::open(&cfg.audit_path)?;
        let plan =
            FaultPlan::from_spec(&format!("worker.crash:{}", cfg.crash_prob), cfg.seed)?;

        let mut mirror = Vec::with_capacity(cfg.platforms);
        let mut meta = Vec::with_capacity(cfg.platforms);
        let mut index = BTreeMap::new();
        let mut initial = BTreeSet::new();
        for i in 0..cfg.platforms {
            let fp = synth_fp(i, &mut rng);
            let key = fp.key();
            let mut pairs = vec![("axpy".to_string(), "n4096".to_string()), {
                let (k, t) = WORKLOADS[1 + rng.gen_range(2)];
                (k.to_string(), t.to_string())
            }];
            let has_gemm = i % 3 == 0;
            let has_portfolio = i % 10 == 0;
            if has_gemm {
                pairs.push(("gemm".to_string(), "m256_n256_k256".to_string()));
            }
            let entries: Vec<DbEntry> = pairs
                .iter()
                .map(|(k, t)| synth_entry(&key, k, t, "seed_cfg", 0, &mut rng))
                .collect();
            db.record_many(&key, Some(&fp), entries.clone())?;
            let mut shard = Shard {
                platform_key: key.clone(),
                fingerprint: Some(fp.clone()),
                entries,
                portfolios: Vec::new(),
                ledger: Ledger::default(),
            };
            for (k, t) in &pairs {
                if k == "gemm" {
                    if !has_portfolio {
                        initial.insert((TaskKind::Sweep, key.clone(), k.clone(), None));
                    }
                } else {
                    initial.insert((TaskKind::Retune, key.clone(), k.clone(), Some(t.clone())));
                }
            }
            if has_portfolio {
                let p = synth_portfolio(0, &mut rng);
                db.record_portfolio(&key, Some(&fp), p.clone())?;
                shard.portfolios.push(p);
                initial.insert((TaskKind::PortfolioRebuild, key.clone(), "gemm".into(), None));
            }
            index.insert(key, i);
            meta.push(PlatMeta { fp, pairs });
            mirror.push(shard);
        }
        let host = synth_fp(usize::MAX / 2, &mut rng);

        // Drift schedule: deterministic platforms at deterministic
        // times in the back half of the run (after most of the backlog
        // has drained).
        let start = cfg.ttl_s + 1;
        let mut drifts: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        for d in 0..cfg.drift_platforms.min(cfg.platforms) {
            let at = start + cfg.duration_s * (6 + (d as u64 % 3)) / 10 + d as u64;
            drifts.entry(at).or_default().push(rng.gen_range(cfg.platforms));
        }

        // Slowdown schedule: mid-run (after the cold backlog has
        // mostly refreshed, so most slowed entries were tuned on the
        // fast hardware), staggered, distinct platforms.
        let mut slow_events: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
        let mut slowed = BTreeSet::new();
        for s in 0..cfg.slow_platforms.min(cfg.platforms) {
            let at = start + cfg.duration_s / 2 + s as u64;
            let mut i = rng.gen_range(cfg.platforms);
            while !slowed.insert(i) {
                i = (i + 1) % cfg.platforms;
            }
            slow_events.entry(at).or_default().push(i);
        }

        let report = SimReport {
            seed: cfg.seed,
            platforms: cfg.platforms,
            workers: cfg.workers,
            duration_s: cfg.duration_s,
            slow_platforms: slowed.len(),
            ..SimReport::default()
        };
        Ok(Fleet {
            cfg,
            db,
            audit,
            plan,
            rng,
            mirror,
            meta,
            index,
            initial,
            queue: TaskQueue::new(cfg.ttl_s),
            workers: (0..cfg.workers).map(|_| WorkerState::Idle).collect(),
            host,
            drifts,
            slow_events,
            slow_since: BTreeMap::new(),
            detected: BTreeSet::new(),
            detection_latencies: Vec::new(),
            sentinel: Sentinel::new(SentinelConfig::default()),
            report,
            staleness: Histogram::new(),
            executions_started: 0,
            alien_serial: 0,
            start,
        })
    }

    fn audit(&self, now: u64, event: AuditEvent) -> Result<()> {
        self.audit.append_at(now, event).map(|_| ())
    }

    /// The machine under a stable key changes hardware.  The store
    /// keeps accepting records under the old key — exactly the
    /// inconsistency the scan's drift rule exists to catch.
    fn drift(&mut self, i: usize, now: u64) -> Result<()> {
        let mut fp = self.meta[i].fp.clone();
        fp.cache_l2_kb *= 2;
        fp.num_cpus *= 2;
        self.meta[i].fp = fp.clone();
        let key = self.mirror[i].platform_key.clone();
        let mut marker =
            synth_entry(&key, "axpy", "n4096", &format!("drift_t{now}"), now, &mut self.rng);
        // A drift marker measured on an already-slowed machine reports
        // the machine as it is.
        if self.slow_since.contains_key(&i) {
            let factor = self.cfg.slow_factor_pm as f64 / 1000.0;
            marker.best_time_s *= factor;
            marker.baseline_time_s *= factor;
            marker.reference_time_s *= factor;
        }
        self.db.record(Some(&fp), marker.clone())?;
        self.audit(
            now,
            AuditEvent::RecordAccepted {
                platform: key,
                kernel: marker.kernel.clone(),
                tag: marker.tag.clone(),
                config: marker.best_config_id.clone(),
            },
        )?;
        self.mirror[i].fingerprint = Some(fp);
        self.mirror[i].entries.push(marker);
        Ok(())
    }

    /// One finished execution reports back: settle the lease and, if
    /// this worker won, refresh the task's data (write-through to the
    /// mirror and the real store) and bill the execution's
    /// core-milliseconds into the platform's ledger.
    fn finish(&mut self, task: &TuningTask, lease_id: u64, started: u64, now: u64) -> Result<()> {
        self.report.executions += 1;
        match self.queue.complete(lease_id) {
            CompleteOutcome::Settled => {}
            CompleteOutcome::Duplicate | CompleteOutcome::Unknown => {
                self.report.duplicates += 1;
                return Ok(());
            }
        }
        self.report.completions += 1;
        self.initial.remove(&task.identity());
        self.audit(now, AuditEvent::TaskCompleted { lease_id })?;
        let idx = self.index[&task.platform_key];
        let fp = self.meta[idx].fp.clone();
        let mut fresh: Vec<DbEntry> = Vec::new();
        match task.kind {
            TaskKind::Retune => {
                let tag = task.tag.clone().unwrap_or_default();
                fresh.push(synth_entry(
                    &task.platform_key,
                    &task.kernel,
                    &tag,
                    &format!("cfg_t{now}"),
                    now,
                    &mut self.rng,
                ));
            }
            TaskKind::Sweep | TaskKind::PortfolioRebuild => {
                for (k, t) in self.meta[idx].pairs.clone() {
                    if k == task.kernel {
                        fresh.push(synth_entry(
                            &task.platform_key,
                            &k,
                            &t,
                            &format!("cfg_t{now}"),
                            now,
                            &mut self.rng,
                        ));
                    }
                }
            }
        }
        // A task executed on slowed hardware produces honestly slower
        // results — the retuned best reflects the machine as it is
        // now, which is exactly what stops the sentinel re-firing on
        // the refreshed entry.
        if let Some(&slow_at) = self.slow_since.get(&idx) {
            debug_assert!(now >= slow_at);
            let factor = self.cfg.slow_factor_pm as f64 / 1000.0;
            for e in &mut fresh {
                e.best_time_s *= factor;
                e.baseline_time_s *= factor;
                e.reference_time_s *= factor;
            }
        }
        if task.kind == TaskKind::PortfolioRebuild {
            let p = synth_portfolio(now, &mut self.rng);
            self.db.record_portfolio(&task.platform_key, Some(&fp), p.clone())?;
            let shard = &mut self.mirror[idx];
            shard.portfolios.retain(|q| q.kernel != p.kernel);
            shard.portfolios.push(p);
        }
        // Ledger: the whole execution is spend, split evenly across
        // the records it produced; each record's benefit is the same
        // gap × invocations the daemon books (see server::ledger_delta).
        let spend_total_ms = now.saturating_sub(started).max(1) * 1000;
        if fresh.is_empty() {
            let delta = LedgerDelta {
                kernel: task.kernel.clone(),
                spend_ms: spend_total_ms,
                benefit_ms: 0,
                invocations: 0,
                at: now,
            };
            self.db.apply_ledger(&task.platform_key, vec![delta.clone()])?;
            self.mirror[idx].ledger.apply(&delta);
        } else {
            let deltas: Vec<LedgerDelta> = fresh
                .iter()
                .map(|e| LedgerDelta {
                    kernel: e.kernel.clone(),
                    spend_ms: (spend_total_ms / fresh.len() as u64).max(1),
                    benefit_ms: ((e.baseline_time_s - e.best_time_s).max(0.0)
                        * e.evaluations as f64
                        * 1000.0)
                        .round() as u64,
                    invocations: e.evaluations,
                    at: now,
                })
                .collect();
            self.db.record_many_with_ledger(
                &task.platform_key,
                Some(&fp),
                fresh.clone(),
                deltas.clone(),
            )?;
            for d in &deltas {
                self.mirror[idx].ledger.apply(d);
            }
            for e in &fresh {
                // The old ratios were measured against a baseline this
                // record just replaced.
                self.sentinel.reset(&e.platform_key, &e.kernel, &e.tag);
                self.audit(
                    now,
                    AuditEvent::RecordAccepted {
                        platform: e.platform_key.clone(),
                        kernel: e.kernel.clone(),
                        tag: e.tag.clone(),
                        config: e.best_config_id.clone(),
                    },
                )?;
            }
            self.mirror[idx].entries.extend(fresh);
        }
        Ok(())
    }

    /// The fleet's cost telemetry: every platform reports one observed
    /// cost per tracked (kernel, workload) against the entry the store
    /// is serving it.  Healthy platforms observe ±5% noise; a slowed
    /// platform running a config tuned *before* its slowdown observes
    /// the injected factor — the signal the sentinel must confirm
    /// (and stationary noise must never let it).
    fn telemetry(&mut self, now: u64) -> Result<()> {
        for i in 0..self.cfg.platforms {
            let pairs = self.meta[i].pairs.clone();
            for (kernel, tag) in pairs {
                let Some((stored_s, recorded_at)) = self.mirror[i]
                    .latest(&kernel, &tag)
                    .map(|e| (e.best_time_s, e.recorded_at))
                else {
                    continue;
                };
                let noise = 0.95 + 0.1 * self.rng.next_f64();
                let slow_at = self.slow_since.get(&i).copied();
                // Entries tuned on the fast hardware are the ones that
                // genuinely regressed; a post-slowdown retune already
                // reflects the slow machine.
                let factor = match slow_at {
                    Some(at) if recorded_at < at => self.cfg.slow_factor_pm as f64 / 1000.0,
                    _ => 1.0,
                };
                let observed_s = stored_s * noise * factor;
                let key = self.mirror[i].platform_key.clone();
                let (_, event) =
                    self.sentinel.observe(&key, &kernel, &tag, observed_s, stored_s);
                let Some(SentinelEvent::Confirmed {
                    ratio_pm,
                    window_n,
                    window_mean_pm,
                    window_max_pm,
                }) = event
                else {
                    continue;
                };
                self.report.regressions_detected += 1;
                match slow_at {
                    Some(at) => {
                        if self.detected.insert(i) {
                            self.detection_latencies.push(now - at);
                        }
                    }
                    // Confirmed on a platform that was never slowed:
                    // the noise floor fired the detector.  The bench
                    // gates this at exactly zero.
                    None => self.report.regression_false_positives += 1,
                }
                self.audit(
                    now,
                    AuditEvent::Regression {
                        platform: key.clone(),
                        kernel: kernel.clone(),
                        workload: tag.clone(),
                        ratio_pm,
                        window_n,
                        window_mean_pm,
                        window_max_pm,
                    },
                )?;
                let task = TuningTask {
                    kind: TaskKind::Retune,
                    platform_key: key,
                    kernel,
                    tag: Some(tag),
                    reason: StaleReason::Regression { ratio_pm },
                    attempts: 0,
                };
                let (kind_s, platform_s, kernel_s, tag_s, reason_s) = (
                    task.kind.as_str().to_string(),
                    task.platform_key.clone(),
                    task.kernel.clone(),
                    task.tag.clone(),
                    task.reason.as_str().to_string(),
                );
                if self.queue.enqueue_at(task, now) {
                    self.report.tasks_enqueued += 1;
                    self.audit(
                        now,
                        AuditEvent::TaskEnqueued {
                            kind: kind_s,
                            platform: platform_s,
                            kernel: kernel_s,
                            tag: tag_s,
                            reason: reason_s,
                        },
                    )?;
                }
            }
        }
        Ok(())
    }

    /// Serve one query against the mirror, the way the daemon would:
    /// exact data when the platform has it, transfer ranking when it
    /// does not, an honest miss otherwise.
    fn serve_one(&mut self, now: u64) -> Result<()> {
        self.report.serves += 1;
        let wants_portfolio = self.rng.next_f64() < 0.1;
        let alien = self.rng.next_f64() < 0.1;
        let (platform, kernel, workload, reason, age) = if alien {
            // A platform the store has never seen: transfer is the
            // only possible answer.
            self.alien_serial += 1;
            let fp = synth_fp(usize::MAX - self.alien_serial, &mut self.rng);
            if wants_portfolio {
                match transfer::rank_portfolios(&self.mirror, &fp, "gemm", &fp.key()).first() {
                    Some(c) => (
                        fp.key(),
                        "gemm".to_string(),
                        None,
                        ServeReason::Transfer {
                            source: c.platform_key.clone(),
                            similarity_pm: sim_pm(c.similarity),
                        },
                        None,
                    ),
                    None => (fp.key(), "gemm".to_string(), None, ServeReason::Miss, None),
                }
            } else {
                let (k, t) = WORKLOADS[self.rng.gen_range(WORKLOADS.len())];
                match transfer::rank_candidates(&self.mirror, &fp, k, t, &fp.key()).first() {
                    Some(c) => (
                        fp.key(),
                        k.to_string(),
                        Some(t.to_string()),
                        ServeReason::Transfer {
                            source: c.platform_key.clone(),
                            similarity_pm: sim_pm(c.similarity),
                        },
                        Some(now.saturating_sub(c.entry.recorded_at)),
                    ),
                    None => (fp.key(), k.to_string(), Some(t.to_string()), ServeReason::Miss, None),
                }
            }
        } else {
            let i = self.rng.gen_range(self.cfg.platforms);
            let shard = &self.mirror[i];
            if wants_portfolio {
                match shard.portfolio("gemm") {
                    Some(_) => (
                        shard.platform_key.clone(),
                        "gemm".to_string(),
                        None,
                        ServeReason::Exact,
                        None,
                    ),
                    None => (
                        shard.platform_key.clone(),
                        "gemm".to_string(),
                        None,
                        ServeReason::Miss,
                        None,
                    ),
                }
            } else {
                let (k, t) = self.meta[i].pairs[self.rng.gen_range(self.meta[i].pairs.len())].clone();
                match shard.latest(&k, &t) {
                    Some(e) => (
                        shard.platform_key.clone(),
                        k,
                        Some(t),
                        ServeReason::Exact,
                        Some(now.saturating_sub(e.recorded_at)),
                    ),
                    None => (shard.platform_key.clone(), k, Some(t), ServeReason::Miss, None),
                }
            }
        };
        match &reason {
            ServeReason::Exact => self.report.exact_hits += 1,
            ServeReason::Miss => self.report.misses += 1,
            _ => self.report.transfers += 1,
        }
        if let Some(age) = age {
            self.staleness.record(age);
        }
        let op = if wants_portfolio { "portfolio" } else { "lookup" };
        self.audit(
            now,
            AuditEvent::Served {
                op: op.into(),
                platform,
                kernel,
                workload,
                reason,
                trace_id: None,
            },
        )
    }

    /// One sim-second: drift, scan, expiry, workers, traffic,
    /// convergence check — in that fixed order.
    fn tick(&mut self, now: u64) -> Result<()> {
        if let Some(idxs) = self.drifts.get(&now).cloned() {
            for i in idxs {
                self.drift(i, now)?;
            }
        }
        if let Some(idxs) = self.slow_events.get(&now).cloned() {
            for i in idxs {
                // The hardware is slower from this tick on; the store
                // still holds bests measured on the fast machine.
                self.slow_since.insert(i, now);
            }
        }

        if (now - self.start) % self.cfg.scan_every_s == 0 {
            let host = self.host.clone();
            for task in self.queue.scan_report(&self.mirror, &host, now) {
                self.report.tasks_enqueued += 1;
                self.audit.append_at(
                    now,
                    AuditEvent::TaskEnqueued {
                        kind: task.kind.as_str().to_string(),
                        platform: task.platform_key.clone(),
                        kernel: task.kernel.clone(),
                        tag: task.tag.clone(),
                        reason: task.reason.as_str().to_string(),
                    },
                )?;
            }
        }

        let expired = self.queue.expire_report(now);
        for t in &expired.requeued {
            self.report.tasks_requeued += 1;
            self.audit(
                now,
                AuditEvent::TaskRequeued {
                    kind: t.kind.as_str().to_string(),
                    platform: t.platform_key.clone(),
                    kernel: t.kernel.clone(),
                    attempts: t.attempts as u64,
                },
            )?;
        }
        for t in &expired.dropped {
            self.report.tasks_dropped += 1;
            self.audit(
                now,
                AuditEvent::TaskDropped {
                    kind: t.kind.as_str().to_string(),
                    platform: t.platform_key.clone(),
                    kernel: t.kernel.clone(),
                    attempts: t.attempts as u64,
                },
            )?;
        }

        for w in 0..self.cfg.workers {
            let state = std::mem::replace(&mut self.workers[w], WorkerState::Idle);
            self.workers[w] = match state {
                WorkerState::Busy { lease_id, task, started, done_at } if now >= done_at => {
                    self.finish(&task, lease_id, started, now)?;
                    WorkerState::Idle
                }
                WorkerState::Crashed { until } if now >= until => WorkerState::Idle,
                other => other,
            };
            if matches!(self.workers[w], WorkerState::Idle) {
                if let Some((lease_id, task)) =
                    self.queue.lease(None, None, self.cfg.lease_ttl_s, now)
                {
                    self.audit(
                        now,
                        AuditEvent::TaskLeased {
                            lease_id,
                            kind: task.kind.as_str().to_string(),
                            platform: task.platform_key.clone(),
                            kernel: task.kernel.clone(),
                        },
                    )?;
                    let secs = exec_secs(
                        task.kind,
                        &mut self.rng,
                        self.executions_started,
                        self.cfg.lease_ttl_s,
                    );
                    self.executions_started += 1;
                    self.workers[w] = if self.plan.decide(InjectionPoint::WorkerCrash) {
                        // Crash before settling: the lease is orphaned
                        // and only its TTL recovers the task.
                        WorkerState::Crashed { until: now + 45 }
                    } else {
                        WorkerState::Busy { lease_id, task, started: now, done_at: now + secs }
                    };
                }
            }
        }

        for _ in 0..poisson(self.cfg.traffic_per_s, &mut self.rng) {
            self.serve_one(now)?;
        }

        if (now - self.start) % self.cfg.telemetry_every_s.max(1) == 0 {
            self.telemetry(now)?;
        }

        // Convergence: the cold backlog is fully refreshed.  The queue
        // may well hold *new* work by now (re-aged data, drift) — that
        // is steady-state churn, not backlog.
        if self.report.convergence_s.is_none() && self.initial.is_empty() {
            self.report.convergence_s = Some(now - self.start);
        }
        Ok(())
    }
}

/// Run one simulation to completion and return its report.  Fails if
/// the audit log does not verify or the shard store on disk disagrees
/// with the in-memory mirror (a write-through was lost).
pub fn run(cfg: &SimConfig) -> Result<SimReport> {
    let mut fleet = Fleet::new(cfg)?;
    let (start, end) = (fleet.start, fleet.start + cfg.duration_s);
    for now in start..end {
        fleet.tick(now)?;
    }

    let Fleet { db, audit, mirror, mut report, staleness, slow_since, detected, detection_latencies, .. } =
        fleet;
    if report.executions > 0 {
        report.duplicate_rate = report.duplicates as f64 / report.executions as f64;
    }
    report.staleness_p50_s = staleness.quantile(0.50);
    report.staleness_p95_s = staleness.quantile(0.95);
    report.staleness_p99_s = staleness.quantile(0.99);
    report.audit_entries = audit.appended();
    report.slowdowns_undetected =
        slow_since.keys().filter(|i| !detected.contains(i)).count() as u64;
    if !detection_latencies.is_empty() {
        report.detection_latency_mean_s = detection_latencies.iter().sum::<u64>() as f64
            / detection_latencies.len() as f64;
        report.detection_latency_max_s = detection_latencies.iter().copied().max().unwrap_or(0);
    }

    // The run's own evidence must hold up before we report anything.
    let verified = verify_log(&cfg.audit_path)
        .map_err(|e| anyhow::anyhow!("simulation audit log failed verification: {e}"))?;
    anyhow::ensure!(
        verified.entries == report.audit_entries,
        "audit log lost entries: wrote {}, verified {}",
        report.audit_entries,
        verified.entries
    );
    let on_disk = db.all_shards().context("re-reading the store the sim wrote")?;
    let disk_entries: usize = on_disk.iter().map(|s| s.entries.len()).sum();
    let mirror_entries: usize = mirror.iter().map(|s| s.entries.len()).sum();
    anyhow::ensure!(
        on_disk.len() == mirror.len() && disk_entries == mirror_entries,
        "write-through mismatch: disk has {} shards / {} entries, mirror {} / {}",
        on_disk.len(),
        disk_entries,
        mirror.len(),
        mirror_entries
    );
    // The ledger must have survived write-through exactly: the disk
    // total is the sum of per-shard exact sums, so any lost delta
    // shows up here as a shortfall against the mirror.
    let (disk_spend, disk_benefit) = on_disk
        .iter()
        .map(|s| s.ledger.totals())
        .fold((0u64, 0u64), |(a, b), (s, g)| (a + s, b + g));
    let (mirror_spend, mirror_benefit) = mirror
        .iter()
        .map(|s| s.ledger.totals())
        .fold((0u64, 0u64), |(a, b), (s, g)| (a + s, b + g));
    anyhow::ensure!(
        (disk_spend, disk_benefit) == (mirror_spend, mirror_benefit),
        "ledger write-through mismatch: disk {disk_spend}/{disk_benefit} ms, \
         mirror {mirror_spend}/{mirror_benefit} ms"
    );
    report.ledger_spend_ms = disk_spend;
    report.ledger_benefit_ms = disk_benefit;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("portatune-sim-{name}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn smoke_sim_converges_with_bounded_duplicates() {
        let root = tmp("smoke");
        let report = run(&SimConfig::smoke(&root, 7)).unwrap();
        assert!(report.convergence_s.is_some(), "backlog never drained: {report:?}");
        assert!(report.tasks_enqueued >= report.platforms as u64, "{report:?}");
        assert!(report.duplicate_rate <= 0.01, "duplicate work too high: {report:?}");
        assert!(report.serves > 0 && report.exact_hits > 0, "{report:?}");
        assert!(report.audit_entries > 0);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn same_seed_is_bit_identical_different_seed_is_not() {
        let (ra, rb, rc) = (tmp("det-a"), tmp("det-b"), tmp("det-c"));
        let mut cfg_a = SimConfig::smoke(&ra, 42);
        cfg_a.platforms = 30;
        cfg_a.duration_s = 600;
        let mut cfg_b = cfg_a.clone();
        cfg_b.db_dir = rb.join("shards");
        cfg_b.audit_path = rb.join("audit.log");
        let a = run(&cfg_a).unwrap();
        let b = run(&cfg_b).unwrap();
        assert_eq!(a, b, "same seed must reproduce the same report");
        assert_eq!(
            std::fs::read(&cfg_a.audit_path).unwrap(),
            std::fs::read(&cfg_b.audit_path).unwrap(),
            "same seed must reproduce the same audit log bytes"
        );
        let mut cfg_c = cfg_a.clone();
        cfg_c.db_dir = rc.join("shards");
        cfg_c.audit_path = rc.join("audit.log");
        cfg_c.seed = 43;
        let c = run(&cfg_c).unwrap();
        assert_ne!(a, c, "a different seed must be a different run");
        // Not just the (seed-carrying) report: the decision sequence
        // itself must actually diverge.
        assert_ne!(
            std::fs::read(&cfg_a.audit_path).unwrap(),
            std::fs::read(&cfg_c.audit_path).unwrap(),
            "a different seed must produce a different decision log"
        );
        for d in [ra, rb, rc] {
            std::fs::remove_dir_all(&d).ok();
        }
    }

    #[test]
    fn seeded_slowdown_is_detected_with_zero_false_positives() {
        let root = tmp("slow");
        let mut cfg = SimConfig::smoke(&root, 23);
        cfg.slow_platforms = 8;
        let report = run(&cfg).unwrap();
        assert_eq!(report.slow_platforms, 8, "{report:?}");
        assert!(report.regressions_detected >= 1, "no slowdown detected: {report:?}");
        assert_eq!(
            report.regression_false_positives, 0,
            "stationary noise fired the sentinel: {report:?}"
        );
        // Telemetry every 30s, 5-sample confirmation: detection lands
        // within a handful of ticks of the injection.
        assert!(
            (1..=300).contains(&report.detection_latency_max_s),
            "detection latency out of range: {report:?}"
        );
        assert!(report.detection_latency_mean_s >= 1.0, "{report:?}");
        // The executions that refreshed the fleet billed real spend
        // and booked real benefit into the on-disk ledgers.
        assert!(report.ledger_spend_ms > 0 && report.ledger_benefit_ms > 0, "{report:?}");
        // The evidence trail: a verifiable Regression event and an
        // evidence-reason retune for each confirmation.
        let entries = crate::service::audit::read_verified(&cfg.audit_path).unwrap();
        let regressions = entries
            .iter()
            .filter(|e| matches!(&e.event, AuditEvent::Regression { .. }))
            .count() as u64;
        assert_eq!(regressions, report.regressions_detected, "{report:?}");
        let evidence_retunes = entries
            .iter()
            .filter(|e| {
                matches!(&e.event, AuditEvent::TaskEnqueued { reason, .. }
                    if reason == "regression")
            })
            .count();
        assert!(evidence_retunes >= 1, "no regression-reason retune queued: {report:?}");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn drift_requeues_work_after_convergence() {
        let root = tmp("drift");
        let mut cfg = SimConfig::smoke(&root, 11);
        cfg.drift_platforms = 4;
        let report = run(&cfg).unwrap();
        assert!(report.completions > 0 && report.convergence_s.is_some(), "{report:?}");
        // Drift fires in the back half of the run; the scan must have
        // caught it and queued work *because of* it, and the audit log
        // must say so.
        let entries = crate::service::audit::read_verified(&cfg.audit_path).unwrap();
        let drift_enqueues = entries
            .iter()
            .filter(|e| {
                matches!(&e.event, AuditEvent::TaskEnqueued { reason, .. }
                    if reason == "fingerprint-drift")
            })
            .count();
        assert!(drift_enqueues >= 1, "no drift-reason task in the audit log: {report:?}");
        std::fs::remove_dir_all(&root).ok();
    }
}
