//! portatune CLI — the leader process of the autotuning system.
//!
//! Subcommands mirror the paper's workflow:
//!
//! ```text
//! portatune platform                      print the fingerprint keying the perf DB
//! portatune inspect                       summarize the artifact manifest
//! portatune tune --kernel K --workload T  empirical search over pre-lowered variants
//! portatune tune-all [--kernels a,b]      tune every workload of the listed kernels
//! portatune report-fig1 [--kernels ...]   regenerate the paper's Figure 1
//! portatune db-list                       show recorded tuning results
//! portatune deploy --kernel K --workload T  artifact the current platform should run
//! portatune annotate FILE                 parse /*@ tune ... @*/ blocks
//! portatune tune-annotated FILE           run every tune block in FILE
//! portatune tune --kernel gemm --sweep    native GEMM sweep (no artifacts)
//! portatune portfolio build|show          "few fit most" variant portfolios
//! portatune serve                         tuning-as-a-service daemon (shard store)
//! portatune query --op deploy ...         ask a running daemon (or --bundle FILE)
//! portatune bundle export|import|info     offline decision bundles
//! portatune metrics                       fetch a daemon's telemetry registry
//! portatune report                        core-hour ledger: tuning ROI per kernel
//! portatune work                          fleet worker: lease → execute → report
//! portatune db-migrate                    import a v1 perfdb.json into shards
//! portatune audit verify|replay           check / re-derive the decision log
//! ```
//!
//! Global flags: `--artifacts DIR` (default `artifacts`), `--db PATH`
//! (default `perfdb.json`), `--shards DIR` (default `perfdb.d`).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use std::sync::Arc;

use portatune::coordinator::annotation::{extract_blocks, Annotation};
use portatune::coordinator::measure::MeasureConfig;
use portatune::coordinator::perfdb::{PerfDb, ShardedDb};
use portatune::coordinator::platform::Fingerprint;
use portatune::coordinator::portfolio::{self, GemmSweep};
use portatune::coordinator::search::{
    Anneal, Exhaustive, Genetic, HillClimb, NelderMead, RandomSearch, SearchStrategy,
};
use portatune::coordinator::tuner::Tuner;
use portatune::obs;
use portatune::report::{Fig1Report, Fig1Row, Table};
use portatune::runtime::{Registry, Runtime};
use portatune::service::audit::{read_verified, verify_log, AuditLog};
use portatune::service::{
    faults, parse_bundle, transfer, write_bundle, BundleMeta, Client, OfflineBundle, Request,
    ServeOpts, Server, DEFAULT_LEASE_TTL_S,
};
use portatune::util::cli::Args;
use portatune::util::json::Json;
use portatune::worker::{Worker, WorkerOpts};
use portatune::workload::gemm;

const USAGE: &str = "usage: portatune <subcommand> [flags]
  global flags (every subcommand):
    --artifacts DIR   artifact root with manifest.json     (default: artifacts)
    --db PATH         legacy v1 perf-DB file               (default: perfdb.json)
    --shards DIR      v2 sharded perf-DB directory         (default: perfdb.d)

  platform          print the fingerprint that keys the perf DB
                      e.g. portatune platform
  inspect           summarize the artifact manifest
                      e.g. portatune inspect --artifacts artifacts
  tune              empirical search over one (kernel, workload)
                      e.g. portatune tune --kernel axpy --workload n65536 --batch 4
                    flags: --kernel K --workload T
                      [--strategy exhaustive|random|hillclimb|anneal|genetic|neldermead]
                      [--budget N] [--seed N] [--quick] [--no-record]
                      [--batch N]      overlap compilation + race measurements
                      [--warm-start]   seed from the shard store's transfer
                                       ranking (falls back to the legacy --db)
                      [--sweep]        native families only (gemm): tune every
                                       shape of the built-in sweep and record
                                       each winner to --shards (no --workload)
                      e.g. portatune tune --kernel gemm --sweep --quick
  tune-all          tune every workload of the listed kernels
                      e.g. portatune tune-all --kernels axpy,dot --strategy genetic --budget 16
  portfolio         build/show \"few fit most\" variant portfolios
                      build: sweep the native GEMM space, cluster per-shape
                             winners into K configs, persist to --shards
                        e.g. portatune portfolio build --kernel gemm --k 4 --target 0.9
                        flags: [--kernel gemm] [--k N (default 4)]
                               [--target F (default 0.9)] [--quick] [--seed N]
                      show:  print the stored portfolio for a platform
                        e.g. portatune portfolio show --kernel gemm
                        flags: [--kernel gemm] [--platform KEY (default: this host)]
  report-fig1       regenerate the paper's Figure 1
                      e.g. portatune report-fig1 --kernels axpy,dot,triad --csv fig1.csv
  db-list           show recorded tuning results from the legacy --db file
                      e.g. portatune db-list --db perfdb.json
  deploy            print the artifact the current platform should run
                      e.g. portatune deploy --kernel axpy --workload n4096
  annotate          parse /*@ tune ... @*/ blocks from a source file
                      e.g. portatune annotate examples/annotated.c
  tune-annotated    execute every /*@ tune @*/ block in a file
                      e.g. portatune tune-annotated examples/annotated.c --quick
  serve             tuning-as-a-service daemon over the shard store
                      e.g. portatune serve --listen 127.0.0.1:7171 --shards perfdb.d
                    flags: [--listen ADDR (default 127.0.0.1:7171)]
                      [--socket PATH (unix domain socket instead of TCP)]
                      [--ttl-days N (default 30)]
                      [--workers N (default 0 = auto from CPU count)]
                        size of the connection worker pool
                      [--bundle FILE]  import an offline decision bundle
                        into the shard store before serving
                      [--scan-secs N (default 60)] [--retune [--batch N]]
                      [--lease-ttl SECS (default 600)]  worker-lease TTL
                      [--max-conns N (default 256)]   shed connections past N
                      [--conn-idle SECS (default 300)] close idle connections
                      [--faults SPEC] [--fault-seed N]  deterministic fault
                        injection, e.g. --faults server.reply-drop:0.2:3
                        (also via PORTATUNE_FAULTS / PORTATUNE_FAULT_SEED)
                      [--audit PATH]  append every consequential decision
                        (lease/complete/fail/requeue, record, serve reason)
                        to a hash-chained tamper-evident log at PATH
                      [--metrics-addr ADDR]  serve a Prometheus text page
                        over HTTP at ADDR (e.g. 127.0.0.1:9090)
                      [--trace PATH]  append Chrome-trace/Perfetto spans
                        (connection, request, per-op) to PATH
                      [--slow-ms N]  log requests slower than N ms as
                        structured JSON lines on stderr (0 = off)
                      imports --db into the shard store at startup when present
  query             ask a running daemon (one JSON reply line on stdout)
                      e.g. portatune query --op lookup --kernel axpy --workload n4096
                      e.g. portatune query --op portfolio --kernel gemm --m 128 --n 128 --k 64
                    flags: --op ping|lookup|deploy|stats|metrics|report|retune-next|portfolio|shutdown
                      [--addr ADDR (default 127.0.0.1:7171) | --socket PATH]
                      [--bundle FILE]  answer from an offline decision
                        bundle instead of a daemon (zero round-trips;
                        read ops only)
                      [--kernel K] [--workload T] [--platform KEY]
                      [--m N --n N --k N]  portfolio-op dims for selection
  metrics           fetch a daemon's telemetry registry (counters +
                    latency histograms; shorthand for query --op metrics)
                      e.g. portatune metrics --addr 127.0.0.1:7171
                    flags: [--addr ADDR (default 127.0.0.1:7171) | --socket PATH]
  report            core-hour ledger: what tuning cost, what it earned
                    back, and which entries are regressing right now
                    (table on stdout + one machine-readable JSON: line)
                      e.g. portatune report --addr 127.0.0.1:7171
                    flags: [--addr ADDR (default 127.0.0.1:7171) | --socket PATH]
                      [--bundle FILE]  answer from an offline decision
                        bundle instead of a daemon
                      [--platform KEY]  only that platform's ledger
                      [--json]  print only the JSON: line (for scripts)
  work              fleet worker: lease tasks from a daemon, execute them
                    (retune via artifacts, sweep / portfolio-rebuild
                    host-side), report results back
                      e.g. portatune work --addr 127.0.0.1:7171 --once --quick
                    flags: [--addr ADDR (default 127.0.0.1:7171) | --socket PATH]
                      [--once]          execute exactly one task, then exit
                                        (non-zero if none arrives or it fails)
                      [--quick]         smoke-sized sweeps and measurements
                      [--any-platform]  lease foreign platforms' tasks too
                      [--lease-ttl SECS (default 600)] [--heartbeat SECS]
                      [--poll SECS (default 2)] [--wait-secs N (default 15)]
                      [--seed N] [--batch N] [--k N] [--target F]
                      [--faults SPEC] [--fault-seed N]  deterministic fault
                        injection (same spec grammar as serve)
                      [--audit PATH]  keep a worker-side hash-chained log of
                        leased/completed/failed tasks at PATH
                      [--trace PATH]  append Chrome-trace/Perfetto spans
                        (lease/execute/report + wire calls) to PATH; each
                        task cycle carries one trace id the daemon echoes
  audit             inspect a hash-chained audit log written via --audit
                      verify: walk the chain; exit 0 if intact, non-zero
                              with the first bad entry index on tampering
                              or truncation
                        e.g. portatune audit verify audit.log
                      replay: re-print the decision sequence in order
                        e.g. portatune audit replay audit.log --platform KEY
                        flags: [--platform KEY]  only that platform's entries
  bundle            offline decision bundles (versioned, checksummed)
                      export: pack --shards (+ this host's fingerprint)
                              into one artifact
                        e.g. portatune bundle export perf.bundle
                        flags: [--platform KEY (default: this host)]
                               default platform for offline queries
                      import: verify FILE and merge its shards into --shards
                        e.g. portatune bundle import perf.bundle
                      info:   verify FILE and describe its contents
                        e.g. portatune bundle info perf.bundle
  db-migrate        import a v1 --db file into --shards (v2 shard files)
                      e.g. portatune db-migrate --db perfdb.json --shards perfdb.d

  The wire protocol the daemon speaks is specified in docs/PROTOCOL.md;
  docs/ARCHITECTURE.md maps the modules behind these subcommands.";

/// Instantiate a search strategy by its CLI name.
pub fn make_strategy(name: &str, seed: u64) -> Result<Box<dyn SearchStrategy>> {
    Ok(match name {
        "exhaustive" => Box::new(Exhaustive::new()),
        "random" => Box::new(RandomSearch::new(seed)),
        "hillclimb" => Box::new(HillClimb::new(seed)),
        "anneal" => Box::new(Anneal::new(seed)),
        "genetic" => Box::new(Genetic::new(seed)),
        "neldermead" => Box::new(NelderMead::new(seed)),
        other => {
            return Err(anyhow::anyhow!(
                "unknown strategy {other}; expected exhaustive|random|hillclimb|anneal|genetic|neldermead"
            ))
        }
    })
}

fn open_registry(artifacts: &Path) -> Result<Registry> {
    let runtime = Runtime::cpu()?;
    Registry::open(runtime, artifacts)
}

/// Install the deterministic fault plan requested via `--faults SPEC`
/// (with optional `--fault-seed N`), falling back to the
/// `PORTATUNE_FAULTS` / `PORTATUNE_FAULT_SEED` environment variables.
/// No flags and no env means no plan: the hooks stay zero-cost.
fn install_faults(args: &Args) -> Result<()> {
    let seed = args.get_parsed::<u64>("fault-seed", faults::DEFAULT_SEED)?;
    if let Some(spec) = args.get("faults") {
        let plan = faults::install(faults::FaultPlan::from_spec(spec, seed)?);
        eprintln!("fault injection: ON (spec {spec:?}, seed {:#x})", plan.seed());
    } else if let Some(plan) = faults::install_from_env()? {
        eprintln!("fault injection: ON (from env, seed {:#x})", plan.seed());
    }
    Ok(())
}

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        eprintln!("{USAGE}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let db_path = PathBuf::from(args.get_or("db", "perfdb.json"));
    let shards_dir = PathBuf::from(args.get_or("shards", "perfdb.d"));
    match args.subcommand() {
        Some("platform") => {
            args.finish()?;
            println!("{}", Fingerprint::detect().describe());
            Ok(())
        }
        Some("inspect") => {
            args.finish()?;
            cmd_inspect(&artifacts)
        }
        Some("tune") => cmd_tune(args, &artifacts, &db_path, &shards_dir),
        Some("tune-all") => cmd_tune_all(args, &artifacts, &db_path),
        Some("portfolio") => cmd_portfolio(args, &shards_dir),
        Some("report-fig1") => cmd_report_fig1(args, &artifacts),
        Some("db-list") => {
            args.finish()?;
            cmd_db_list(&db_path)
        }
        Some("deploy") => cmd_deploy(args, &artifacts, &db_path),
        Some("annotate") => cmd_annotate(args),
        Some("tune-annotated") => cmd_tune_annotated(args, &artifacts, &db_path),
        Some("serve") => cmd_serve(args, &artifacts, &db_path, &shards_dir),
        Some("query") => cmd_query(args),
        Some("metrics") => cmd_metrics(args),
        Some("report") => cmd_report(args),
        Some("work") => cmd_work(args, &artifacts),
        Some("audit") => cmd_audit(args),
        Some("bundle") => cmd_bundle(args, &shards_dir),
        Some("db-migrate") => cmd_db_migrate(args, &db_path, &shards_dir),
        _ => Err(anyhow::anyhow!("missing or unknown subcommand")),
    }
}

/// Run the tuning-as-a-service daemon against the shard store.
fn cmd_serve(args: &Args, artifacts: &Path, db_path: &Path, shards_dir: &Path) -> Result<()> {
    let listen = args.get_or("listen", "127.0.0.1:7171");
    let socket = args.get("socket").map(PathBuf::from);
    let ttl_days = args.get_parsed::<u64>("ttl-days", 30)?;
    let workers = args.get_parsed::<usize>("workers", 0)?;
    let bundle_path = args.get("bundle").map(PathBuf::from);
    let scan_secs = args.get_parsed::<u64>("scan-secs", 60)?;
    let retune = args.get_bool("retune");
    let batch = args.get_parsed::<usize>("batch", 4)?;
    let lease_ttl_s = args.get_parsed::<u64>("lease-ttl", DEFAULT_LEASE_TTL_S)?;
    let defaults = ServeOpts::default();
    let max_conns = args.get_parsed::<usize>("max-conns", defaults.max_conns)?;
    let conn_idle_s = args.get_parsed::<u64>("conn-idle", defaults.conn_idle_s)?;
    let audit_path = args.get("audit").map(PathBuf::from);
    let metrics_addr = args.get("metrics-addr").map(str::to_string);
    let trace_path = args.get("trace").map(PathBuf::from);
    let slow_ms = args.get_parsed::<u64>("slow-ms", 0)?;
    install_faults(args)?;
    args.finish()?;

    if let Some(path) = &trace_path {
        obs::trace::install(path)?;
        println!("trace spans: {}", path.display());
    }
    if slow_ms > 0 {
        obs::set_slow_op_ms(slow_ms);
        println!("slow-op log: requests over {slow_ms}ms");
    }

    let db = ShardedDb::open(shards_dir)?;
    if db_path.exists() {
        let imported = db.import_legacy(db_path)?;
        println!("imported {imported} entr(ies) from {}", db_path.display());
    }
    if let Some(path) = &bundle_path {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bundle {}", path.display()))?;
        let (meta, shard_texts) =
            parse_bundle(&text).with_context(|| format!("verifying bundle {}", path.display()))?;
        let mut entries = 0usize;
        for shard_text in &shard_texts {
            entries += db.import_shard_text(shard_text)?.1;
        }
        println!(
            "imported bundle {} (platform {}, gen {}, {} shard(s), {entries} entr(ies))",
            path.display(),
            meta.platform,
            meta.generation,
            shard_texts.len()
        );
    }
    let host = Fingerprint::detect();
    println!("platform: {}", host.key());
    let opts = ServeOpts {
        ttl_s: ttl_days * 24 * 3600,
        lease_ttl_s,
        max_conns,
        conn_idle_s,
        workers,
    };
    let server = Arc::new(Server::new(db, host, opts));
    if let Some(path) = audit_path {
        let log = AuditLog::open(&path)
            .with_context(|| format!("opening audit log {}", path.display()))?;
        println!("audit log: {}", path.display());
        server.enable_audit(Arc::new(log));
    }
    if let Some(addr) = metrics_addr {
        let listener = std::net::TcpListener::bind(&addr)
            .with_context(|| format!("binding metrics address {addr}"))?;
        println!("metrics: http://{addr}/metrics");
        let srv = Arc::clone(&server);
        std::thread::spawn(move || {
            if let Err(e) = srv.run_metrics_http(listener) {
                eprintln!("[serve] metrics responder died: {e:#}");
            }
        });
    }
    let _scan =
        Arc::clone(&server).spawn_scan(std::time::Duration::from_secs(scan_secs.max(1)));
    if retune {
        // The re-tune worker builds its registry inside its own thread
        // (backend types are not Send); without real artifacts +
        // runtime it logs and exits — the daemon still serves, it just
        // cannot re-measure.
        let artifacts_dir = artifacts.to_path_buf();
        let _worker = Arc::clone(&server)
            .spawn_retune_worker(move || open_registry(&artifacts_dir), batch);
        println!("re-tune worker: on (batch {batch})");
    }

    match socket {
        #[cfg(unix)]
        Some(path) => {
            let _ = std::fs::remove_file(&path);
            let listener = std::os::unix::net::UnixListener::bind(&path)
                .with_context(|| format!("binding unix socket {}", path.display()))?;
            println!("serving on unix:{} (shards: {})", path.display(), shards_dir.display());
            let result = server.run_unix(listener);
            let _ = std::fs::remove_file(&path);
            result
        }
        #[cfg(not(unix))]
        Some(_) => Err(anyhow::anyhow!("--socket requires a unix platform; use --listen")),
        None => {
            let listener = std::net::TcpListener::bind(&listen)
                .with_context(|| format!("binding {listen}"))?;
            println!("serving on {listen} (shards: {})", shards_dir.display());
            server.run_tcp(listener)
        }
    }
}

/// Ask a running daemon; prints the JSON reply on stdout.
fn cmd_query(args: &Args) -> Result<()> {
    let op = args.get_or("op", "deploy");
    let addr = args.get_or("addr", "127.0.0.1:7171");
    let socket = args.get("socket").map(PathBuf::from);
    let bundle = args.get("bundle").map(PathBuf::from);
    let kernel = args.get("kernel").map(str::to_string);
    let workload = args.get("workload").map(str::to_string);
    let platform = args.get("platform").map(str::to_string);
    let dims: Vec<(String, Option<i64>)> = ["m", "n", "k"]
        .iter()
        .map(|d| Ok((d.to_string(), args.get(d).map(|v| v.parse::<i64>()).transpose()?)))
        .collect::<Result<_>>()?;
    args.finish()?;

    let need = |v: Option<String>, flag: &str| {
        v.ok_or_else(|| anyhow::anyhow!("query --op {op} requires --{flag}"))
    };
    let request = match op.as_str() {
        "ping" => Request::Ping,
        "lookup" => Request::Lookup {
            platform,
            kernel: need(kernel, "kernel")?,
            workload: need(workload, "workload")?,
        },
        "deploy" => Request::Deploy {
            platform,
            kernel: need(kernel, "kernel")?,
            workload: need(workload, "workload")?,
            fingerprint: Some(Fingerprint::detect()),
        },
        "stats" => Request::Stats,
        "metrics" => Request::Metrics,
        "report" => Request::Report { platform },
        "retune-next" => Request::RetuneNext,
        "portfolio" => {
            let given: std::collections::BTreeMap<String, i64> =
                dims.into_iter().filter_map(|(k, v)| v.map(|v| (k, v))).collect();
            Request::Portfolio {
                platform,
                kernel: need(kernel, "kernel")?,
                dims: if given.is_empty() { None } else { Some(given) },
                fingerprint: Some(Fingerprint::detect()),
            }
        }
        "shutdown" => Request::Shutdown,
        other => {
            return Err(anyhow::anyhow!(
                "unknown query op {other}; expected \
                 ping|lookup|deploy|stats|metrics|report|retune-next|portfolio|shutdown"
            ))
        }
    };
    let client = match (bundle, socket) {
        (Some(path), _) => Client::from_bundle(path)?,
        #[cfg(unix)]
        (None, Some(path)) => Client::unix(path),
        #[cfg(not(unix))]
        (None, Some(_)) => {
            return Err(anyhow::anyhow!("--socket requires a unix platform; use --addr"))
        }
        (None, None) => Client::tcp(addr),
    };
    println!("{}", client.call(&request)?.compact());
    Ok(())
}

/// Fetch a daemon's telemetry registry (pretty-printed JSON): the
/// `metrics` wire op — counters plus latency-histogram summaries.
fn cmd_metrics(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7171");
    let socket = args.get("socket").map(PathBuf::from);
    args.finish()?;
    let client = match socket {
        #[cfg(unix)]
        Some(path) => Client::unix(path),
        #[cfg(not(unix))]
        Some(_) => return Err(anyhow::anyhow!("--socket requires a unix platform; use --addr")),
        None => Client::tcp(addr),
    };
    println!("{}", client.call(&Request::Metrics)?.pretty());
    Ok(())
}

/// Core-hour ledger report: per-kernel tuning spend vs realized
/// benefit, break-even status, and active regressions — the `report`
/// wire op rendered as a table, followed by one `JSON:` line so
/// scripts (and the CI smoke) never have to parse the table.
fn cmd_report(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7171");
    let socket = args.get("socket").map(PathBuf::from);
    let bundle = args.get("bundle").map(PathBuf::from);
    let platform = args.get("platform").map(str::to_string);
    let json_only = args.get_bool("json");
    args.finish()?;
    let client = match (bundle, socket) {
        (Some(path), _) => Client::from_bundle(path)?,
        #[cfg(unix)]
        (None, Some(path)) => Client::unix(path),
        #[cfg(not(unix))]
        (None, Some(_)) => {
            return Err(anyhow::anyhow!("--socket requires a unix platform; use --addr"))
        }
        (None, None) => Client::tcp(addr),
    };
    let reply = client.report(platform)?;
    let report = reply
        .get("report")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("malformed report reply: {}", reply.compact()))?;
    if !json_only {
        print_ledger_report(&report);
    }
    println!("JSON: {}", report.compact());
    Ok(())
}

/// Render the `report` payload as human tables: one ledger row per
/// (platform, kernel), then the active-regression list.
fn print_ledger_report(report: &Json) {
    let fmt_s = |v: Option<&Json>| -> String {
        v.and_then(Json::as_f64).map(|s| format!("{s:.3}")).unwrap_or_else(|| "-".into())
    };
    let fmt_u = |v: Option<&Json>| -> String {
        v.and_then(Json::as_u64).map(|n| n.to_string()).unwrap_or_else(|| "-".into())
    };
    let mut t = Table::new(&[
        "platform", "kernel", "spend s", "benefit s", "net s", "invocations", "tunes",
        "break-even", "eta s", "regressing",
    ]);
    for p in report.get("platforms").and_then(Json::as_arr).unwrap_or(&[]) {
        let platform = p.get("platform").and_then(Json::as_str).unwrap_or("?");
        for k in p.get("kernels").and_then(Json::as_arr).unwrap_or(&[]) {
            let flag = |key: &str| {
                if k.get(key).and_then(Json::as_bool).unwrap_or(false) { "yes" } else { "no" }
            };
            t.row(vec![
                platform.chars().take(24).collect(),
                k.get("kernel").and_then(Json::as_str).unwrap_or("?").to_string(),
                fmt_s(k.get("spend_core_seconds")),
                fmt_s(k.get("benefit_core_seconds")),
                fmt_s(k.get("net_core_seconds")),
                fmt_u(k.get("invocations")),
                fmt_u(k.get("tunes")),
                flag("break_even").to_string(),
                fmt_u(k.get("break_even_eta_s")),
                flag("regressing").to_string(),
            ]);
        }
    }
    if t.is_empty() {
        println!("(empty ledger: no tuning spend or benefit recorded yet)");
    } else {
        print!("{}", t.render());
    }
    if let Some(totals) = report.get("totals") {
        println!(
            "totals: spend {} s, benefit {} s, net {} s over {} kernel(s); {} broke even, {} regressing",
            fmt_s(totals.get("spend_core_seconds")),
            fmt_s(totals.get("benefit_core_seconds")),
            fmt_s(totals.get("net_core_seconds")),
            fmt_u(totals.get("kernels")),
            fmt_u(totals.get("break_even")),
            fmt_u(totals.get("regressions_active")),
        );
    }
    let flagged = report.get("regressions").and_then(Json::as_arr).unwrap_or(&[]);
    for r in flagged {
        println!(
            "REGRESSING: {}/{} on {}",
            r.get("kernel").and_then(Json::as_str).unwrap_or("?"),
            r.get("workload").and_then(Json::as_str).unwrap_or("?"),
            r.get("platform").and_then(Json::as_str).unwrap_or("?"),
        );
    }
}

/// Fleet worker: lease tasks from a daemon, execute, report back.
fn cmd_work(args: &Args, artifacts: &Path) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7171");
    let socket = args.get("socket").map(PathBuf::from);
    let once = args.get_bool("once");
    let quick = args.get_bool("quick");
    let any_platform = args.get_bool("any-platform");
    let lease_ttl_s = args.get_parsed::<u64>("lease-ttl", DEFAULT_LEASE_TTL_S)?;
    let heartbeat_s = args.get_parsed::<u64>("heartbeat", 0)?;
    let poll_s = args.get_parsed::<u64>("poll", 2)?;
    let wait_s = args.get_parsed::<u64>("wait-secs", 15)?;
    let seed = args.get_parsed::<u64>("seed", 42)?;
    let batch = args.get_parsed::<usize>("batch", 4)?;
    let k_max = args.get_parsed::<usize>("k", 4)?;
    let target = args.get_parsed::<f64>("target", 0.9)?;
    let audit = args.get("audit").map(PathBuf::from);
    let trace_path = args.get("trace").map(PathBuf::from);
    install_faults(args)?;
    args.finish()?;

    if let Some(path) = &trace_path {
        obs::trace::install(path)?;
        println!("trace spans: {}", path.display());
    }
    let client = match socket {
        #[cfg(unix)]
        Some(path) => Client::unix(path),
        #[cfg(not(unix))]
        Some(_) => return Err(anyhow::anyhow!("--socket requires a unix platform; use --addr")),
        None => Client::tcp(addr),
    };
    let worker = Worker::new(
        client,
        WorkerOpts {
            artifacts: artifacts.to_path_buf(),
            lease_ttl_s,
            heartbeat_s,
            quick,
            seed,
            batch,
            any_platform,
            k_max,
            target,
            audit,
        },
    );
    println!(
        "worker on platform {} ({}; lease ttl {lease_ttl_s}s)",
        worker.host_key(),
        if any_platform { "any-platform" } else { "own-platform tasks only" },
    );
    let summary = worker.run(
        once,
        std::time::Duration::from_secs(poll_s.max(1)),
        std::time::Duration::from_secs(wait_s),
    )?;
    println!(
        "worker done: {} task(s) completed, {} failed",
        summary.completed, summary.failed
    );
    Ok(())
}

/// `audit verify` / `audit replay` over a hash-chained decision log.
fn cmd_audit(args: &Args) -> Result<()> {
    let action = args.positional.get(1).map(String::as_str);
    let log = args
        .positional
        .get(2)
        .map(PathBuf::from)
        .ok_or_else(|| {
            anyhow::anyhow!("audit requires a log path, e.g. portatune audit verify audit.log")
        })?;
    match action {
        Some("verify") => {
            args.finish()?;
            cmd_audit_verify(&log)
        }
        Some("replay") => cmd_audit_replay(args, &log),
        other => Err(anyhow::anyhow!(
            "audit requires an action (verify|replay), got {other:?}"
        )),
    }
}

/// Walk the chain; exit 0 when intact, exit 2 with the first bad entry
/// index on any tampering or truncation (distinct from exit 1, the
/// generic CLI error path, so scripts can tell "bad log" from "bad
/// invocation").
fn cmd_audit_verify(log: &Path) -> Result<()> {
    match verify_log(log) {
        Ok(report) => {
            let head = match (report.head_present, report.head_lag) {
                (false, _) => ", no head sidecar".to_string(),
                (true, 0) => ", head current".to_string(),
                (true, lag) => format!(", head lags by {lag} entr(ies)"),
            };
            println!(
                "ok: {} entr(ies), chain intact{}{head}",
                report.entries,
                if report.torn_tail { ", torn tail discarded" } else { "" },
            );
            Ok(())
        }
        Err(e) => {
            eprintln!("audit verify FAILED: {e}");
            if let Some(index) = e.index() {
                eprintln!("first bad entry index: {index}");
            }
            std::process::exit(2);
        }
    }
}

/// Re-derive the decision sequence from a verified log, optionally
/// filtered to one platform's entries.
fn cmd_audit_replay(args: &Args, log: &Path) -> Result<()> {
    let platform = args.get("platform").map(str::to_string);
    args.finish()?;
    let entries = read_verified(log)
        .map_err(|e| anyhow::anyhow!("audit log failed verification: {e}"))?;
    let total = entries.len();
    let mut shown = 0usize;
    for entry in entries {
        if let Some(want) = &platform {
            if entry.event.platform() != Some(want.as_str()) {
                continue;
            }
        }
        println!("#{} t={} {}", entry.seq, entry.ts, entry.event.describe());
        shown += 1;
    }
    println!("({shown} of {total} entr(ies) shown)");
    Ok(())
}

/// `bundle export` / `bundle import` / `bundle info` over the
/// versioned, checksummed offline decision-bundle format
/// (docs/PROTOCOL.md has the byte-level spec).
fn cmd_bundle(args: &Args, shards_dir: &Path) -> Result<()> {
    let action = args.positional.get(1).map(String::as_str);
    let file = args.positional.get(2).map(PathBuf::from).ok_or_else(|| {
        anyhow::anyhow!("bundle requires a file path, e.g. portatune bundle export perf.bundle")
    })?;
    match action {
        Some("export") => cmd_bundle_export(args, shards_dir, &file),
        Some("import") => {
            args.finish()?;
            cmd_bundle_import(shards_dir, &file)
        }
        Some("info") => {
            args.finish()?;
            cmd_bundle_info(&file)
        }
        other => Err(anyhow::anyhow!(
            "bundle requires an action (export|import|info), got {other:?}"
        )),
    }
}

/// Pack every shard in the store, plus this host's fingerprint, into
/// one bundle file.  Cut directly from the store (no daemon), the
/// generation is 0; `query --bundle` replies echo it so parity checks
/// against a live daemon can tell which cut they are looking at.
fn cmd_bundle_export(args: &Args, shards_dir: &Path, file: &Path) -> Result<()> {
    let host = Fingerprint::detect();
    let platform = args.get_or("platform", &host.key());
    args.finish()?;
    let db = ShardedDb::open(shards_dir)?;
    let mut shard_texts = Vec::new();
    for key in db.platforms()? {
        if let Some(text) = db.export_shard_text(&key)? {
            shard_texts.push(text);
        }
    }
    let meta = BundleMeta { platform, generation: 0, fingerprint: Some(host) };
    let text = write_bundle(&meta, &shard_texts);
    std::fs::write(file, &text).with_context(|| format!("writing {}", file.display()))?;
    println!(
        "exported {} shard(s) from {} to {} ({} bytes, platform {})",
        shard_texts.len(),
        shards_dir.display(),
        file.display(),
        text.len(),
        meta.platform
    );
    Ok(())
}

/// Verify a bundle and merge its shards into the store (same
/// identity-deduped merge a live `record` uses, so importing twice is
/// idempotent).
fn cmd_bundle_import(shards_dir: &Path, file: &Path) -> Result<()> {
    let text = std::fs::read_to_string(file)
        .with_context(|| format!("reading bundle {}", file.display()))?;
    let (meta, shard_texts) =
        parse_bundle(&text).with_context(|| format!("verifying bundle {}", file.display()))?;
    let db = ShardedDb::open(shards_dir)?;
    for shard_text in &shard_texts {
        let (platform, entries) = db.import_shard_text(shard_text)?;
        println!("imported shard {platform}: {entries} entr(ies)");
    }
    println!(
        "bundle {} (platform {}, gen {}): {} shard(s) merged into {}",
        file.display(),
        meta.platform,
        meta.generation,
        shard_texts.len(),
        shards_dir.display()
    );
    Ok(())
}

/// Verify a bundle and describe what it would serve.
fn cmd_bundle_info(file: &Path) -> Result<()> {
    let bundle = OfflineBundle::load(file)?;
    let snap = bundle.snapshot();
    println!(
        "bundle {}: platform {}, gen {}, {} shard(s)",
        file.display(),
        bundle.platform(),
        snap.generation(),
        snap.shards().len()
    );
    for shard in snap.shards() {
        println!(
            "  shard {}: {} entr(ies), {} portfolio(s){}",
            shard.platform_key,
            shard.entries.len(),
            shard.portfolios.len(),
            if shard.fingerprint.is_some() { ", fingerprint" } else { "" }
        );
    }
    Ok(())
}

/// One-shot migration: v1 single-file DB → v2 shard store.
fn cmd_db_migrate(args: &Args, db_path: &Path, shards_dir: &Path) -> Result<()> {
    args.finish()?;
    let db = ShardedDb::open(shards_dir)?;
    let imported = db.import_legacy(db_path)?;
    println!(
        "imported {imported} entr(ies) from {} into {} ({} platform shard(s))",
        db_path.display(),
        shards_dir.display(),
        db.platforms()?.len()
    );
    Ok(())
}

fn cmd_inspect(artifacts: &Path) -> Result<()> {
    let registry = open_registry(artifacts)?;
    println!(
        "platform: {} ({} devices)",
        registry.runtime().platform_name(),
        registry.runtime().device_count()
    );
    let mut t = Table::new(&["kernel", "workload", "dims", "variants", "flops", "bytes"]);
    for k in &registry.manifest().kernels {
        for w in &k.workloads {
            let dims: Vec<String> = w.dims.iter().map(|(k, v)| format!("{k}={v}")).collect();
            t.row(vec![
                k.name.clone(),
                w.tag.clone(),
                dims.join(","),
                w.variants.len().to_string(),
                w.flops.to_string(),
                w.bytes.to_string(),
            ]);
        }
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_tune(args: &Args, artifacts: &Path, db_path: &Path, shards_dir: &Path) -> Result<()> {
    let kernel = args
        .get("kernel")
        .ok_or_else(|| anyhow::anyhow!("tune requires --kernel"))?
        .to_string();
    if args.get_bool("sweep") {
        return cmd_tune_sweep(args, &kernel, shards_dir);
    }
    let workload = args
        .get("workload")
        .ok_or_else(|| anyhow::anyhow!("tune requires --workload (or --sweep)"))?
        .to_string();
    let strategy_name = args.get_or("strategy", "exhaustive");
    let budget = args.get_parsed::<usize>("budget", usize::MAX)?;
    let seed = args.get_parsed::<u64>("seed", 42)?;
    let batch = args.get_parsed::<usize>("batch", 1)?;
    let quick = args.get_bool("quick");
    let warm = args.get_bool("warm-start");
    let no_record = args.get_bool("no-record");
    args.finish()?;

    let registry = open_registry(artifacts)?;
    let mut db = PerfDb::open(db_path)?;
    let mut tuner = Tuner::new(&registry);
    tuner.batch = batch.max(1);
    if quick {
        tuner.measure_cfg = MeasureConfig::quick();
    }
    if warm {
        let host = Fingerprint::detect();
        // Prefer the shard store's fingerprint-similarity ranking
        // (nearest platform first); fall back to the legacy file's
        // exclude-only heuristic when the shard store is absent *or has
        // nothing to offer* (an empty perfdb.d left behind by a prior
        // serve/migrate run must not shadow a populated --db file).
        let mut configs = Vec::new();
        if shards_dir.is_dir() {
            let sharded = ShardedDb::open(shards_dir)?;
            let ranked = transfer::rank_candidates(
                &sharded.all_shards()?,
                &host,
                &kernel,
                &workload,
                &host.key(),
            );
            configs = transfer::warm_start_configs(&ranked, usize::MAX);
        }
        if configs.is_empty() {
            configs = db.warm_start(&kernel, &workload, &host.key());
        }
        let seeded = tuner.seed_warm_start(configs, 8);
        println!("warm start: {seeded} candidate(s)");
    }
    let mut strategy = make_strategy(&strategy_name, seed)?;
    let outcome = tuner.tune(&kernel, &workload, strategy.as_mut(), budget)?;

    println!(
        "tuned {kernel}/{workload} with {} ({} evaluations)",
        outcome.strategy,
        outcome.evaluations()
    );
    println!(
        "  baseline (default schedule): {:.3} ms   xla reference: {:.3} ms ({:.2} GFLOP/s)",
        outcome.baseline_time() * 1e3,
        outcome.reference.cost() * 1e3,
        outcome.reference.gflops(outcome.flops)
    );
    match &outcome.best {
        Some(best) => println!(
            "  best:     {:.3} ms ({}) -> {:.2}x speedup, {:.1}% time reduction",
            best.cost * 1e3,
            best.config_id,
            outcome.speedup(),
            outcome.time_reduction_pct()
        ),
        None => println!("  no variant beat the correctness gate; baseline retained"),
    }
    let mut ranked: Vec<_> = outcome.evaluated.iter().collect();
    ranked.sort_by(|a, b| a.cost.total_cmp(&b.cost));
    let mut t = Table::new(&["variant", "median", "status"]);
    for v in ranked.iter().take(10) {
        let status = match &v.correctness {
            Some(c) if c.ok => "ok".to_string(),
            Some(c) => format!("GATED (max abs err {:.2e})", c.max_abs_err),
            None => "FAILED".to_string(),
        };
        let time = v
            .measurement
            .as_ref()
            .map(|m| format!("{:.3} ms", m.cost() * 1e3))
            .unwrap_or_else(|| "-".to_string());
        t.row(vec![v.config_id.clone(), time, status]);
    }
    print!("{}", t.render());
    println!("  stats: {}", outcome.stats.render());

    if !no_record {
        tuner.record(&mut db, &outcome);
        db.save()?;
        println!(
            "recorded to {} (platform {})",
            db_path.display(),
            outcome.platform.key()
        );
    }
    Ok(())
}

/// `tune --sweep`: tune every shape of the native GEMM sweep (no
/// artifacts or runtime needed) and record each per-shape winner into
/// the shard store — the tuning history `portfolio build` clusters.
fn cmd_tune_sweep(args: &Args, kernel: &str, shards_dir: &Path) -> Result<()> {
    let quick = args.get_bool("quick");
    let seed = args.get_parsed::<u64>("seed", 42)?;
    if args.get("workload").is_some() {
        return Err(anyhow::anyhow!(
            "--sweep tunes the whole built-in shape sweep; drop --workload"
        ));
    }
    args.finish()?;
    anyhow::ensure!(
        kernel == gemm::KERNEL,
        "--sweep supports the native gemm family only; use tune-all for artifact-backed kernels"
    );
    let host = Fingerprint::detect();
    let sweep = run_gemm_sweep(quick, seed, &host)?;
    let db = ShardedDb::open(shards_dir)?;
    let entries = sweep.entries(&host.key(), "sweep-exhaustive");
    db.record_many(&host.key(), Some(&host), entries.clone())?;

    let mut t = Table::new(&["shape", "best", "tuned", "default", "speedup", "GFLOP/s"]);
    for entry in &entries {
        let flops = sweep
            .matrix
            .shapes
            .iter()
            .find(|s| s.tag == entry.tag)
            .map(|s| s.flops)
            .unwrap_or(0);
        t.row(vec![
            entry.tag.clone(),
            entry.best_config_id.clone(),
            format!("{:.3} ms", entry.best_time_s * 1e3),
            format!("{:.3} ms", entry.baseline_time_s * 1e3),
            format!("{:.2}x", entry.speedup()),
            format!("{:.2}", flops as f64 / entry.best_time_s / 1e9),
        ]);
    }
    print!("{}", t.render());
    println!(
        "recorded {} shape(s) to {} (platform {})",
        entries.len(),
        shards_dir.display(),
        host.key()
    );
    Ok(())
}

/// Shared sweep runner for `tune --sweep` and `portfolio build` (the
/// worker fleet's sweep tasks run the same [`portfolio::sweep_native`]
/// without the progress line).
fn run_gemm_sweep(quick: bool, seed: u64, host: &Fingerprint) -> Result<GemmSweep> {
    let shapes = if quick { gemm::quick_sweep() } else { gemm::default_sweep() };
    println!(
        "sweeping {} over {} shapes x {} configs (native, no artifacts needed)",
        gemm::KERNEL,
        shapes.len(),
        gemm::configs().len()
    );
    portfolio::sweep_native(gemm::KERNEL, quick, seed, host)
}

/// `portfolio build` / `portfolio show`.
fn cmd_portfolio(args: &Args, shards_dir: &Path) -> Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("build") => cmd_portfolio_build(args, shards_dir),
        Some("show") => cmd_portfolio_show(args, shards_dir),
        other => Err(anyhow::anyhow!(
            "portfolio requires an action (build|show), got {other:?}"
        )),
    }
}

fn cmd_portfolio_build(args: &Args, shards_dir: &Path) -> Result<()> {
    let kernel = args.get_or("kernel", gemm::KERNEL);
    let k_max = args.get_parsed::<usize>("k", 4)?;
    let target = args.get_parsed::<f64>("target", 0.9)?;
    let quick = args.get_bool("quick");
    let seed = args.get_parsed::<u64>("seed", 42)?;
    args.finish()?;
    anyhow::ensure!(
        kernel == gemm::KERNEL,
        "portfolio build supports the native gemm family only (so far)"
    );

    let host = Fingerprint::detect();
    let sweep = run_gemm_sweep(quick, seed, &host)?;
    let built = sweep.matrix.build_portfolio(k_max, target)?;

    // Persist the sweep history AND the portfolio: the serve daemon
    // answers lookups from the former and `portfolio` ops from the
    // latter.
    let db = ShardedDb::open(shards_dir)?;
    let entries = sweep.entries(&host.key(), "sweep-exhaustive");
    db.record_many(&host.key(), Some(&host), entries)?;
    db.record_portfolio(&host.key(), Some(&host), built.clone())?;

    print_portfolio(&built, &host.key());
    println!(
        "persisted to {} — {} config(s) retain {:.1}% of per-shape-tuned performance",
        shards_dir.display(),
        built.len(),
        built.retained * 100.0
    );
    Ok(())
}

fn cmd_portfolio_show(args: &Args, shards_dir: &Path) -> Result<()> {
    let kernel = args.get_or("kernel", gemm::KERNEL);
    let platform = args
        .get("platform")
        .map(str::to_string)
        .unwrap_or_else(|| Fingerprint::detect().key());
    args.finish()?;
    let db = ShardedDb::open(shards_dir)?;
    match db.portfolio(&platform, &kernel)? {
        Some(p) => {
            print_portfolio(&p, &platform);
            Ok(())
        }
        None => {
            println!("(no {kernel} portfolio recorded for platform {platform})");
            Ok(())
        }
    }
}

fn print_portfolio(p: &portatune::coordinator::portfolio::Portfolio, platform: &str) {
    println!(
        "{} portfolio on {platform}: {} config(s), retained {:.1}%, built by {} at {}",
        p.kernel,
        p.len(),
        p.retained * 100.0,
        p.strategy,
        p.built_at
    );
    let mut t = Table::new(&["config", "covers", "shapes"]);
    for item in &p.items {
        t.row(vec![
            item.config_id.clone(),
            item.covered.len().to_string(),
            item.covered.join(","),
        ]);
    }
    print!("{}", t.render());
}

fn cmd_tune_all(args: &Args, artifacts: &Path, db_path: &Path) -> Result<()> {
    let kernels = args.get_or("kernels", "");
    let strategy_name = args.get_or("strategy", "exhaustive");
    let budget = args.get_parsed::<usize>("budget", usize::MAX)?;
    let seed = args.get_parsed::<u64>("seed", 42)?;
    let batch = args.get_parsed::<usize>("batch", 1)?;
    let quick = args.get_bool("quick");
    args.finish()?;

    let registry = open_registry(artifacts)?;
    let mut db = PerfDb::open(db_path)?;
    let selected: Vec<String> = if kernels.is_empty() {
        registry.manifest().kernels.iter().map(|k| k.name.clone()).collect()
    } else {
        kernels.split(',').map(str::to_string).collect()
    };
    let mut tuner = Tuner::new(&registry);
    tuner.batch = batch.max(1);
    if quick {
        tuner.measure_cfg = MeasureConfig::quick();
    }
    let mut t = Table::new(&["kernel", "workload", "best", "speedup", "evals", "reps saved"]);
    for kname in &selected {
        let entry = registry
            .manifest()
            .kernel(kname)
            .ok_or_else(|| anyhow::anyhow!("unknown kernel {kname}"))?
            .clone();
        for w in &entry.workloads {
            let mut strategy = make_strategy(&strategy_name, seed)?;
            let outcome = tuner.tune(kname, &w.tag, strategy.as_mut(), budget)?;
            t.row(vec![
                kname.clone(),
                w.tag.clone(),
                outcome
                    .best
                    .as_ref()
                    .map(|b| b.config_id.clone())
                    .unwrap_or_else(|| "baseline".into()),
                format!("{:.2}x", outcome.speedup()),
                outcome.evaluations().to_string(),
                outcome.stats.reps_saved.to_string(),
            ]);
            tuner.record(&mut db, &outcome);
            db.save()?;
            eprint!(".");
        }
    }
    eprintln!();
    print!("{}", t.render());
    Ok(())
}

fn cmd_report_fig1(args: &Args, artifacts: &Path) -> Result<()> {
    let kernels = args.get_or("kernels", "axpy,dot,triad");
    let csv = args.get("csv").map(PathBuf::from);
    let quick = args.get_bool("quick");
    args.finish()?;

    let registry = open_registry(artifacts)?;
    let mut tuner = Tuner::new(&registry);
    if quick {
        tuner.measure_cfg = MeasureConfig::quick();
    }
    let mut all_csv = String::new();
    for kname in kernels.split(',').filter(|s| !s.is_empty()) {
        let entry = registry
            .manifest()
            .kernel(kname)
            .ok_or_else(|| anyhow::anyhow!("unknown kernel {kname}"))?
            .clone();
        let mut report = Fig1Report::new(kname);
        for w in &entry.workloads {
            let mut strategy = Exhaustive::new();
            let outcome = tuner.tune(kname, &w.tag, &mut strategy, usize::MAX)?;
            report.push(Fig1Row {
                size: w.tag.clone(),
                baseline_s: outcome.baseline_time(),
                reference_s: outcome.reference.cost(),
                tuned_s: outcome.best_time(),
                best_id: outcome
                    .best
                    .as_ref()
                    .map(|b| b.config_id.clone())
                    .unwrap_or_else(|| "baseline".into()),
                evaluations: outcome.evaluations(),
            });
            eprint!(".");
        }
        eprintln!();
        println!("{}", report.render());
        all_csv.push_str(&report.to_csv());
    }
    if let Some(path) = csv {
        std::fs::write(&path, &all_csv)?;
        println!("csv written to {}", path.display());
    }
    Ok(())
}

fn cmd_db_list(db_path: &Path) -> Result<()> {
    let db = PerfDb::open(db_path)?;
    if db.is_empty() {
        println!("(empty performance database at {})", db_path.display());
        return Ok(());
    }
    let mut t = Table::new(&[
        "platform", "kernel", "workload", "best", "time", "speedup", "strategy", "evals",
    ]);
    for e in db.entries() {
        t.row(vec![
            e.platform_key.chars().take(24).collect(),
            e.kernel.clone(),
            e.tag.clone(),
            e.best_config_id.clone(),
            format!("{:.3} ms", e.best_time_s * 1e3),
            format!("{:.2}x", e.speedup()),
            e.strategy.clone(),
            e.evaluations.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_deploy(args: &Args, artifacts: &Path, db_path: &Path) -> Result<()> {
    let kernel = args
        .get("kernel")
        .ok_or_else(|| anyhow::anyhow!("deploy requires --kernel"))?
        .to_string();
    let workload = args
        .get("workload")
        .ok_or_else(|| anyhow::anyhow!("deploy requires --workload"))?
        .to_string();
    args.finish()?;
    let registry = open_registry(artifacts)?;
    let db = PerfDb::open(db_path)?;
    let tuner = Tuner::new(&registry);
    println!("{}", tuner.deployed_artifact(&db, &kernel, &workload)?);
    Ok(())
}

fn cmd_annotate(args: &Args) -> Result<()> {
    let file = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("annotate requires a file argument"))?;
    args.finish()?;
    let source = std::fs::read_to_string(file)?;
    let blocks = extract_blocks(&source);
    if blocks.is_empty() {
        println!("no /*@ tune ... @*/ blocks in {file}");
        return Ok(());
    }
    for (i, block) in blocks.iter().enumerate() {
        match Annotation::parse(block) {
            Ok(ann) => {
                println!("# block {} — kernel={} ok", i + 1, ann.kernel);
                print!("{}", ann.render());
            }
            Err(e) => println!("# block {} — parse error: {e}", i + 1),
        }
    }
    Ok(())
}

/// The paper's full annotation-driven workflow: every `/*@ tune @*/`
/// block in the file selects its kernel, workload(s), strategy, budget,
/// and seed; the tuner runs each and records the winners.
fn cmd_tune_annotated(args: &Args, artifacts: &Path, db_path: &Path) -> Result<()> {
    let file = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("tune-annotated requires a file argument"))?
        .clone();
    let quick = args.get_bool("quick");
    args.finish()?;

    let source = std::fs::read_to_string(&file)?;
    let blocks = extract_blocks(&source);
    anyhow::ensure!(!blocks.is_empty(), "no /*@ tune ... @*/ blocks in {file}");

    let registry = open_registry(artifacts)?;
    let mut db = PerfDb::open(db_path)?;
    let mut tuner = Tuner::new(&registry);
    if quick {
        tuner.measure_cfg = MeasureConfig::quick();
    }

    let mut t = Table::new(&["kernel", "workload", "strategy", "best", "speedup", "evals"]);
    for (i, block) in blocks.iter().enumerate() {
        let ann = Annotation::parse(block)
            .map_err(|e| anyhow::anyhow!("block {}: {e}", i + 1))?;
        let entry = registry
            .manifest()
            .kernel(&ann.kernel)
            .ok_or_else(|| anyhow::anyhow!("block {}: unknown kernel {}", i + 1, ann.kernel))?
            .clone();
        // A block may bind one workload or apply to all of the kernel's.
        let tags: Vec<String> = match &ann.workload {
            Some(w) => vec![w.clone()],
            None => entry.workloads.iter().map(|w| w.tag.clone()).collect(),
        };
        let strategy_name = ann.search.clone().unwrap_or_else(|| "exhaustive".into());
        let budget = ann
            .options
            .get("budget")
            .map(|b| b.parse::<usize>())
            .transpose()
            .map_err(|_| anyhow::anyhow!("block {}: bad budget", i + 1))?
            .unwrap_or(usize::MAX);
        let seed = ann
            .options
            .get("seed")
            .map(|s| s.parse::<u64>())
            .transpose()
            .map_err(|_| anyhow::anyhow!("block {}: bad seed", i + 1))?
            .unwrap_or(42);

        for tag in tags {
            let mut strategy = make_strategy(&strategy_name, seed)?;
            let outcome = tuner.tune(&ann.kernel, &tag, strategy.as_mut(), budget)?;
            t.row(vec![
                ann.kernel.clone(),
                tag.clone(),
                strategy_name.clone(),
                outcome
                    .best
                    .as_ref()
                    .map(|b| b.config_id.clone())
                    .unwrap_or_else(|| "baseline".into()),
                format!("{:.2}x", outcome.speedup()),
                outcome.evaluations().to_string(),
            ]);
            tuner.record(&mut db, &outcome);
            eprint!(".");
        }
    }
    eprintln!();
    db.save()?;
    print!("{}", t.render());
    println!("recorded to {}", db_path.display());
    Ok(())
}
