//! Simulated annealing over the one-step neighborhood graph.
//!
//! Metropolis acceptance on *relative* cost deltas: timings span four
//! orders of magnitude across workloads (4K axpy ≈ µs, 4M triad ≈ ms),
//! so an absolute-delta temperature would need per-workload scaling.
//! With `d = (new - current) / current`, a temperature of 0.25 means
//! "accept a 25% slowdown with probability 1/e", which transfers across
//! kernels unchanged.

use super::{Budget, SearchResult, SearchStrategy};
use crate::coordinator::spec::{Config, TuningSpec};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
/// Simulated annealing over the neighbor move set (seeded).
pub struct Anneal {
    seed: u64,
    /// Initial temperature (relative-slowdown units).
    t0: f64,
    /// Geometric cooling factor per step.
    alpha: f64,
}

impl Anneal {
    /// An annealer with the default temperature schedule.
    pub fn new(seed: u64) -> Anneal {
        Anneal { seed, t0: 0.35, alpha: 0.92 }
    }

    /// An annealer with an explicit initial temperature and decay.
    pub fn with_schedule(seed: u64, t0: f64, alpha: f64) -> Anneal {
        assert!(t0 > 0.0 && alpha > 0.0 && alpha < 1.0, "bad annealing schedule");
        Anneal { seed, t0, alpha }
    }
}

impl SearchStrategy for Anneal {
    fn name(&self) -> &'static str {
        "anneal"
    }

    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult {
        let mut rng = Rng::new(self.seed);
        let total_valid = spec.enumerate().len();
        let mut b = Budget::new(spec, budget, eval);

        let Some(mut current) = spec.random_config(&mut rng, 256) else {
            return b.finish();
        };
        let Some(mut current_cost) = b.eval(&current) else {
            return b.finish();
        };
        let mut temperature = self.t0;

        while !b.exhausted() && !b.space_exhausted(total_valid) {
            let neighbors = spec.neighbors(&current);
            if neighbors.is_empty() {
                // Isolated point: random teleport.
                match spec.random_config(&mut rng, 256) {
                    Some(c) => {
                        let Some(cost) = b.eval(&c) else { break };
                        current = c;
                        current_cost = cost;
                        continue;
                    }
                    None => break,
                }
            }
            let cand = neighbors[rng.gen_range(neighbors.len())].clone();
            let Some(cand_cost) = b.eval(&cand) else { break };

            let accept = if !current_cost.is_finite() {
                // Escape failed states unconditionally toward finite ones.
                cand_cost.is_finite()
            } else if cand_cost <= current_cost {
                true
            } else if cand_cost.is_finite() {
                let d = (cand_cost - current_cost) / current_cost;
                rng.next_f64() < (-d / temperature.max(1e-9)).exp()
            } else {
                false
            };
            if accept {
                current = cand;
                current_cost = cand_cost;
            }
            temperature *= self.alpha;

            // Reheat when frozen: all-neighbors-seen at low temperature
            // means the chain has stopped moving; restart the schedule
            // from a random point to keep using the remaining budget.
            if temperature < 1e-3 {
                temperature = self.t0;
                if let Some(c) = spec.random_config(&mut rng, 256) {
                    if !b.seen(&c) {
                        let Some(cost) = b.eval(&c) else { break };
                        current = c;
                        current_cost = cost;
                    }
                }
            }
        }
        b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn finds_optimum_with_moderate_budget() {
        // The bowl has 30 valid points; annealing with the full budget
        // must land on the optimum (it can always walk there).
        let mut s = Anneal::new(17);
        let r = run_on_bowl(&mut s, usize::MAX);
        assert_eq!(r.best.unwrap().1, 1.0);
    }

    #[test]
    fn near_optimal_with_third_budget() {
        let spec = bowl_spec();
        let full = spec.enumerate().len();
        let mut s = Anneal::new(23);
        let r = run_on_bowl(&mut s, full / 3);
        let (_, cost) = r.best.unwrap();
        // Optimum is 1.0; worst point is ~17.  Within 3x of optimal on a
        // third of the budget is a loose, stable bound.
        assert!(cost <= 3.0, "anneal best {cost} too far from optimum");
    }

    #[test]
    fn respects_budget() {
        let mut s = Anneal::new(5);
        let r = run_on_bowl(&mut s, 6);
        assert!(r.evaluations() <= 6);
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = bowl_spec();
        let ids = |r: &SearchResult| {
            r.history.iter().map(|e| spec.config_id(&e.config)).collect::<Vec<_>>()
        };
        let r1 = run_on_bowl(&mut Anneal::new(31), 12);
        let r2 = run_on_bowl(&mut Anneal::new(31), 12);
        assert_eq!(ids(&r1), ids(&r2));
    }

    #[test]
    #[should_panic]
    fn bad_schedule_panics() {
        Anneal::with_schedule(1, 0.0, 0.9);
    }

    #[test]
    fn escapes_infinite_cost_starts() {
        // Make a stripe of the space fail (infinite cost): annealing must
        // still find a finite best.
        let spec = bowl_spec();
        let mut eval = {
            let spec = spec.clone();
            move |c: &Config| {
                if c["block_size"] >= 2048 {
                    f64::INFINITY
                } else {
                    bowl_cost(&spec, c)
                }
            }
        };
        let mut s = Anneal::new(41);
        let r = s.run(&spec, usize::MAX, &mut eval);
        let (best, cost) = r.best.unwrap();
        assert!(cost.is_finite());
        assert!(best["block_size"] < 2048);
    }
}
