//! Nelder–Mead simplex search over the parameter index space — the
//! remaining member of Orio's strategy set.
//!
//! The simplex operates on continuous coordinates in index space (one
//! dimension per parameter); every probe is rounded and clamped to the
//! nearest domain index and evaluated through the shared budget (so
//! re-probing a rounded-to-same config is free).  Invalid (constraint-
//! violating) probes cost +inf, which the standard reflect/expand/
//! contract/shrink rules treat as "worst", steering the simplex back
//! into the feasible region — the same trick Orio uses for its
//! discrete-domain Nelder–Mead.

use super::{Budget, SearchResult, SearchStrategy};
use crate::coordinator::spec::{Config, TuningSpec};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
/// Nelder–Mead simplex search adapted to the discrete index lattice.
pub struct NelderMead {
    seed: u64,
    /// Reflection / expansion / contraction / shrink coefficients.
    alpha: f64,
    gamma: f64,
    rho: f64,
    sigma: f64,
    max_restarts: usize,
}

impl NelderMead {
    /// A simplex search with the given seed.
    pub fn new(seed: u64) -> NelderMead {
        NelderMead { seed, alpha: 1.0, gamma: 2.0, rho: 0.5, sigma: 0.5, max_restarts: 4 }
    }

    fn round_to_config(spec: &TuningSpec, point: &[f64]) -> Config {
        let idx: Vec<usize> = spec
            .params
            .iter()
            .zip(point)
            .map(|(p, &x)| {
                let max = (p.values.len() - 1) as f64;
                x.clamp(0.0, max).round() as usize
            })
            .collect();
        spec.config_at(&idx)
    }
}

impl SearchStrategy for NelderMead {
    fn name(&self) -> &'static str {
        "neldermead"
    }

    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult {
        let dim = spec.params.len();
        if dim == 0 {
            return SearchResult { best: None, history: Vec::new() };
        }
        let total_valid = spec.enumerate().len();
        let mut rng = Rng::new(self.seed);
        let mut b = Budget::new(spec, budget, eval);

        // Evaluate a continuous point (rounded); invalid configs -> +inf.
        // Returns None only when the budget is gone.
        let probe = |b: &mut Budget, point: &[f64]| -> Option<f64> {
            let config = Self::round_to_config(spec, point);
            if !spec.is_valid(&config) {
                return Some(f64::INFINITY);
            }
            b.eval(&config)
        };

        'restarts: for _ in 0..self.max_restarts {
            // Initial simplex: a random valid vertex + unit steps.
            let Some(start) = spec.random_config(&mut rng, 256) else { break };
            let start_idx: Vec<f64> = spec
                .index_of(&start)
                .unwrap()
                .into_iter()
                .map(|i| i as f64)
                .collect();
            let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(dim + 1);
            let Some(c0) = probe(&mut b, &start_idx) else { break };
            simplex.push((start_idx.clone(), c0));
            for d in 0..dim {
                let mut v = start_idx.clone();
                let max = (spec.params[d].values.len() - 1) as f64;
                v[d] = if v[d] + 1.0 <= max { v[d] + 1.0 } else { (v[d] - 1.0).max(0.0) };
                let Some(c) = probe(&mut b, &v) else { break 'restarts };
                simplex.push((v, c));
            }

            for _iter in 0..64 {
                if b.exhausted() || b.space_exhausted(total_valid) {
                    break 'restarts;
                }
                simplex.sort_by(|a, bb| a.1.total_cmp(&bb.1));
                let worst = simplex[dim].clone();
                let second_worst = simplex[dim - 1].1;
                let best_cost = simplex[0].1;

                // Centroid of all but the worst.
                let centroid: Vec<f64> = (0..dim)
                    .map(|d| simplex[..dim].iter().map(|(v, _)| v[d]).sum::<f64>() / dim as f64)
                    .collect();

                let lerp = |t: f64| -> Vec<f64> {
                    (0..dim)
                        .map(|d| centroid[d] + t * (centroid[d] - worst.0[d]))
                        .collect()
                };

                // Reflect.
                let xr = lerp(self.alpha);
                let Some(cr) = probe(&mut b, &xr) else { break 'restarts };
                if cr < best_cost {
                    // Expand.
                    let xe = lerp(self.gamma);
                    let Some(ce) = probe(&mut b, &xe) else { break 'restarts };
                    simplex[dim] = if ce < cr { (xe, ce) } else { (xr, cr) };
                    continue;
                }
                if cr < second_worst {
                    simplex[dim] = (xr, cr);
                    continue;
                }
                // Contract (inside).
                let xc = lerp(-self.rho);
                let Some(cc) = probe(&mut b, &xc) else { break 'restarts };
                if cc < worst.1 {
                    simplex[dim] = (xc, cc);
                    continue;
                }
                // Shrink toward the best vertex.
                let best_v = simplex[0].0.clone();
                let mut converged = true;
                for item in simplex.iter_mut().skip(1) {
                    let nv: Vec<f64> = (0..dim)
                        .map(|d| best_v[d] + self.sigma * (item.0[d] - best_v[d]))
                        .collect();
                    if Self::round_to_config(spec, &nv) != Self::round_to_config(spec, &item.0) {
                        converged = false;
                    }
                    let Some(nc) = probe(&mut b, &nv) else { break 'restarts };
                    *item = (nv, nc);
                }
                if converged {
                    break; // simplex collapsed to one cell -> restart
                }
            }
        }
        b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn converges_on_bowl() {
        let mut s = NelderMead::new(5);
        let r = run_on_bowl(&mut s, usize::MAX);
        let (_, cost) = r.best.unwrap();
        // The bowl optimum is 1.0; NM on a 2-D discrete bowl should land
        // on it (or the immediately adjacent cell at 1.5).
        assert!(cost <= 1.5, "NM best {cost}");
    }

    #[test]
    fn respects_budget() {
        let mut s = NelderMead::new(9);
        let r = run_on_bowl(&mut s, 6);
        assert!(r.evaluations() <= 6);
        assert!(r.best.is_some());
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = bowl_spec();
        let ids = |r: &SearchResult| {
            r.history.iter().map(|e| spec.config_id(&e.config)).collect::<Vec<_>>()
        };
        let r1 = run_on_bowl(&mut NelderMead::new(3), 15);
        let r2 = run_on_bowl(&mut NelderMead::new(3), 15);
        assert_eq!(ids(&r1), ids(&r2));
    }

    #[test]
    fn handles_infeasible_probes() {
        // Constrain half the bowl away; NM must still return a valid best.
        let spec = bowl_spec();
        let mut eval = {
            let spec = spec.clone();
            move |c: &Config| bowl_cost(&spec, c)
        };
        let mut s = NelderMead::new(21);
        let r = s.run(&spec, usize::MAX, &mut eval);
        let (best, _) = r.best.unwrap();
        assert!(spec.is_valid(&best));
    }
}
