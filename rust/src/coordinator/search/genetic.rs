//! Genetic algorithm over parameter index vectors.
//!
//! Individuals are per-parameter domain indices; tournament selection,
//! uniform crossover, and per-gene mutation, with elitism.  Invalid
//! children (constraint violations) are repaired by re-sampling the
//! offending genes; irreparable ones are replaced by random valid
//! configs so the population never collapses.

use super::{Budget, SearchResult, SearchStrategy};
use crate::coordinator::spec::{Config, TuningSpec};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
/// Generational genetic algorithm (tournament selection, uniform
/// crossover, per-parameter mutation; seeded).
pub struct Genetic {
    seed: u64,
    pop_size: usize,
    mutation_rate: f64,
    tournament: usize,
    // Batch-mode (ask/tell) state: whole generations surface as batches.
    rng: Option<Rng>,
    pop: Vec<(Vec<usize>, f64)>,
    round: Vec<(Config, f64)>,
}

impl Genetic {
    /// A GA with the default population and mutation rate.
    pub fn new(seed: u64) -> Genetic {
        Genetic::with_params(seed, 8, 0.25)
    }

    /// A GA with explicit population size and mutation rate.
    pub fn with_params(seed: u64, pop_size: usize, mutation_rate: f64) -> Genetic {
        assert!(pop_size >= 2, "population must be >= 2");
        assert!((0.0..=1.0).contains(&mutation_rate), "mutation_rate in [0,1]");
        Genetic {
            seed,
            pop_size,
            mutation_rate,
            tournament: 3,
            rng: None,
            pop: Vec::new(),
            round: Vec::new(),
        }
    }

    /// Fold the last round's observations into the population
    /// (steady-state replacement, same rule as sequential mode).
    fn absorb_round(&mut self, spec: &TuningSpec) {
        for (config, cost) in std::mem::take(&mut self.round) {
            let Some(idx) = spec.index_of(&config) else { continue };
            if self.pop.len() < self.pop_size {
                self.pop.push((idx, cost));
                continue;
            }
            let worst = self
                .pop
                .iter()
                .enumerate()
                .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
                .map(|(i, _)| i)
                .unwrap();
            if cost <= self.pop[worst].1 {
                self.pop[worst] = (idx, cost);
            }
        }
    }

    fn random_individual(spec: &TuningSpec, rng: &mut Rng) -> Option<Vec<usize>> {
        spec.random_config(rng, 256)
            .and_then(|c| spec.index_of(&c))
    }

    fn repair(
        spec: &TuningSpec,
        rng: &mut Rng,
        mut idx: Vec<usize>,
    ) -> Option<Vec<usize>> {
        for _ in 0..32 {
            let config = spec.config_at(&idx);
            if spec.is_valid(&config) {
                return Some(idx);
            }
            // Re-sample one random gene.
            let g = rng.gen_range(idx.len());
            idx[g] = rng.gen_range(spec.params[g].values.len());
        }
        None
    }
}

impl SearchStrategy for Genetic {
    fn name(&self) -> &'static str {
        "genetic"
    }

    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult {
        if spec.params.is_empty() {
            return SearchResult { best: None, history: Vec::new() };
        }
        let mut rng = Rng::new(self.seed);
        let total_valid = spec.enumerate().len();
        let mut b = Budget::new(spec, budget, eval);

        // Initial population.
        let mut pop: Vec<(Vec<usize>, f64)> = Vec::new();
        while pop.len() < self.pop_size {
            let Some(ind) = Self::random_individual(spec, &mut rng) else { break };
            let config = spec.config_at(&ind);
            let Some(cost) = b.eval(&config) else { break };
            pop.push((ind, cost));
        }
        if pop.is_empty() {
            return b.finish();
        }

        while !b.exhausted() && !b.space_exhausted(total_valid) {
            // Tournament selection of two parents.
            let select = |rng: &mut Rng, pop: &[(Vec<usize>, f64)]| -> Vec<usize> {
                let mut best: Option<(usize, f64)> = None;
                for _ in 0..self.tournament {
                    let i = rng.gen_range(pop.len());
                    if best.map_or(true, |(_, c)| pop[i].1 < c) {
                        best = Some((i, pop[i].1));
                    }
                }
                pop[best.unwrap().0].0.clone()
            };
            let pa = select(&mut rng, &pop);
            let pb = select(&mut rng, &pop);

            // Uniform crossover + mutation.
            let mut child: Vec<usize> = pa
                .iter()
                .zip(&pb)
                .map(|(&x, &y)| if rng.next_f64() < 0.5 { x } else { y })
                .collect();
            for (g, p) in spec.params.iter().enumerate() {
                if rng.next_f64() < self.mutation_rate {
                    child[g] = rng.gen_range(p.values.len());
                }
            }

            let Some(child) = Self::repair(spec, &mut rng, child)
                .or_else(|| Self::random_individual(spec, &mut rng))
            else {
                break;
            };
            let config = spec.config_at(&child);
            let Some(cost) = b.eval(&config) else { break };

            // Steady-state replacement: replace the worst individual if
            // the child is no worse (elitism is implicit — the best
            // individual is never the replacement target unless the
            // child beats it).
            let worst = pop
                .iter()
                .enumerate()
                .max_by(|a, b| a.1 .1.total_cmp(&b.1 .1))
                .map(|(i, _)| i)
                .unwrap();
            if cost <= pop[worst].1 {
                pop[worst] = (child, cost);
            }
        }
        b.finish()
    }

    fn supports_batch(&self) -> bool {
        true
    }

    /// One generation per call: `k` random individuals while the
    /// population is filling, then `k` bred children (tournament
    /// selection, uniform crossover, mutation, constraint repair).
    fn suggest(
        &mut self,
        spec: &TuningSpec,
        k: usize,
        _seen: &dyn Fn(&Config) -> bool,
    ) -> Vec<Config> {
        if spec.params.is_empty() {
            return Vec::new();
        }
        let seed = self.seed;
        let mut rng = self.rng.take().unwrap_or_else(|| Rng::new(seed));
        self.absorb_round(spec);
        let want = k.max(1);
        let mut out: Vec<Config> = Vec::new();

        if self.pop.len() < self.pop_size {
            let mut ids: Vec<String> = Vec::new();
            for _ in 0..want * 16 {
                if out.len() >= want {
                    break;
                }
                let Some(ind) = Self::random_individual(spec, &mut rng) else { break };
                let config = spec.config_at(&ind);
                let id = spec.config_id(&config);
                if !ids.contains(&id) {
                    ids.push(id);
                    out.push(config);
                }
            }
        } else {
            for _ in 0..want {
                let select = |rng: &mut Rng, pop: &[(Vec<usize>, f64)]| -> Vec<usize> {
                    let mut best: Option<(usize, f64)> = None;
                    for _ in 0..self.tournament {
                        let i = rng.gen_range(pop.len());
                        if best.map_or(true, |(_, c)| pop[i].1 < c) {
                            best = Some((i, pop[i].1));
                        }
                    }
                    pop[best.unwrap().0].0.clone()
                };
                let pa = select(&mut rng, &self.pop);
                let pb = select(&mut rng, &self.pop);
                let mut child: Vec<usize> = pa
                    .iter()
                    .zip(&pb)
                    .map(|(&x, &y)| if rng.next_f64() < 0.5 { x } else { y })
                    .collect();
                for (g, p) in spec.params.iter().enumerate() {
                    if rng.next_f64() < self.mutation_rate {
                        child[g] = rng.gen_range(p.values.len());
                    }
                }
                if let Some(child) = Self::repair(spec, &mut rng, child)
                    .or_else(|| Self::random_individual(spec, &mut rng))
                {
                    out.push(spec.config_at(&child));
                }
            }
        }
        self.rng = Some(rng);
        out
    }

    fn observe(&mut self, _spec: &TuningSpec, config: &Config, cost: f64) {
        self.round.push((config.clone(), cost));
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn finds_optimum_with_full_budget() {
        let mut s = Genetic::new(13);
        let r = run_on_bowl(&mut s, usize::MAX);
        assert_eq!(r.best.unwrap().1, 1.0);
    }

    #[test]
    fn near_optimal_with_half_budget() {
        let spec = bowl_spec();
        let full = spec.enumerate().len();
        let mut s = Genetic::new(29);
        let r = run_on_bowl(&mut s, full / 2);
        let (_, cost) = r.best.unwrap();
        assert!(cost <= 3.0, "genetic best {cost} too far from optimum");
    }

    #[test]
    fn respects_budget() {
        let mut s = Genetic::new(2);
        let r = run_on_bowl(&mut s, 7);
        assert!(r.evaluations() <= 7);
    }

    #[test]
    fn children_always_valid() {
        let spec = bowl_spec();
        let mut s = Genetic::new(37);
        let mut eval = {
            let spec = spec.clone();
            move |c: &Config| {
                assert!(spec.is_valid(c), "GA evaluated invalid config {c:?}");
                bowl_cost(&spec, c)
            }
        };
        s.run(&spec, 40, &mut eval);
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = bowl_spec();
        let ids = |r: &SearchResult| {
            r.history.iter().map(|e| spec.config_id(&e.config)).collect::<Vec<_>>()
        };
        let r1 = run_on_bowl(&mut Genetic::new(19), 20);
        let r2 = run_on_bowl(&mut Genetic::new(19), 20);
        assert_eq!(ids(&r1), ids(&r2));
    }

    #[test]
    #[should_panic]
    fn tiny_population_panics() {
        Genetic::with_params(1, 1, 0.2);
    }

    #[test]
    fn batch_mode_respects_budget_and_validity() {
        use super::super::drive_batched;
        let spec = bowl_spec();
        let mut s = Genetic::new(13);
        let mut eval = |batch: &[Config]| -> Vec<f64> {
            let spec = bowl_spec();
            batch
                .iter()
                .map(|c| {
                    assert!(spec.is_valid(c), "GA suggested invalid config {c:?}");
                    bowl_cost(&spec, c)
                })
                .collect()
        };
        let r = drive_batched(&mut s, &spec, 20, 8, &[], &mut eval);
        assert!(r.evaluations() <= 20);
        assert!(r.best.is_some());
    }
}
