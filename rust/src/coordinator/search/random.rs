//! Uniform random sampling without replacement.
//!
//! The classic autotuning baseline: shuffle the valid space with a seeded
//! Fisher-Yates and evaluate a prefix.  Sampling *without* replacement
//! matters — with spaces of 10–50 points and budgets of similar order,
//! with-replacement sampling wastes a large fraction of the budget on
//! repeats.

use super::{Budget, SearchResult, SearchStrategy};
use crate::coordinator::spec::{Config, TuningSpec};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct RandomSearch {
    seed: u64,
}

impl RandomSearch {
    pub fn new(seed: u64) -> RandomSearch {
        RandomSearch { seed }
    }
}

impl SearchStrategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult {
        let mut rng = Rng::new(self.seed);
        let mut configs = spec.enumerate();
        rng.shuffle(&mut configs);
        let mut b = Budget::new(spec, budget, eval);
        for config in configs {
            if b.eval(&config).is_none() {
                break;
            }
        }
        b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn respects_budget_without_repeats() {
        let mut s = RandomSearch::new(11);
        let r = run_on_bowl(&mut s, 8);
        assert_eq!(r.evaluations(), 8);
        let spec = bowl_spec();
        let ids: Vec<String> =
            r.history.iter().map(|e| spec.config_id(&e.config)).collect();
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len());
    }

    #[test]
    fn full_budget_finds_optimum() {
        let mut s = RandomSearch::new(7);
        let r = run_on_bowl(&mut s, usize::MAX);
        assert_eq!(r.best.unwrap().1, 1.0);
    }

    #[test]
    fn seeded_replay_is_identical() {
        let r1 = run_on_bowl(&mut RandomSearch::new(5), 10);
        let r2 = run_on_bowl(&mut RandomSearch::new(5), 10);
        let spec = bowl_spec();
        let ids = |r: &super::SearchResult| {
            r.history.iter().map(|e| spec.config_id(&e.config)).collect::<Vec<_>>()
        };
        assert_eq!(ids(&r1), ids(&r2));
    }

    #[test]
    fn different_seeds_differ() {
        let spec = bowl_spec();
        let r1 = run_on_bowl(&mut RandomSearch::new(1), 10);
        let r2 = run_on_bowl(&mut RandomSearch::new(2), 10);
        let ids = |r: &super::SearchResult| {
            r.history.iter().map(|e| spec.config_id(&e.config)).collect::<Vec<_>>()
        };
        assert_ne!(ids(&r1), ids(&r2));
    }
}
