//! Uniform random sampling without replacement.
//!
//! The classic autotuning baseline: shuffle the valid space with a seeded
//! Fisher-Yates and evaluate a prefix.  Sampling *without* replacement
//! matters — with spaces of 10–50 points and budgets of similar order,
//! with-replacement sampling wastes a large fraction of the budget on
//! repeats.

use super::{Budget, SearchResult, SearchStrategy};
use crate::coordinator::spec::{Config, TuningSpec};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
/// Uniform random sampling of valid configs (seeded).
pub struct RandomSearch {
    seed: u64,
    /// Batch-mode state: the seeded shuffle, materialized once.
    plan: Option<Vec<Config>>,
    cursor: usize,
}

impl RandomSearch {
    /// A sampler with the given seed.
    pub fn new(seed: u64) -> RandomSearch {
        RandomSearch { seed, plan: None, cursor: 0 }
    }
}

impl SearchStrategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult {
        let mut rng = Rng::new(self.seed);
        let mut configs = spec.enumerate();
        rng.shuffle(&mut configs);
        let mut b = Budget::new(spec, budget, eval);
        for config in configs {
            if b.eval(&config).is_none() {
                break;
            }
        }
        b.finish()
    }

    fn supports_batch(&self) -> bool {
        true
    }

    /// The next `k` configs of the seeded without-replacement shuffle —
    /// identical sampling plan as `run`, surfaced batch-wise.
    fn suggest(
        &mut self,
        spec: &TuningSpec,
        k: usize,
        _seen: &dyn Fn(&Config) -> bool,
    ) -> Vec<Config> {
        let plan = self.plan.get_or_insert_with(|| {
            let mut rng = Rng::new(self.seed);
            let mut configs = spec.enumerate();
            rng.shuffle(&mut configs);
            configs
        });
        let batch: Vec<Config> = plan.iter().skip(self.cursor).take(k.max(1)).cloned().collect();
        self.cursor += batch.len();
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn respects_budget_without_repeats() {
        let mut s = RandomSearch::new(11);
        let r = run_on_bowl(&mut s, 8);
        assert_eq!(r.evaluations(), 8);
        let spec = bowl_spec();
        let ids: Vec<String> =
            r.history.iter().map(|e| spec.config_id(&e.config)).collect();
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len());
    }

    #[test]
    fn full_budget_finds_optimum() {
        let mut s = RandomSearch::new(7);
        let r = run_on_bowl(&mut s, usize::MAX);
        assert_eq!(r.best.unwrap().1, 1.0);
    }

    #[test]
    fn seeded_replay_is_identical() {
        let r1 = run_on_bowl(&mut RandomSearch::new(5), 10);
        let r2 = run_on_bowl(&mut RandomSearch::new(5), 10);
        let spec = bowl_spec();
        let ids = |r: &super::SearchResult| {
            r.history.iter().map(|e| spec.config_id(&e.config)).collect::<Vec<_>>()
        };
        assert_eq!(ids(&r1), ids(&r2));
    }

    #[test]
    fn batch_plan_matches_sequential_order() {
        let spec = bowl_spec();
        let r = run_on_bowl(&mut RandomSearch::new(5), usize::MAX);
        let seq: Vec<String> =
            r.history.iter().map(|e| spec.config_id(&e.config)).collect();
        let mut s = RandomSearch::new(5);
        let mut bat: Vec<String> = Vec::new();
        loop {
            let b = s.suggest(&spec, 7, &|_| false);
            if b.is_empty() {
                break;
            }
            bat.extend(b.iter().map(|c| spec.config_id(c)));
        }
        assert_eq!(seq, bat, "batch mode must replay the same sampling plan");
    }

    #[test]
    fn different_seeds_differ() {
        let spec = bowl_spec();
        let r1 = run_on_bowl(&mut RandomSearch::new(1), 10);
        let r2 = run_on_bowl(&mut RandomSearch::new(2), 10);
        let ids = |r: &super::SearchResult| {
            r.history.iter().map(|e| spec.config_id(&e.config)).collect::<Vec<_>>()
        };
        assert_ne!(ids(&r1), ids(&r2));
    }
}
