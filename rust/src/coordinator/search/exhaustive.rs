//! Exhaustive sweep: evaluate every valid configuration (or as many as
//! the budget allows), in deterministic enumeration order.
//!
//! This is the ground-truth strategy — Figure 1's "autotuned" series is
//! produced with it, and the ablation bench scores every other strategy
//! against its optimum.

use super::{Budget, SearchResult, SearchStrategy};
use crate::coordinator::spec::{Config, TuningSpec};

#[derive(Debug, Default, Clone)]
/// Deterministic full-space sweep in enumeration order.
pub struct Exhaustive {
    /// Batch-mode state: the enumeration, materialized once.
    plan: Option<Vec<Config>>,
    cursor: usize,
}

impl Exhaustive {
    /// A fresh sweep.
    pub fn new() -> Exhaustive {
        Exhaustive::default()
    }
}

impl SearchStrategy for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult {
        let mut b = Budget::new(spec, budget, eval);
        for config in spec.enumerate() {
            if b.eval(&config).is_none() {
                break;
            }
        }
        b.finish()
    }

    fn supports_batch(&self) -> bool {
        true
    }

    /// The next `k` configs in enumeration order — the whole sweep
    /// surfaces as ready-made batches for compile prefetch and racing.
    fn suggest(
        &mut self,
        spec: &TuningSpec,
        k: usize,
        _seen: &dyn Fn(&Config) -> bool,
    ) -> Vec<Config> {
        let plan = self.plan.get_or_insert_with(|| spec.enumerate());
        let batch: Vec<Config> =
            plan.iter().skip(self.cursor).take(k.max(1)).cloned().collect();
        self.cursor += batch.len();
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn finds_global_optimum() {
        let mut s = Exhaustive::new();
        let r = run_on_bowl(&mut s, usize::MAX);
        let (best, cost) = r.best.unwrap();
        assert_eq!(best["block_size"], 1024);
        assert_eq!(best["unroll"], 4);
        assert_eq!(cost, 1.0);
    }

    #[test]
    fn covers_entire_valid_space() {
        let spec = bowl_spec();
        let mut s = Exhaustive::new();
        let r = run_on_bowl(&mut s, usize::MAX);
        assert_eq!(r.evaluations(), spec.enumerate().len());
    }

    #[test]
    fn respects_budget() {
        let mut s = Exhaustive::new();
        let r = run_on_bowl(&mut s, 5);
        assert_eq!(r.evaluations(), 5);
    }

    #[test]
    fn batch_suggestions_walk_enumeration_order() {
        let spec = bowl_spec();
        let all = spec.enumerate();
        let mut s = Exhaustive::new();
        assert!(s.supports_batch());
        let b1 = s.suggest(&spec, 4, &|_| false);
        let b2 = s.suggest(&spec, 4, &|_| false);
        assert_eq!(b1.as_slice(), &all[0..4]);
        assert_eq!(b2.as_slice(), &all[4..8]);
        // Drains to empty at the end of the space.
        let mut total = b1.len() + b2.len();
        loop {
            let b = s.suggest(&spec, 64, &|_| false);
            if b.is_empty() {
                break;
            }
            total += b.len();
        }
        assert_eq!(total, all.len());
    }

    #[test]
    fn deterministic_history() {
        let mut s1 = Exhaustive::new();
        let mut s2 = Exhaustive::new();
        let r1 = run_on_bowl(&mut s1, 10);
        let r2 = run_on_bowl(&mut s2, 10);
        let ids1: Vec<_> = r1.history.iter().map(|e| format!("{:?}", e.config)).collect();
        let ids2: Vec<_> = r2.history.iter().map(|e| format!("{:?}", e.config)).collect();
        assert_eq!(ids1, ids2);
    }
}
