//! Exhaustive sweep: evaluate every valid configuration (or as many as
//! the budget allows), in deterministic enumeration order.
//!
//! This is the ground-truth strategy — Figure 1's "autotuned" series is
//! produced with it, and the ablation bench scores every other strategy
//! against its optimum.

use super::{Budget, SearchResult, SearchStrategy};
use crate::coordinator::spec::{Config, TuningSpec};

#[derive(Debug, Default, Clone)]
pub struct Exhaustive;

impl Exhaustive {
    pub fn new() -> Exhaustive {
        Exhaustive
    }
}

impl SearchStrategy for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult {
        let mut b = Budget::new(spec, budget, eval);
        for config in spec.enumerate() {
            if b.eval(&config).is_none() {
                break;
            }
        }
        b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn finds_global_optimum() {
        let mut s = Exhaustive::new();
        let r = run_on_bowl(&mut s, usize::MAX);
        let (best, cost) = r.best.unwrap();
        assert_eq!(best["block_size"], 1024);
        assert_eq!(best["unroll"], 4);
        assert_eq!(cost, 1.0);
    }

    #[test]
    fn covers_entire_valid_space() {
        let spec = bowl_spec();
        let mut s = Exhaustive::new();
        let r = run_on_bowl(&mut s, usize::MAX);
        assert_eq!(r.evaluations(), spec.enumerate().len());
    }

    #[test]
    fn respects_budget() {
        let mut s = Exhaustive::new();
        let r = run_on_bowl(&mut s, 5);
        assert_eq!(r.evaluations(), 5);
    }

    #[test]
    fn deterministic_history() {
        let mut s1 = Exhaustive::new();
        let mut s2 = Exhaustive::new();
        let r1 = run_on_bowl(&mut s1, 10);
        let r2 = run_on_bowl(&mut s2, 10);
        let ids1: Vec<_> = r1.history.iter().map(|e| format!("{:?}", e.config)).collect();
        let ids2: Vec<_> = r2.history.iter().map(|e| format!("{:?}", e.config)).collect();
        assert_eq!(ids1, ids2);
    }
}
