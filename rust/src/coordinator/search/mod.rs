//! Empirical search strategies over the variant space.
//!
//! This is the strategy set Orio ships (the paper's §2 "depending on the
//! number of parameter variations ... a number of resulting code variants
//! are compared"): exhaustive sweep, uniform random sampling, greedy
//! hill-climbing with restarts, simulated annealing, and a genetic
//! algorithm.  Every strategy operates through [`Budget`], which dedupes
//! repeated configurations (an evaluation = one compile+measure cycle, the
//! expensive unit the budget must bound) and records the full history for
//! the ablation benches.
//!
//! Costs are wall-clock seconds (lower is better); `f64::INFINITY` marks
//! a variant that failed its correctness gate or crashed, which every
//! strategy treats as "never select, never move to".

mod anneal;
mod exhaustive;
mod genetic;
mod hillclimb;
mod random;
mod simplex;

pub use anneal::Anneal;
pub use exhaustive::Exhaustive;
pub use genetic::Genetic;
pub use hillclimb::HillClimb;
pub use random::RandomSearch;
pub use simplex::NelderMead;

use std::collections::HashMap;

use super::spec::{Config, TuningSpec};

/// One recorded (config, cost) evaluation, in evaluation order.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub config: Config,
    pub cost: f64,
}

/// Outcome of a search run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Best finite-cost config found, if any.
    pub best: Option<(Config, f64)>,
    /// Unique evaluations in the order they were first performed.
    pub history: Vec<Evaluation>,
}

impl SearchResult {
    pub fn evaluations(&self) -> usize {
        self.history.len()
    }

    /// Cost trajectory: best-so-far after each evaluation (for the
    /// convergence series in the ablation bench).
    pub fn best_so_far(&self) -> Vec<f64> {
        let mut best = f64::INFINITY;
        self.history
            .iter()
            .map(|e| {
                if e.cost < best {
                    best = e.cost;
                }
                best
            })
            .collect()
    }
}

/// A search strategy: explore `spec` within `budget` unique evaluations.
pub trait SearchStrategy {
    fn name(&self) -> &'static str;

    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult;
}

/// Budget-enforcing, deduplicating evaluation wrapper shared by all
/// strategies.
pub(crate) struct Budget<'a, 'b> {
    spec: &'a TuningSpec,
    remaining: usize,
    cache: HashMap<String, f64>,
    history: Vec<Evaluation>,
    best: Option<(Config, f64)>,
    eval: &'a mut dyn FnMut(&Config) -> f64,
    _marker: std::marker::PhantomData<&'b ()>,
}

impl<'a, 'b> Budget<'a, 'b> {
    pub(crate) fn new(
        spec: &'a TuningSpec,
        budget: usize,
        eval: &'a mut dyn FnMut(&Config) -> f64,
    ) -> Self {
        Budget {
            spec,
            remaining: budget,
            cache: HashMap::new(),
            history: Vec::new(),
            best: None,
            eval,
            _marker: std::marker::PhantomData,
        }
    }

    /// Evaluate a config.  Cached repeats are free; new evaluations
    /// consume budget.  Returns `None` when the budget is exhausted.
    pub(crate) fn eval(&mut self, config: &Config) -> Option<f64> {
        let id = self.spec.config_id(config);
        if let Some(&c) = self.cache.get(&id) {
            return Some(c);
        }
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let cost = (self.eval)(config);
        self.cache.insert(id, cost);
        self.history.push(Evaluation { config: config.clone(), cost });
        if cost.is_finite() {
            match &self.best {
                Some((_, b)) if *b <= cost => {}
                _ => self.best = Some((config.clone(), cost)),
            }
        }
        Some(cost)
    }

    pub(crate) fn exhausted(&self) -> bool {
        self.remaining == 0
    }

    /// True once every valid config has been evaluated — iterative
    /// strategies must stop then even with budget left, or they would
    /// spin forever on cached repeats.
    pub(crate) fn space_exhausted(&self, total_valid: usize) -> bool {
        self.cache.len() >= total_valid
    }

    pub(crate) fn seen(&self, config: &Config) -> bool {
        self.cache.contains_key(&self.spec.config_id(config))
    }

    #[cfg(test)]
    pub(crate) fn unique_evals(&self) -> usize {
        self.history.len()
    }

    pub(crate) fn finish(self) -> SearchResult {
        SearchResult { best: self.best, history: self.history }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::runtime::registry::ParamDef;

    /// A deterministic synthetic cost surface: quadratic bowl over the
    /// parameter indices with a known global optimum, so strategy tests
    /// can assert quality without a PJRT runtime.
    pub fn bowl_spec() -> TuningSpec {
        TuningSpec::new(
            "bowl",
            "t",
            vec![
                ParamDef {
                    name: "block_size".into(),
                    abbrev: "b".into(),
                    values: vec![64, 128, 256, 512, 1024, 2048, 4096, 8192],
                },
                ParamDef {
                    name: "unroll".into(),
                    abbrev: "u".into(),
                    values: vec![1, 2, 4, 8],
                },
            ],
            &["block_size % unroll == 0".to_string()],
            [("n".to_string(), 1 << 20)].into_iter().collect(),
        )
        .unwrap()
    }

    /// Optimum at block_size=1024 (index 4), unroll=4 (index 2).
    pub fn bowl_cost(spec: &TuningSpec, c: &Config) -> f64 {
        let idx = spec.index_of(c).expect("in-domain");
        let db = idx[0] as f64 - 4.0;
        let du = idx[1] as f64 - 2.0;
        1.0 + db * db + 0.5 * du * du
    }

    pub fn run_on_bowl(strategy: &mut dyn SearchStrategy, budget: usize) -> SearchResult {
        let spec = bowl_spec();
        let mut eval = {
            let spec = spec.clone();
            move |c: &Config| bowl_cost(&spec, c)
        };
        strategy.run(&spec, budget, &mut eval)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn budget_dedupes_and_bounds() {
        let spec = bowl_spec();
        let mut calls = 0usize;
        let mut eval = |c: &Config| {
            calls += 1;
            bowl_cost(&bowl_spec(), c)
        };
        let mut b = Budget::new(&spec, 3, &mut eval);
        let cfgs = spec.enumerate();
        assert!(b.eval(&cfgs[0]).is_some());
        assert!(b.eval(&cfgs[0]).is_some()); // cached, free
        assert!(b.eval(&cfgs[1]).is_some());
        assert!(b.eval(&cfgs[2]).is_some());
        assert!(b.eval(&cfgs[3]).is_none()); // budget exhausted
        let r = b.finish();
        assert_eq!(calls, 3);
        assert_eq!(r.evaluations(), 3);
    }

    #[test]
    fn budget_tracks_best_finite_only() {
        let spec = bowl_spec();
        let mut eval = |c: &Config| {
            if c["unroll"] == 1 {
                f64::INFINITY
            } else {
                bowl_cost(&bowl_spec(), c)
            }
        };
        let mut b = Budget::new(&spec, usize::MAX, &mut eval);
        for c in spec.enumerate() {
            b.eval(&c);
        }
        let r = b.finish();
        let (best, _) = r.best.unwrap();
        assert_ne!(best["unroll"], 1);
    }

    #[test]
    fn best_so_far_is_monotone() {
        let mut s = Exhaustive::new();
        let r = run_on_bowl(&mut s, 20);
        let traj = r.best_so_far();
        assert!(traj.windows(2).all(|w| w[1] <= w[0]));
    }
}
