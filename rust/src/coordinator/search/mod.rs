//! Empirical search strategies over the variant space.
//!
//! This is the strategy set Orio ships (the paper's §2 "depending on the
//! number of parameter variations ... a number of resulting code variants
//! are compared"): exhaustive sweep, uniform random sampling, greedy
//! hill-climbing with restarts, simulated annealing, and a genetic
//! algorithm.  Every strategy operates through the crate-internal
//! `Budget` wrapper, which dedupes
//! repeated configurations (an evaluation = one compile+measure cycle, the
//! expensive unit the budget must bound) and records the full history for
//! the ablation benches.
//!
//! Costs are wall-clock seconds (lower is better); `f64::INFINITY` marks
//! a variant that failed its correctness gate or crashed, which every
//! strategy treats as "never select, never move to".

mod anneal;
mod exhaustive;
mod genetic;
mod hillclimb;
mod random;
mod simplex;

pub use anneal::Anneal;
pub use exhaustive::Exhaustive;
pub use genetic::Genetic;
pub use hillclimb::HillClimb;
pub use random::RandomSearch;
pub use simplex::NelderMead;

use std::collections::HashMap;

use super::spec::{Config, TuningSpec};

/// One recorded (config, cost) evaluation, in evaluation order.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// The evaluated parameter assignment.
    pub config: Config,
    /// Observed cost (seconds; +inf = gated/failed).
    pub cost: f64,
}

/// Outcome of a search run.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Best finite-cost config found, if any.
    pub best: Option<(Config, f64)>,
    /// Unique evaluations in the order they were first performed.
    pub history: Vec<Evaluation>,
}

impl SearchResult {
    /// Number of unique evaluations performed.
    pub fn evaluations(&self) -> usize {
        self.history.len()
    }

    /// Cost trajectory: best-so-far after each evaluation (for the
    /// convergence series in the ablation bench).
    pub fn best_so_far(&self) -> Vec<f64> {
        let mut best = f64::INFINITY;
        self.history
            .iter()
            .map(|e| {
                if e.cost < best {
                    best = e.cost;
                }
                best
            })
            .collect()
    }
}

/// A search strategy: explore `spec` within `budget` unique evaluations.
///
/// Two driving modes:
/// * **Sequential** ([`run`](SearchStrategy::run)) — the strategy owns
///   the loop and calls `eval` one configuration at a time.  Every
///   strategy implements this.
/// * **Batched** ([`suggest`](SearchStrategy::suggest) /
///   [`observe`](SearchStrategy::observe)) — the *driver* owns the loop:
///   it asks for a batch of candidates, evaluates them together
///   (overlapping compilation, racing measurements), and tells the
///   strategy every observed cost.  Strategies whose structure is
///   naturally generational (exhaustive order, random plans, GA
///   generations, hill-climb neighborhoods) override these and report
///   [`supports_batch`](SearchStrategy::supports_batch) = true;
///   inherently sequential strategies (annealing, Nelder–Mead) keep the
///   default single-candidate implementation and are driven through
///   `run` instead.
pub trait SearchStrategy {
    /// Stable strategy name (CLI spelling, DB `strategy` field).
    fn name(&self) -> &'static str;

    /// Sequential drive: explore `spec` within `budget` unique
    /// evaluations, calling `eval` one configuration at a time.
    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult;

    /// Does this strategy surface meaningful multi-candidate batches?
    /// The batched tuning pipeline only engages when this is true —
    /// sequential strategies would silently degrade to enumeration
    /// order under the default `suggest`.
    fn supports_batch(&self) -> bool {
        false
    }

    /// Propose up to `k` candidates for the next evaluation round.
    /// `seen` answers "has the driver already evaluated this config?"
    /// so stateless implementations can avoid re-proposing.  Returning
    /// an empty batch ends the search.
    ///
    /// Default: the next single unseen configuration in deterministic
    /// enumeration order (correct for any strategy, sequential in
    /// spirit).
    fn suggest(
        &mut self,
        spec: &TuningSpec,
        k: usize,
        seen: &dyn Fn(&Config) -> bool,
    ) -> Vec<Config> {
        let _ = k;
        spec.enumerate().into_iter().find(|c| !seen(c)).into_iter().collect()
    }

    /// Feed one observed cost back (ask/tell).  Called for every member
    /// of a suggested batch — freshly measured, served from the
    /// driver's cache, or `f64::INFINITY` for invalid/failed configs.
    fn observe(&mut self, spec: &TuningSpec, config: &Config, cost: f64) {
        let _ = (spec, config, cost);
    }
}

/// Rounds with zero fresh evaluations the batched driver tolerates
/// before concluding the strategy is spinning on seen configs.  Cached
/// rounds can be legitimate progress (a hill-climb walking through
/// territory a previous restart already measured), so the cap is
/// generous; it exists to bound strategies that cycle forever on the
/// same proposals.
const MAX_STALE_ROUNDS: usize = 8;

/// Drive a strategy through its batch-proposal interface.
///
/// The driver owns dedupe and budget accounting: every *unique* config
/// evaluated through `eval_batch` consumes budget; re-proposals are
/// served from the cache (and still `observe`d, so stateful strategies
/// keep advancing).  `preseeded` carries evaluations performed outside
/// the strategy's budget — the tuner's forced default and warm-start
/// candidates — so the strategy never pays for them.
///
/// `eval_batch` receives a deduplicated, valid, unseen batch and must
/// return one cost per config (`f64::INFINITY` for failures).  This is
/// where the tuner hangs compile prefetch + gate + racing; tests pass a
/// synthetic surface.
pub fn drive_batched(
    strategy: &mut dyn SearchStrategy,
    spec: &TuningSpec,
    budget: usize,
    batch: usize,
    preseeded: &[(Config, f64)],
    eval_batch: &mut dyn FnMut(&[Config]) -> Vec<f64>,
) -> SearchResult {
    let batch = batch.max(1);
    let total_valid = spec.enumerate().len();
    let mut cache: HashMap<String, f64> = preseeded
        .iter()
        .map(|(c, cost)| (spec.config_id(c), *cost))
        .collect();
    let mut history: Vec<Evaluation> = Vec::new();
    let mut best: Option<(Config, f64)> = None;
    let mut remaining = budget;
    let mut stale = 0usize;

    while remaining > 0 && cache.len() < total_valid && stale < MAX_STALE_ROUNDS {
        let proposal = {
            let seen = |c: &Config| cache.contains_key(&spec.config_id(c));
            strategy.suggest(spec, batch, &seen)
        };
        if proposal.is_empty() {
            break;
        }

        // Split the proposal: fresh valid configs (bounded by remaining
        // budget) get evaluated; the rest are answered from the cache.
        let mut fresh: Vec<Config> = Vec::new();
        let mut fresh_ids: Vec<String> = Vec::new();
        for c in &proposal {
            let id = spec.config_id(c);
            if spec.is_valid(c)
                && !cache.contains_key(&id)
                && !fresh_ids.contains(&id)
                && fresh.len() < remaining
            {
                fresh.push(c.clone());
                fresh_ids.push(id);
            }
        }

        if fresh.is_empty() {
            stale += 1;
        } else {
            stale = 0;
            let costs = eval_batch(&fresh);
            debug_assert_eq!(costs.len(), fresh.len());
            remaining -= fresh.len();
            for (c, &cost) in fresh.iter().zip(&costs) {
                cache.insert(spec.config_id(c), cost);
                history.push(Evaluation { config: c.clone(), cost });
                if cost.is_finite() {
                    match &best {
                        Some((_, b)) if *b <= cost => {}
                        _ => best = Some((c.clone(), cost)),
                    }
                }
            }
        }

        // Tell the strategy about every proposed config, in proposal
        // order — fresh results, cached repeats, and invalid configs
        // (infinite cost) alike.  Valid configs that were never
        // evaluated (budget truncation on the final round) are NOT
        // observed: reporting them as failures would poison the
        // strategy's state with phantom infinities.
        for c in &proposal {
            if !spec.is_valid(c) {
                strategy.observe(spec, c, f64::INFINITY);
            } else if let Some(&cost) = cache.get(&spec.config_id(c)) {
                strategy.observe(spec, c, cost);
            }
        }
    }

    SearchResult { best, history }
}

/// Budget-enforcing, deduplicating evaluation wrapper shared by all
/// strategies.
pub(crate) struct Budget<'a, 'b> {
    spec: &'a TuningSpec,
    remaining: usize,
    cache: HashMap<String, f64>,
    history: Vec<Evaluation>,
    best: Option<(Config, f64)>,
    eval: &'a mut dyn FnMut(&Config) -> f64,
    _marker: std::marker::PhantomData<&'b ()>,
}

impl<'a, 'b> Budget<'a, 'b> {
    pub(crate) fn new(
        spec: &'a TuningSpec,
        budget: usize,
        eval: &'a mut dyn FnMut(&Config) -> f64,
    ) -> Self {
        Budget {
            spec,
            remaining: budget,
            cache: HashMap::new(),
            history: Vec::new(),
            best: None,
            eval,
            _marker: std::marker::PhantomData,
        }
    }

    /// Evaluate a config.  Cached repeats are free; new evaluations
    /// consume budget.  Returns `None` when the budget is exhausted.
    pub(crate) fn eval(&mut self, config: &Config) -> Option<f64> {
        let id = self.spec.config_id(config);
        if let Some(&c) = self.cache.get(&id) {
            return Some(c);
        }
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let cost = (self.eval)(config);
        self.cache.insert(id, cost);
        self.history.push(Evaluation { config: config.clone(), cost });
        if cost.is_finite() {
            match &self.best {
                Some((_, b)) if *b <= cost => {}
                _ => self.best = Some((config.clone(), cost)),
            }
        }
        Some(cost)
    }

    pub(crate) fn exhausted(&self) -> bool {
        self.remaining == 0
    }

    /// True once every valid config has been evaluated — iterative
    /// strategies must stop then even with budget left, or they would
    /// spin forever on cached repeats.
    pub(crate) fn space_exhausted(&self, total_valid: usize) -> bool {
        self.cache.len() >= total_valid
    }

    pub(crate) fn seen(&self, config: &Config) -> bool {
        self.cache.contains_key(&self.spec.config_id(config))
    }

    #[cfg(test)]
    pub(crate) fn unique_evals(&self) -> usize {
        self.history.len()
    }

    pub(crate) fn finish(self) -> SearchResult {
        SearchResult { best: self.best, history: self.history }
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::runtime::registry::ParamDef;

    /// A deterministic synthetic cost surface: quadratic bowl over the
    /// parameter indices with a known global optimum, so strategy tests
    /// can assert quality without a PJRT runtime.
    pub fn bowl_spec() -> TuningSpec {
        TuningSpec::new(
            "bowl",
            "t",
            vec![
                ParamDef {
                    name: "block_size".into(),
                    abbrev: "b".into(),
                    values: vec![64, 128, 256, 512, 1024, 2048, 4096, 8192],
                },
                ParamDef {
                    name: "unroll".into(),
                    abbrev: "u".into(),
                    values: vec![1, 2, 4, 8],
                },
            ],
            &["block_size % unroll == 0".to_string()],
            [("n".to_string(), 1 << 20)].into_iter().collect(),
        )
        .unwrap()
    }

    /// Optimum at block_size=1024 (index 4), unroll=4 (index 2).
    pub fn bowl_cost(spec: &TuningSpec, c: &Config) -> f64 {
        let idx = spec.index_of(c).expect("in-domain");
        let db = idx[0] as f64 - 4.0;
        let du = idx[1] as f64 - 2.0;
        1.0 + db * db + 0.5 * du * du
    }

    pub fn run_on_bowl(strategy: &mut dyn SearchStrategy, budget: usize) -> SearchResult {
        let spec = bowl_spec();
        let mut eval = {
            let spec = spec.clone();
            move |c: &Config| bowl_cost(&spec, c)
        };
        strategy.run(&spec, budget, &mut eval)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn budget_dedupes_and_bounds() {
        let spec = bowl_spec();
        let mut calls = 0usize;
        let mut eval = |c: &Config| {
            calls += 1;
            bowl_cost(&bowl_spec(), c)
        };
        let mut b = Budget::new(&spec, 3, &mut eval);
        let cfgs = spec.enumerate();
        assert!(b.eval(&cfgs[0]).is_some());
        assert!(b.eval(&cfgs[0]).is_some()); // cached, free
        assert!(b.eval(&cfgs[1]).is_some());
        assert!(b.eval(&cfgs[2]).is_some());
        assert!(b.eval(&cfgs[3]).is_none()); // budget exhausted
        let r = b.finish();
        assert_eq!(calls, 3);
        assert_eq!(r.evaluations(), 3);
    }

    #[test]
    fn budget_tracks_best_finite_only() {
        let spec = bowl_spec();
        let mut eval = |c: &Config| {
            if c["unroll"] == 1 {
                f64::INFINITY
            } else {
                bowl_cost(&bowl_spec(), c)
            }
        };
        let mut b = Budget::new(&spec, usize::MAX, &mut eval);
        for c in spec.enumerate() {
            b.eval(&c);
        }
        let r = b.finish();
        let (best, _) = r.best.unwrap();
        assert_ne!(best["unroll"], 1);
    }

    #[test]
    fn best_so_far_is_monotone() {
        let mut s = Exhaustive::new();
        let r = run_on_bowl(&mut s, 20);
        let traj = r.best_so_far();
        assert!(traj.windows(2).all(|w| w[1] <= w[0]));
    }

    /// A strategy that only implements the sequential interface.
    struct DefaultOnly;

    impl SearchStrategy for DefaultOnly {
        fn name(&self) -> &'static str {
            "default-only"
        }

        fn run(
            &mut self,
            spec: &TuningSpec,
            budget: usize,
            eval: &mut dyn FnMut(&Config) -> f64,
        ) -> SearchResult {
            let mut b = Budget::new(spec, budget, eval);
            for c in spec.enumerate() {
                if b.eval(&c).is_none() {
                    break;
                }
            }
            b.finish()
        }
    }

    #[test]
    fn default_suggest_is_single_unseen_candidate() {
        let spec = bowl_spec();
        let mut s = DefaultOnly;
        assert!(!s.supports_batch());
        let all = spec.enumerate();
        let first = s.suggest(&spec, 5, &|_| false);
        assert_eq!(first, vec![all[0].clone()]);
        let head = all[0].clone();
        let second = s.suggest(&spec, 5, &move |c: &Config| *c == head);
        assert_eq!(second, vec![all[1].clone()]);
    }

    fn bowl_eval_batch(batch: &[Config]) -> Vec<f64> {
        let spec = bowl_spec();
        batch.iter().map(|c| bowl_cost(&spec, c)).collect()
    }

    #[test]
    fn drive_batched_budget_dedupe_and_preseed() {
        let spec = bowl_spec();
        let all = spec.enumerate();
        let pre = vec![(all[0].clone(), bowl_cost(&spec, &all[0]))];
        let mut s = Exhaustive::new();
        let mut calls = 0usize;
        let mut eval = |batch: &[Config]| {
            calls += batch.len();
            bowl_eval_batch(batch)
        };
        let r = drive_batched(&mut s, &spec, 6, 4, &pre, &mut eval);
        assert_eq!(r.evaluations(), 6);
        assert_eq!(calls, 6, "budget counts only fresh evaluations");
        // The preseeded config is never re-evaluated.
        assert!(r.history.iter().all(|e| e.config != all[0]));
        let mut ids: Vec<String> =
            r.history.iter().map(|e| spec.config_id(&e.config)).collect();
        let n = ids.len();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n, "batched history must stay deduplicated");
    }

    #[test]
    fn drive_batched_full_budget_finds_optimum() {
        let spec = bowl_spec();
        let mut s = Exhaustive::new();
        let r = drive_batched(&mut s, &spec, usize::MAX, 4, &[], &mut bowl_eval_batch);
        assert_eq!(r.evaluations(), spec.enumerate().len());
        assert_eq!(r.best.unwrap().1, 1.0);
    }

    #[test]
    fn drive_batched_stops_on_stale_proposals() {
        /// Pathological strategy proposing the same config forever.
        struct Stuck;
        impl SearchStrategy for Stuck {
            fn name(&self) -> &'static str {
                "stuck"
            }
            fn run(
                &mut self,
                _spec: &TuningSpec,
                _budget: usize,
                _eval: &mut dyn FnMut(&Config) -> f64,
            ) -> SearchResult {
                SearchResult { best: None, history: Vec::new() }
            }
            fn supports_batch(&self) -> bool {
                true
            }
            fn suggest(
                &mut self,
                spec: &TuningSpec,
                _k: usize,
                _seen: &dyn Fn(&Config) -> bool,
            ) -> Vec<Config> {
                vec![spec.enumerate()[0].clone()]
            }
        }
        let spec = bowl_spec();
        let mut s = Stuck;
        let r = drive_batched(&mut s, &spec, usize::MAX, 2, &[], &mut bowl_eval_batch);
        assert_eq!(r.evaluations(), 1, "stale proposals must terminate the drive");
    }
}
