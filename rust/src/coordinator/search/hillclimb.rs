//! Greedy hill-climbing with random restarts.
//!
//! From a random valid start, evaluate all one-step neighbors (one
//! parameter moved one position in its ordered domain) and move to the
//! best strict improvement; a local optimum triggers a fresh random
//! restart.  Schedule spaces like ours (block sizes / unroll factors in
//! ordered power-of-two domains) are mostly unimodal along each axis, so
//! coordinate-wise descent converges in a handful of evaluations —
//! Orio's "simplex-like" local strategies exploit the same structure.

use super::{Budget, SearchResult, SearchStrategy};
use crate::coordinator::spec::{Config, TuningSpec};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct HillClimb {
    seed: u64,
    max_restarts: usize,
}

impl HillClimb {
    pub fn new(seed: u64) -> HillClimb {
        HillClimb { seed, max_restarts: 8 }
    }

    pub fn with_restarts(seed: u64, max_restarts: usize) -> HillClimb {
        HillClimb { seed, max_restarts: max_restarts.max(1) }
    }
}

impl SearchStrategy for HillClimb {
    fn name(&self) -> &'static str {
        "hillclimb"
    }

    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult {
        let mut rng = Rng::new(self.seed);
        let mut b = Budget::new(spec, budget, eval);
        'restarts: for _ in 0..self.max_restarts {
            let Some(mut current) = spec.random_config(&mut rng, 256) else {
                break;
            };
            let Some(mut current_cost) = b.eval(&current) else {
                break;
            };
            loop {
                let mut moved = false;
                let mut neighbors = spec.neighbors(&current);
                // Deterministic order, then shuffle to avoid axis bias
                // between restarts.
                rng.shuffle(&mut neighbors);
                let mut best_n: Option<(Config, f64)> = None;
                for n in neighbors {
                    let Some(cost) = b.eval(&n) else {
                        break 'restarts;
                    };
                    if cost < current_cost
                        && best_n.as_ref().map_or(true, |(_, bc)| cost < *bc)
                    {
                        best_n = Some((n, cost));
                    }
                }
                if let Some((n, cost)) = best_n {
                    current = n;
                    current_cost = cost;
                    moved = true;
                }
                if !moved {
                    break; // local optimum -> restart
                }
            }
            if b.exhausted() {
                break;
            }
        }
        b.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn converges_on_unimodal_surface() {
        let mut s = HillClimb::new(3);
        let r = run_on_bowl(&mut s, usize::MAX);
        let (_, cost) = r.best.unwrap();
        assert_eq!(cost, 1.0, "bowl is unimodal; hillclimb must find the optimum");
    }

    #[test]
    fn uses_fewer_evals_than_exhaustive() {
        let spec = bowl_spec();
        let full = spec.enumerate().len();
        let mut s = HillClimb::with_restarts(3, 1);
        let r = run_on_bowl(&mut s, usize::MAX);
        assert!(
            r.evaluations() < full,
            "single-restart hillclimb ({}) should beat exhaustive ({full})",
            r.evaluations()
        );
    }

    #[test]
    fn respects_budget() {
        let mut s = HillClimb::new(1);
        let r = run_on_bowl(&mut s, 4);
        assert!(r.evaluations() <= 4);
        assert!(r.best.is_some());
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = bowl_spec();
        let r1 = run_on_bowl(&mut HillClimb::new(9), 15);
        let r2 = run_on_bowl(&mut HillClimb::new(9), 15);
        let ids = |r: &SearchResult| {
            r.history.iter().map(|e| spec.config_id(&e.config)).collect::<Vec<_>>()
        };
        assert_eq!(ids(&r1), ids(&r2));
    }
}
