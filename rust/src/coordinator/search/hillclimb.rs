//! Greedy hill-climbing with random restarts.
//!
//! From a random valid start, evaluate all one-step neighbors (one
//! parameter moved one position in its ordered domain) and move to the
//! best strict improvement; a local optimum triggers a fresh random
//! restart.  Schedule spaces like ours (block sizes / unroll factors in
//! ordered power-of-two domains) are mostly unimodal along each axis, so
//! coordinate-wise descent converges in a handful of evaluations —
//! Orio's "simplex-like" local strategies exploit the same structure.

use super::{Budget, SearchResult, SearchStrategy};
use crate::coordinator::spec::{Config, TuningSpec};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
/// Greedy hill-climbing over one-step neighbors, with random restarts.
pub struct HillClimb {
    seed: u64,
    max_restarts: usize,
    // Batch-mode (ask/tell) state: the climb advances one neighborhood
    // per suggest/observe round instead of one neighbor per eval.
    rng: Option<Rng>,
    current: Option<(Config, f64)>,
    round: Vec<(Config, f64)>,
    restarts_done: usize,
    finished: bool,
}

impl HillClimb {
    /// A climber with the default restart budget.
    pub fn new(seed: u64) -> HillClimb {
        HillClimb::with_restarts(seed, 8)
    }

    /// A climber with an explicit restart budget.
    pub fn with_restarts(seed: u64, max_restarts: usize) -> HillClimb {
        HillClimb {
            seed,
            max_restarts: max_restarts.max(1),
            rng: None,
            current: None,
            round: Vec::new(),
            restarts_done: 0,
            finished: false,
        }
    }

    /// Fold the last round's observations into the climb state: move to
    /// the best strict improvement, or count a restart at a local
    /// optimum.
    fn absorb_round(&mut self) {
        if self.round.is_empty() {
            return;
        }
        let best_round = self
            .round
            .iter()
            .filter(|(_, cost)| cost.is_finite())
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .cloned();
        match (self.current.take(), best_round) {
            // Starts round: adopt the best start as the climb origin.
            (None, Some(b)) => self.current = Some(b),
            // All starts failed: burn a restart.
            (None, None) => self.restarts_done += 1,
            // Neighborhood round with a strict improvement: move.
            (Some((_, cc)), Some((bc, bcost))) if bcost < cc => {
                self.current = Some((bc, bcost));
            }
            // Local optimum: restart from scratch (current stays None).
            (Some(_), _) => self.restarts_done += 1,
        }
        self.round.clear();
    }
}

impl SearchStrategy for HillClimb {
    fn name(&self) -> &'static str {
        "hillclimb"
    }

    fn run(
        &mut self,
        spec: &TuningSpec,
        budget: usize,
        eval: &mut dyn FnMut(&Config) -> f64,
    ) -> SearchResult {
        let mut rng = Rng::new(self.seed);
        let mut b = Budget::new(spec, budget, eval);
        'restarts: for _ in 0..self.max_restarts {
            let Some(mut current) = spec.random_config(&mut rng, 256) else {
                break;
            };
            let Some(mut current_cost) = b.eval(&current) else {
                break;
            };
            loop {
                let mut moved = false;
                let mut neighbors = spec.neighbors(&current);
                // Deterministic order, then shuffle to avoid axis bias
                // between restarts.
                rng.shuffle(&mut neighbors);
                let mut best_n: Option<(Config, f64)> = None;
                for n in neighbors {
                    let Some(cost) = b.eval(&n) else {
                        break 'restarts;
                    };
                    if cost < current_cost
                        && best_n.as_ref().map_or(true, |(_, bc)| cost < *bc)
                    {
                        best_n = Some((n, cost));
                    }
                }
                if let Some((n, cost)) = best_n {
                    current = n;
                    current_cost = cost;
                    moved = true;
                }
                if !moved {
                    break; // local optimum -> restart
                }
            }
            if b.exhausted() {
                break;
            }
        }
        b.finish()
    }

    fn supports_batch(&self) -> bool {
        true
    }

    /// One climb round per call: either `k` random starts (after a
    /// restart) or the FULL one-step neighborhood of the current point —
    /// neighborhoods are at most `2 · #params` configs and truncating
    /// them could hide the only improving direction, so they may exceed
    /// `k`.
    fn suggest(
        &mut self,
        spec: &TuningSpec,
        k: usize,
        seen: &dyn Fn(&Config) -> bool,
    ) -> Vec<Config> {
        if self.finished {
            return Vec::new();
        }
        self.absorb_round();
        if self.current.is_none() && self.restarts_done >= self.max_restarts {
            self.finished = true;
            return Vec::new();
        }
        let seed = self.seed;
        let rng = self.rng.get_or_insert_with(|| Rng::new(seed));

        if let Some((c, _)) = &self.current {
            let mut neighbors = spec.neighbors(c);
            rng.shuffle(&mut neighbors);
            if !neighbors.is_empty() {
                return neighbors;
            }
            // Isolated point: force a restart below.
            self.current = None;
            self.restarts_done += 1;
            if self.restarts_done >= self.max_restarts {
                self.finished = true;
                return Vec::new();
            }
        }

        // Fresh starts: up to k distinct valid configs, preferring ones
        // the driver hasn't evaluated (falls back to a seen config so
        // the climb can resume from cached costs in tiny spaces).
        let want = k.max(1);
        let mut starts: Vec<Config> = Vec::new();
        let mut ids: Vec<String> = Vec::new();
        for _ in 0..want * 16 {
            if starts.len() >= want {
                break;
            }
            let Some(c) = spec.random_config(rng, 64) else { break };
            let id = spec.config_id(&c);
            if !ids.contains(&id) && !seen(&c) {
                ids.push(id);
                starts.push(c);
            }
        }
        if starts.is_empty() {
            if let Some(c) = spec.random_config(rng, 64) {
                starts.push(c);
            }
        }
        starts
    }

    fn observe(&mut self, _spec: &TuningSpec, config: &Config, cost: f64) {
        self.round.push((config.clone(), cost));
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::*;
    use super::*;

    #[test]
    fn converges_on_unimodal_surface() {
        let mut s = HillClimb::new(3);
        let r = run_on_bowl(&mut s, usize::MAX);
        let (_, cost) = r.best.unwrap();
        assert_eq!(cost, 1.0, "bowl is unimodal; hillclimb must find the optimum");
    }

    #[test]
    fn uses_fewer_evals_than_exhaustive() {
        let spec = bowl_spec();
        let full = spec.enumerate().len();
        let mut s = HillClimb::with_restarts(3, 1);
        let r = run_on_bowl(&mut s, usize::MAX);
        assert!(
            r.evaluations() < full,
            "single-restart hillclimb ({}) should beat exhaustive ({full})",
            r.evaluations()
        );
    }

    #[test]
    fn respects_budget() {
        let mut s = HillClimb::new(1);
        let r = run_on_bowl(&mut s, 4);
        assert!(r.evaluations() <= 4);
        assert!(r.best.is_some());
    }

    #[test]
    fn batch_mode_converges_on_bowl() {
        use super::super::drive_batched;
        let spec = bowl_spec();
        let mut s = HillClimb::new(3);
        let mut eval = |batch: &[Config]| -> Vec<f64> {
            let spec = bowl_spec();
            batch.iter().map(|c| bowl_cost(&spec, c)).collect()
        };
        let r = drive_batched(&mut s, &spec, usize::MAX, 4, &[], &mut eval);
        assert_eq!(
            r.best.unwrap().1,
            1.0,
            "bowl is unimodal; batched neighborhood climbing must find the optimum"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = bowl_spec();
        let r1 = run_on_bowl(&mut HillClimb::new(9), 15);
        let r2 = run_on_bowl(&mut HillClimb::new(9), 15);
        let ids = |r: &SearchResult| {
            r.history.iter().map(|e| spec.config_id(&e.config)).collect::<Vec<_>>()
        };
        assert_eq!(ids(&r1), ids(&r2));
    }
}
