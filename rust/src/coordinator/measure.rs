//! Measurement harness: the paper's "compiled and executed to obtain its
//! performance metrics" stage.
//!
//! Protocol per variant: warmup executions (JIT caches, branch
//! predictors, page faults), then timed repetitions of
//! execute-and-materialize, with optional adaptive extension until the
//! relative spread (MAD/median) falls under a threshold or a hard cap is
//! reached.  Inputs are converted to literals ONCE, outside the timed
//! region — only execution + output materialization is timed.
//!
//! Two entry points share that protocol:
//! * [`measure`] — one executable, full sampling (the serial pipeline);
//! * [`race`] — a batch of executables with interleaved repetitions and
//!   successive-halving early termination: every candidate gets a
//!   guaranteed floor of repetitions ([`MeasureConfig::race_min_reps`]),
//!   after which any candidate whose most optimistic achievable median
//!   (its fastest sample so far) is already slower than the incumbent
//!   best median stops being measured.  On a noise-free cost surface the
//!   race provably selects the same winner as full measurement (the
//!   winner's own samples define the bar and can never exceed it); the
//!   property tests in `tests/prop_coordinator.rs` pin this down.

use std::time::Instant;

use anyhow::Result;

#[cfg(not(feature = "xla-runtime"))]
use crate::xla;

use crate::runtime::{Executable, TensorData};
use crate::util::stats::{reject_outliers, Summary};

/// Harness parameters.
#[derive(Debug, Clone)]
pub struct MeasureConfig {
    /// Untimed executions before sampling.
    pub warmup: usize,
    /// Initial number of timed repetitions.
    pub reps: usize,
    /// Extend sampling (doubling) until `rel_spread` <= this or `max_reps`.
    pub target_rel_spread: f64,
    /// Hard cap on total timed repetitions.
    pub max_reps: usize,
    /// MAD multiplier for one-sided outlier rejection (0 = keep all).
    pub outlier_k: f64,
    /// Racing floor: repetitions every raced candidate is guaranteed
    /// before the early-termination cutoff may prune it.  Lower = more
    /// aggressive saving, higher = more robust to timing noise.
    pub race_min_reps: usize,
}

impl Default for MeasureConfig {
    fn default() -> Self {
        MeasureConfig {
            warmup: 2,
            reps: 7,
            target_rel_spread: 0.10,
            max_reps: 28,
            outlier_k: 5.0,
            race_min_reps: 3,
        }
    }
}

impl MeasureConfig {
    /// Fast profile for tests and smoke runs.
    pub fn quick() -> MeasureConfig {
        MeasureConfig {
            warmup: 1,
            reps: 3,
            target_rel_spread: 1.0,
            max_reps: 3,
            outlier_k: 0.0,
            race_min_reps: 2,
        }
    }
}

/// A completed measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Robust summary over (outlier-filtered) samples, seconds.
    pub summary: Summary,
    /// Raw samples in collection order, seconds.
    pub samples: Vec<f64>,
}

impl Measurement {
    /// The scalar the tuner optimizes.
    pub fn cost(&self) -> f64 {
        self.summary.median
    }

    /// Effective GFLOP/s given the workload's flop count.
    pub fn gflops(&self, flops: u64) -> f64 {
        flops as f64 / self.summary.median / 1e9
    }

    /// Effective GiB/s given the workload's bytes-moved estimate.
    pub fn gibps(&self, bytes: u64) -> f64 {
        bytes as f64 / self.summary.median / (1024.0 * 1024.0 * 1024.0)
    }
}

/// Robust summary over samples with the configured outlier rejection.
fn summarize(samples: Vec<f64>, cfg: &MeasureConfig) -> Result<Measurement> {
    let filtered = if cfg.outlier_k > 0.0 {
        reject_outliers(&samples, cfg.outlier_k)
    } else {
        samples.clone()
    };
    let summary = Summary::from_samples(&filtered)
        .ok_or_else(|| anyhow::anyhow!("degenerate timing sample"))?;
    Ok(Measurement { summary, samples })
}

/// Is a sample set complete under the adaptive-extension rule?
fn sampling_done(samples: &[f64], cfg: &MeasureConfig) -> bool {
    if samples.len() >= cfg.max_reps {
        return true;
    }
    if samples.len() < cfg.reps.max(1) {
        return false;
    }
    match Summary::from_samples(samples) {
        Some(s) => s.rel_spread() <= cfg.target_rel_spread,
        None => true,
    }
}

/// The timing protocol: repeat execute-and-materialize until the
/// adaptive-extension rule is satisfied.  The single place a timed
/// repetition is defined — `measure`, `measure_with_outputs`, and the
/// racing samplers all route through the same shape.
fn timed_samples(
    exe: &Executable,
    literals: &[xla::Literal],
    cfg: &MeasureConfig,
) -> Result<Vec<f64>> {
    let mut samples = Vec::with_capacity(cfg.reps);
    while !sampling_done(&samples, cfg) {
        let t0 = Instant::now();
        let out = exe.run_literals(literals)?;
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(&out);
        samples.push(dt);
    }
    Ok(samples)
}

/// Measure an arbitrary host-side computation under the same timing
/// protocol as artifact execution (warmup, adaptive extension, outlier
/// rejection).  The native workload families (GEMM) route their sweep
/// measurements through this so artifact-backed and host-side timings
/// are directly comparable.  The closure must keep its result
/// observable (e.g. `std::hint::black_box` the output buffer) so the
/// optimizer cannot delete the work being timed.
pub fn measure_host(
    run: &mut dyn FnMut() -> Result<()>,
    cfg: &MeasureConfig,
) -> Result<Measurement> {
    for _ in 0..cfg.warmup {
        run()?;
    }
    let mut samples = Vec::with_capacity(cfg.reps);
    while !sampling_done(&samples, cfg) {
        let t0 = Instant::now();
        run()?;
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(samples, cfg)
}

/// Measure one executable over fixed inputs.
pub fn measure(
    exe: &Executable,
    inputs: &[TensorData],
    cfg: &MeasureConfig,
) -> Result<Measurement> {
    // Literal conversion happens once, outside the timed region.
    let literals = inputs
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<Vec<_>>>()?;

    for _ in 0..cfg.warmup {
        exe.run_literals(&literals)?;
    }
    summarize(timed_samples(exe, &literals, cfg)?, cfg)
}

/// Measure one executable AND capture its outputs, reusing the first
/// warmup execution as the output run — the artifact is never executed
/// redundantly just to read its results (the baseline used to pay one
/// full extra execution per tune for exactly this).
pub fn measure_with_outputs(
    exe: &Executable,
    inputs: &[TensorData],
    cfg: &MeasureConfig,
) -> Result<(Measurement, Vec<f32>)> {
    let literals = inputs
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<Vec<_>>>()?;

    // First execution doubles as warmup #1 and the output capture; it
    // always runs even with warmup = 0 (outputs have to come from
    // somewhere), it just stays untimed.
    let first = exe.run_literals(&literals)?;
    let outputs = first.to_vec::<f32>()?;
    for _ in 1..cfg.warmup {
        exe.run_literals(&literals)?;
    }
    Ok((summarize(timed_samples(exe, &literals, cfg)?, cfg)?, outputs))
}

/// One candidate's record from a [`race`].
#[derive(Debug, Clone)]
pub struct Lane {
    /// Raw samples collected before completion or cutoff.
    pub samples: Vec<f64>,
    /// Round-robin round at which the cutoff pruned this lane
    /// (`None` = ran to normal completion).
    pub cut_at: Option<usize>,
    /// The lane's sampler errored mid-race (its partial samples remain).
    pub errored: bool,
}

/// Result of racing a batch of candidates.
#[derive(Debug)]
pub struct RaceOutcome {
    /// Per-lane summaries, input order; `None` when a lane produced no
    /// usable samples (sampler error before its first repetition).
    pub measurements: Vec<Option<Measurement>>,
    /// Per-lane sampling records, input order.
    pub lanes: Vec<Lane>,
    /// Lane index with the smallest final median, if any lane finished.
    pub winner: Option<usize>,
    /// Timed repetitions actually executed across all lanes.
    pub reps_timed: u64,
    /// Lower bound on repetitions avoided vs the serial harness, which
    /// gives every candidate at least `cfg.reps` (savings from skipped
    /// adaptive extensions are real but not counted here).
    pub reps_saved: u64,
    /// Lanes stopped early by the cutoff.
    pub pruned: u64,
}

/// Race a set of cost samplers with interleaved repetitions and
/// successive-halving early termination.  `incumbent` is an externally
/// known best median (e.g. the best variant of previous batches): lanes
/// that cannot beat it stop at the repetition floor.
///
/// This is the testable core of [`race`]; each closure returns one timed
/// repetition's cost in seconds.
pub fn race_samplers(
    samplers: &mut [Box<dyn FnMut() -> Result<f64> + '_>],
    cfg: &MeasureConfig,
    incumbent: Option<f64>,
) -> Result<RaceOutcome> {
    let n = samplers.len();
    let min_reps = cfg.race_min_reps.clamp(1, cfg.max_reps.max(1));
    let mut lanes: Vec<Lane> = (0..n)
        .map(|_| Lane { samples: Vec::new(), cut_at: None, errored: false })
        .collect();
    let mut reps_timed = 0u64;
    let mut round = 0usize;

    loop {
        round += 1;
        let mut any_progress = false;
        for (lane, sampler) in lanes.iter_mut().zip(samplers.iter_mut()) {
            if lane.cut_at.is_some() || lane.errored || sampling_done(&lane.samples, cfg) {
                continue;
            }
            match sampler() {
                Ok(dt) => {
                    lane.samples.push(dt);
                    reps_timed += 1;
                    any_progress = true;
                }
                Err(_) => {
                    lane.errored = true;
                    lane.cut_at = Some(round);
                }
            }
        }
        if !any_progress {
            break;
        }

        // Cutoff pass.  The bar is the most credible median known so
        // far: the best current median among lanes that reached the
        // repetition floor, tightened by the external incumbent.
        let best_median = lanes
            .iter()
            .filter(|l| !l.errored && l.samples.len() >= min_reps)
            .filter_map(|l| Summary::from_samples(&l.samples))
            .map(|s| s.median)
            .fold(f64::INFINITY, f64::min);
        let bar = best_median.min(incumbent.unwrap_or(f64::INFINITY));
        if bar.is_finite() {
            for lane in lanes.iter_mut() {
                if lane.cut_at.is_some()
                    || lane.errored
                    || lane.samples.len() < min_reps
                    || sampling_done(&lane.samples, cfg)
                {
                    continue;
                }
                // Most optimistic median this lane can still achieve is
                // bounded below by its fastest observation; strictly
                // above the bar ⇒ it can never win ⇒ stop paying for it.
                let optimistic = lane.samples.iter().copied().fold(f64::INFINITY, f64::min);
                if optimistic > bar {
                    lane.cut_at = Some(round);
                }
            }
        }
    }

    let measurements: Vec<Option<Measurement>> = lanes
        .iter()
        .map(|l| {
            if l.samples.is_empty() {
                None
            } else {
                summarize(l.samples.clone(), cfg).ok()
            }
        })
        .collect();
    let winner = measurements
        .iter()
        .enumerate()
        .filter(|(i, _)| !lanes[*i].errored)
        .filter_map(|(i, m)| m.as_ref().map(|m| (i, m.cost())))
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .map(|(i, _)| i);
    let reps_saved = lanes
        .iter()
        .map(|l| cfg.reps.saturating_sub(l.samples.len()) as u64)
        .sum();
    let pruned = lanes.iter().filter(|l| l.cut_at.is_some() && !l.errored).count() as u64;
    Ok(RaceOutcome { measurements, lanes, winner, reps_timed, reps_saved, pruned })
}

/// Race a batch of compiled variants over fixed inputs (see module docs).
/// Timing stays on the calling thread; repetitions are interleaved
/// across candidates so the cutoff always compares contemporaneous
/// samples (a system-wide slowdown hits every lane equally).
pub fn race(
    exes: &[&Executable],
    inputs: &[TensorData],
    cfg: &MeasureConfig,
    incumbent: Option<f64>,
) -> Result<RaceOutcome> {
    let literals = inputs
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<Vec<_>>>()?;
    for exe in exes {
        for _ in 0..cfg.warmup {
            exe.run_literals(&literals)?;
        }
    }
    let mut samplers: Vec<Box<dyn FnMut() -> Result<f64> + '_>> = exes
        .iter()
        .map(|exe| {
            let literals = &literals;
            Box::new(move || {
                let t0 = Instant::now();
                let out = exe.run_literals(literals)?;
                let dt = t0.elapsed().as_secs_f64();
                std::hint::black_box(&out);
                Ok(dt)
            }) as Box<dyn FnMut() -> Result<f64> + '_>
        })
        .collect();
    race_samplers(&mut samplers, cfg, incumbent)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = MeasureConfig::default();
        assert!(c.warmup >= 1);
        assert!(c.reps >= 3);
        assert!(c.max_reps >= c.reps);
        assert!(c.target_rel_spread > 0.0);
        assert!(c.race_min_reps >= 1 && c.race_min_reps <= c.reps);
    }

    #[test]
    fn quick_config_is_cheap() {
        let c = MeasureConfig::quick();
        assert!(c.warmup <= 1);
        assert!(c.max_reps <= 5);
    }

    #[test]
    fn measurement_derivations() {
        let samples = vec![2e-3, 1e-3, 3e-3];
        let m = Measurement {
            summary: Summary::from_samples(&samples).unwrap(),
            samples,
        };
        assert_eq!(m.cost(), 2e-3);
        // 2 GFLOP in 2ms = 1000 GFLOP/s.
        assert!((m.gflops(2_000_000_000) - 1000.0).abs() < 1e-9);
        // 2 GiB in 2 ms = 1000 GiB/s.
        let gib = m.gibps(2 * 1024 * 1024 * 1024);
        assert!((gib - 1000.0).abs() < 1e-9);
    }

    fn constant_lanes(costs: &[f64]) -> Vec<Box<dyn FnMut() -> Result<f64> + '_>> {
        costs
            .iter()
            .map(|&c| Box::new(move || Ok(c)) as Box<dyn FnMut() -> Result<f64> + '_>)
            .collect()
    }

    fn cfg() -> MeasureConfig {
        MeasureConfig {
            warmup: 0,
            reps: 7,
            target_rel_spread: 0.10,
            max_reps: 28,
            outlier_k: 0.0,
            race_min_reps: 3,
        }
    }

    #[test]
    fn race_picks_true_winner_on_constant_costs() {
        let costs = [4e-3, 1e-3, 2e-3, 8e-3];
        let mut lanes = constant_lanes(&costs);
        let out = race_samplers(&mut lanes, &cfg(), None).unwrap();
        assert_eq!(out.winner, Some(1));
        let m = out.measurements[1].as_ref().unwrap();
        assert!((m.cost() - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn race_prunes_losers_at_the_floor() {
        let costs = [4e-3, 1e-3, 2e-3, 8e-3];
        let mut lanes = constant_lanes(&costs);
        let c = cfg();
        let out = race_samplers(&mut lanes, &c, None).unwrap();
        // Constant samples ⇒ spread 0 ⇒ the winner stops at `reps`;
        // every loser is cut at the floor.
        assert_eq!(out.pruned, 3);
        for (i, lane) in out.lanes.iter().enumerate() {
            if i == 1 {
                assert_eq!(lane.samples.len(), c.reps);
                assert!(lane.cut_at.is_none());
            } else {
                assert_eq!(lane.samples.len(), c.race_min_reps);
                assert!(lane.cut_at.is_some());
            }
        }
        // ≥ 30% fewer timed reps than serial full measurement.
        let serial = (costs.len() * c.reps) as u64;
        assert!(
            out.reps_timed as f64 <= 0.7 * serial as f64,
            "race spent {} of serial {serial}",
            out.reps_timed
        );
        assert_eq!(out.reps_saved, serial - out.reps_timed);
    }

    #[test]
    fn race_incumbent_prunes_everything_slower() {
        let costs = [4e-3, 2e-3];
        let mut lanes = constant_lanes(&costs);
        let c = cfg();
        let out = race_samplers(&mut lanes, &c, Some(1e-3)).unwrap();
        // Both lanes lose to the incumbent: both stop at the floor.
        assert_eq!(out.pruned, 2);
        assert!(out.lanes.iter().all(|l| l.samples.len() == c.race_min_reps));
        // Winner is still reported (relative order preserved).
        assert_eq!(out.winner, Some(1));
    }

    #[test]
    fn race_tolerates_a_failing_lane() {
        let mut n = 0usize;
        let mut lanes: Vec<Box<dyn FnMut() -> Result<f64> + '_>> = vec![
            Box::new(|| Ok(2e-3)),
            Box::new(move || {
                n += 1;
                if n > 1 {
                    Err(anyhow::anyhow!("lane died"))
                } else {
                    Ok(1e-3)
                }
            }),
        ];
        let out = race_samplers(&mut lanes, &cfg(), None).unwrap();
        assert!(out.lanes[1].errored);
        // The healthy lane still completes and wins — errored lanes are
        // never eligible even when their partial median looks fast.
        assert_eq!(out.lanes[0].cut_at, None);
        assert!(out.measurements[0].is_some());
        assert_eq!(out.winner, Some(0));
    }

    #[test]
    fn race_never_cuts_below_the_floor() {
        // Noisy-ish deterministic lanes: alternating samples.
        let mut flip = false;
        let mut lanes: Vec<Box<dyn FnMut() -> Result<f64> + '_>> = vec![
            Box::new(|| Ok(1e-3)),
            Box::new(move || {
                flip = !flip;
                Ok(if flip { 5e-3 } else { 6e-3 })
            }),
        ];
        let c = cfg();
        let out = race_samplers(&mut lanes, &c, None).unwrap();
        for lane in &out.lanes {
            assert!(lane.samples.len() >= c.race_min_reps);
        }
    }

    #[test]
    fn measure_host_obeys_the_sampling_protocol() {
        let mut calls = 0usize;
        let c = MeasureConfig { warmup: 2, target_rel_spread: 1.0, ..cfg() };
        let mut run = || {
            calls += 1;
            std::hint::black_box(calls);
            Ok(())
        };
        let m = measure_host(&mut run, &c).unwrap();
        assert!(m.samples.len() >= c.reps && m.samples.len() <= c.max_reps);
        assert_eq!(calls, c.warmup + m.samples.len(), "warmups run untimed before sampling");
        assert!(m.cost() >= 0.0);
    }

    #[test]
    fn measure_host_propagates_errors() {
        let mut run = || Err(anyhow::anyhow!("boom"));
        assert!(measure_host(&mut run, &cfg()).is_err());
    }

    #[test]
    fn race_on_empty_batch_is_empty() {
        let mut lanes: Vec<Box<dyn FnMut() -> Result<f64> + '_>> = Vec::new();
        let out = race_samplers(&mut lanes, &cfg(), None).unwrap();
        assert!(out.winner.is_none());
        assert_eq!(out.reps_timed, 0);
    }
}
