//! Measurement harness: the paper's "compiled and executed to obtain its
//! performance metrics" stage.
//!
//! Protocol per variant: warmup executions (JIT caches, branch
//! predictors, page faults), then timed repetitions of
//! execute-and-materialize, with optional adaptive extension until the
//! relative spread (MAD/median) falls under a threshold or a hard cap is
//! reached.  Inputs are converted to literals ONCE, outside the timed
//! region — only execution + output materialization is timed.

use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Executable, TensorData};
use crate::util::stats::{reject_outliers, Summary};

/// Harness parameters.
#[derive(Debug, Clone)]
pub struct MeasureConfig {
    /// Untimed executions before sampling.
    pub warmup: usize,
    /// Initial number of timed repetitions.
    pub reps: usize,
    /// Extend sampling (doubling) until `rel_spread` <= this or `max_reps`.
    pub target_rel_spread: f64,
    /// Hard cap on total timed repetitions.
    pub max_reps: usize,
    /// MAD multiplier for one-sided outlier rejection (0 = keep all).
    pub outlier_k: f64,
}

impl Default for MeasureConfig {
    fn default() -> Self {
        MeasureConfig {
            warmup: 2,
            reps: 7,
            target_rel_spread: 0.10,
            max_reps: 28,
            outlier_k: 5.0,
        }
    }
}

impl MeasureConfig {
    /// Fast profile for tests and smoke runs.
    pub fn quick() -> MeasureConfig {
        MeasureConfig {
            warmup: 1,
            reps: 3,
            target_rel_spread: 1.0,
            max_reps: 3,
            outlier_k: 0.0,
        }
    }
}

/// A completed measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Robust summary over (outlier-filtered) samples, seconds.
    pub summary: Summary,
    /// Raw samples in collection order, seconds.
    pub samples: Vec<f64>,
}

impl Measurement {
    /// The scalar the tuner optimizes.
    pub fn cost(&self) -> f64 {
        self.summary.median
    }

    /// Effective GFLOP/s given the workload's flop count.
    pub fn gflops(&self, flops: u64) -> f64 {
        flops as f64 / self.summary.median / 1e9
    }

    /// Effective GiB/s given the workload's bytes-moved estimate.
    pub fn gibps(&self, bytes: u64) -> f64 {
        bytes as f64 / self.summary.median / (1024.0 * 1024.0 * 1024.0)
    }
}

/// Measure one executable over fixed inputs.
pub fn measure(
    exe: &Executable,
    inputs: &[TensorData],
    cfg: &MeasureConfig,
) -> Result<Measurement> {
    // Literal conversion happens once, outside the timed region.
    let literals = inputs
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<Vec<_>>>()?;

    for _ in 0..cfg.warmup {
        exe.run_literals(&literals)?;
    }

    let mut samples = Vec::with_capacity(cfg.reps);
    let mut quota = cfg.reps.max(1);
    loop {
        while samples.len() < quota {
            let t0 = Instant::now();
            let out = exe.run_literals(&literals)?;
            let dt = t0.elapsed().as_secs_f64();
            std::hint::black_box(&out);
            samples.push(dt);
        }
        let summary = Summary::from_samples(&samples)
            .ok_or_else(|| anyhow::anyhow!("degenerate timing sample"))?;
        if summary.rel_spread() <= cfg.target_rel_spread || quota >= cfg.max_reps {
            break;
        }
        quota = (quota * 2).min(cfg.max_reps);
    }

    let filtered = if cfg.outlier_k > 0.0 {
        reject_outliers(&samples, cfg.outlier_k)
    } else {
        samples.clone()
    };
    let summary = Summary::from_samples(&filtered)
        .ok_or_else(|| anyhow::anyhow!("degenerate timing sample"))?;
    Ok(Measurement { summary, samples })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let c = MeasureConfig::default();
        assert!(c.warmup >= 1);
        assert!(c.reps >= 3);
        assert!(c.max_reps >= c.reps);
        assert!(c.target_rel_spread > 0.0);
    }

    #[test]
    fn quick_config_is_cheap() {
        let c = MeasureConfig::quick();
        assert!(c.warmup <= 1);
        assert!(c.max_reps <= 5);
    }

    #[test]
    fn measurement_derivations() {
        let samples = vec![2e-3, 1e-3, 3e-3];
        let m = Measurement {
            summary: Summary::from_samples(&samples).unwrap(),
            samples,
        };
        assert_eq!(m.cost(), 2e-3);
        // 2 GFLOP in 2ms = 1000 GFLOP/s.
        assert!((m.gflops(2_000_000_000) - 1000.0).abs() < 1e-9);
        // 2 GiB in 2 ms = 1000 GiB/s.
        let gib = m.gibps(2 * 1024 * 1024 * 1024);
        assert!((gib - 1000.0).abs() < 1e-9);
    }
}
