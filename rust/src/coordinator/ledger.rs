//! The core-hour ledger: ROI accounting for every tuning decision.
//!
//! The paper's premise is that autotuning exists to protect a scarce
//! core-hour budget — so the store must be able to say whether tuning
//! *paid for itself*, per (platform, kernel), not just how fast it
//! serves.  Each shard carries a [`Ledger`]: per-kernel cells that
//! accumulate tuning **spend** (compile + measure + sweep wall time,
//! reported by whoever did the work) and realized **benefit**
//! (baseline-vs-best saving multiplied by the live invocation counts
//! flowing through `record`).  A kernel *breaks even* once its
//! accumulated benefit covers its accumulated spend.
//!
//! Units are integer **core-milliseconds** throughout.  Integer sums
//! are exact, so concurrent accrual through the shard store's locked
//! read-merge-rename commits loses nothing (`tests/prop_ledger.rs`
//! proves the exact-sum claim under 8-thread recording), and the
//! cross-store [`merge`](Ledger::merge) is a commutative, associative,
//! idempotent join — re-importing the same bundle can never
//! double-count a core-second.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// One accrual against a kernel's ledger cell: what a single `record`
/// (or portfolio report) contributes.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LedgerDelta {
    /// Kernel family the work belongs to.
    pub kernel: String,
    /// Tuning cost in core-milliseconds (compile + measure + sweep
    /// wall time for the work this record reports).
    pub spend_ms: u64,
    /// Realized saving in core-milliseconds: (baseline − best) × the
    /// invocations this record represents.
    pub benefit_ms: u64,
    /// Live invocations this record represents.
    pub invocations: u64,
    /// Unix second of the accrual (stamps the cell's activity window).
    pub at: u64,
}

/// Accumulated spend/benefit for one kernel on one platform.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LedgerCell {
    /// Total tuning spend, core-milliseconds.
    pub spend_ms: u64,
    /// Total realized benefit, core-milliseconds.
    pub benefit_ms: u64,
    /// Total live invocations accounted.
    pub invocations: u64,
    /// Accruals that carried nonzero spend (≈ tuning runs paid for).
    pub tunes: u64,
    /// Unix second of the first accrual (0 = never).
    pub first_at: u64,
    /// Unix second of the newest accrual.
    pub updated_at: u64,
}

impl LedgerCell {
    /// Net position in core-milliseconds (positive once tuning paid
    /// for itself).
    pub fn net_ms(&self) -> i64 {
        self.benefit_ms as i64 - self.spend_ms as i64
    }

    /// Whether accumulated benefit covers accumulated spend.  A cell
    /// with no spend has nothing to break even *from* and reports
    /// `false` — "free" benefit is not ROI.
    pub fn break_even(&self) -> bool {
        self.spend_ms > 0 && self.benefit_ms >= self.spend_ms
    }

    /// Seconds until break-even at the observed benefit rate, `None`
    /// when already even or when no rate is observable yet.
    pub fn break_even_eta_s(&self) -> Option<u64> {
        if self.break_even() || self.spend_ms == 0 {
            return None;
        }
        let window_s = self.updated_at.saturating_sub(self.first_at).max(1);
        if self.benefit_ms == 0 {
            return None;
        }
        let deficit_ms = self.spend_ms - self.benefit_ms;
        // deficit / (benefit per second), rounded up.
        Some((deficit_ms.saturating_mul(window_s)).div_ceil(self.benefit_ms))
    }

    /// Apply one accrual (exact integer sums).
    fn apply(&mut self, d: &LedgerDelta) {
        self.spend_ms += d.spend_ms;
        self.benefit_ms += d.benefit_ms;
        self.invocations += d.invocations;
        if d.spend_ms > 0 {
            self.tunes += 1;
        }
        if d.at > 0 {
            self.first_at = if self.first_at == 0 { d.at } else { self.first_at.min(d.at) };
            self.updated_at = self.updated_at.max(d.at);
        }
    }

    /// Field-wise join with another cell (see [`Ledger::merge`]).
    fn join(&mut self, other: &LedgerCell) {
        self.spend_ms = self.spend_ms.max(other.spend_ms);
        self.benefit_ms = self.benefit_ms.max(other.benefit_ms);
        self.invocations = self.invocations.max(other.invocations);
        self.tunes = self.tunes.max(other.tunes);
        self.first_at = match (self.first_at, other.first_at) {
            (0, b) => b,
            (a, 0) => a,
            (a, b) => a.min(b),
        };
        self.updated_at = self.updated_at.max(other.updated_at);
    }

    fn to_json(&self) -> Json {
        json::obj(vec![
            ("spend_ms", json::int(self.spend_ms as i64)),
            ("benefit_ms", json::int(self.benefit_ms as i64)),
            ("invocations", json::int(self.invocations as i64)),
            ("tunes", json::int(self.tunes as i64)),
            ("first_at", json::int(self.first_at as i64)),
            ("updated_at", json::int(self.updated_at as i64)),
        ])
    }

    fn from_json(v: &Json) -> Result<LedgerCell> {
        let gi = |k: &str| -> Result<u64> {
            v.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow::anyhow!("ledger cell missing {k}"))
        };
        Ok(LedgerCell {
            spend_ms: gi("spend_ms")?,
            benefit_ms: gi("benefit_ms")?,
            invocations: gi("invocations")?,
            tunes: gi("tunes")?,
            first_at: gi("first_at")?,
            updated_at: gi("updated_at")?,
        })
    }
}

/// Per-kernel ROI cells for one platform's shard.  Persisted beside
/// `entries` and `portfolios`; absent in pre-ledger shard files
/// (parsing defaults to empty, exactly like `portfolios`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Ledger {
    /// kernel → accumulated cell, sorted (canonical serialization).
    pub cells: BTreeMap<String, LedgerCell>,
}

impl Ledger {
    /// Whether no kernel has accrued anything yet.
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// The cell for a kernel, if it has accrued anything.
    pub fn cell(&self, kernel: &str) -> Option<&LedgerCell> {
        self.cells.get(kernel)
    }

    /// Accrue one delta into its kernel's cell.  Called under the
    /// shard's commit lock, so every delta lands exactly once — sums
    /// stay exact under any writer interleaving.
    pub fn apply(&mut self, delta: &LedgerDelta) {
        if delta.spend_ms == 0 && delta.benefit_ms == 0 && delta.invocations == 0 {
            return;
        }
        self.cells.entry(delta.kernel.clone()).or_default().apply(delta);
    }

    /// Join with another ledger: union of kernels, field-wise max per
    /// cell (`first_at` joins by min).  Commutative, associative, and
    /// idempotent — the shape a cross-store merge needs: importing the
    /// same bundle twice, or in either order, never double-counts.
    /// Monotone counters from the same lineage merge losslessly; truly
    /// divergent histories converge on the larger claim rather than
    /// summing (a sum would double-count the shared prefix).
    pub fn merge(&mut self, other: &Ledger) {
        for (kernel, cell) in &other.cells {
            self.cells.entry(kernel.clone()).or_default().join(cell);
        }
    }

    /// (total spend, total benefit) in core-milliseconds.
    pub fn totals(&self) -> (u64, u64) {
        self.cells.values().fold((0, 0), |(s, b), c| (s + c.spend_ms, b + c.benefit_ms))
    }

    /// Serialize as `{kernel: cell}` (BTreeMap order is canonical).
    pub fn to_json(&self) -> Json {
        Json::Obj(self.cells.iter().map(|(k, c)| (k.clone(), c.to_json())).collect())
    }

    /// Parse the [`to_json`](Self::to_json) form.
    pub fn from_json(v: &Json) -> Result<Ledger> {
        let obj = v.as_obj().ok_or_else(|| anyhow::anyhow!("ledger must be an object"))?;
        let mut cells = BTreeMap::new();
        for (kernel, cell) in obj {
            cells.insert(
                kernel.clone(),
                LedgerCell::from_json(cell).with_context(|| format!("ledger cell {kernel}"))?,
            );
        }
        Ok(Ledger { cells })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(kernel: &str, spend: u64, benefit: u64, inv: u64, at: u64) -> LedgerDelta {
        LedgerDelta {
            kernel: kernel.into(),
            spend_ms: spend,
            benefit_ms: benefit,
            invocations: inv,
            at,
        }
    }

    #[test]
    fn apply_accumulates_exact_sums() {
        let mut l = Ledger::default();
        l.apply(&delta("axpy", 100, 0, 0, 50));
        l.apply(&delta("axpy", 0, 30, 3, 60));
        l.apply(&delta("gemm", 500, 0, 0, 55));
        let axpy = l.cell("axpy").unwrap();
        assert_eq!(axpy.spend_ms, 100);
        assert_eq!(axpy.benefit_ms, 30);
        assert_eq!(axpy.invocations, 3);
        assert_eq!(axpy.tunes, 1, "only the spend-carrying accrual counts as a tune");
        assert_eq!(axpy.first_at, 50);
        assert_eq!(axpy.updated_at, 60);
        assert_eq!(l.totals(), (600, 30));
        // Empty deltas allocate nothing.
        l.apply(&delta("noop", 0, 0, 0, 99));
        assert!(l.cell("noop").is_none());
    }

    #[test]
    fn break_even_semantics() {
        let mut c = LedgerCell::default();
        assert!(!c.break_even(), "an empty cell has not broken even");
        c.apply(&delta("k", 100, 0, 0, 10));
        assert!(!c.break_even());
        assert_eq!(c.net_ms(), -100);
        c.apply(&delta("k", 0, 100, 10, 20));
        assert!(c.break_even());
        assert_eq!(c.net_ms(), 0);
        assert_eq!(c.break_even_eta_s(), None, "already even: no ETA");
        // Benefit-only cells never claim ROI.
        let mut free = LedgerCell::default();
        free.apply(&delta("k", 0, 500, 1, 10));
        assert!(!free.break_even());
    }

    #[test]
    fn eta_projects_the_observed_rate() {
        let mut c = LedgerCell::default();
        c.apply(&delta("k", 1000, 0, 0, 100));
        assert_eq!(c.break_even_eta_s(), None, "no benefit rate observed yet");
        // 400ms of benefit over a 200s window → 2ms/s; 600ms deficit
        // → 300s to even.
        c.apply(&delta("k", 0, 400, 4, 300));
        assert_eq!(c.break_even_eta_s(), Some(300));
    }

    #[test]
    fn merge_is_commutative_associative_idempotent() {
        let mut a = Ledger::default();
        a.apply(&delta("axpy", 100, 40, 4, 50));
        a.apply(&delta("gemm", 900, 0, 0, 70));
        let mut b = Ledger::default();
        b.apply(&delta("axpy", 100, 90, 9, 60));
        b.apply(&delta("dot", 10, 80, 8, 40));
        let mut c = Ledger::default();
        c.apply(&delta("gemm", 900, 300, 30, 90));

        let join = |x: &Ledger, y: &Ledger| {
            let mut out = x.clone();
            out.merge(y);
            out
        };
        assert_eq!(join(&a, &b), join(&b, &a), "commutative");
        assert_eq!(
            join(&join(&a, &b), &c),
            join(&a, &join(&b, &c)),
            "associative"
        );
        assert_eq!(join(&a, &a), a, "idempotent");
        // Union of kernels, max per field, min on first_at.
        let m = join(&a, &b);
        assert_eq!(m.cells.len(), 3);
        let axpy = m.cell("axpy").unwrap();
        assert_eq!(axpy.spend_ms, 100);
        assert_eq!(axpy.benefit_ms, 90);
        assert_eq!(axpy.first_at, 50);
        assert_eq!(axpy.updated_at, 60);
    }

    #[test]
    fn json_round_trips_and_tolerates_absence() {
        let mut l = Ledger::default();
        l.apply(&delta("axpy", 123, 456, 7, 1_700_000_000));
        l.apply(&delta("gemm", 9, 0, 0, 1_700_000_100));
        let back = Ledger::from_json(&json::parse(&l.to_json().compact()).unwrap()).unwrap();
        assert_eq!(back, l);
        assert_eq!(Ledger::from_json(&Json::Obj(Default::default())).unwrap(), Ledger::default());
        assert!(Ledger::from_json(&json::s("nope")).is_err());
    }
}
