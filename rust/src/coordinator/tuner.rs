//! The tuning orchestrator: the paper's §2 pipeline end to end.
//!
//! For one (kernel, workload):
//!   1. generate deterministic inputs (workload module),
//!   2. compile + measure the **baseline** artifact (the un-annotated
//!      reference program); its first warmup execution doubles as the
//!      reference-output capture (no redundant run),
//!   3. drive a search strategy over the variant space; each evaluation
//!      compiles the pre-lowered variant artifact, checks its outputs
//!      against the reference (gate), and measures it,
//!   4. select the best correct variant; optionally persist to the
//!      performance DB keyed by the platform fingerprint.
//!
//! Two drive modes share steps 1–2 and 4:
//! * **serial** (`batch` = 1, the default): the strategy calls back one
//!   config at a time — compile, gate, measure, repeat.
//! * **batched** (`batch` > 1 and the strategy
//!   [`supports_batch`](crate::coordinator::search::SearchStrategy::supports_batch)):
//!   the strategy surfaces whole candidate batches; the batch's
//!   artifacts compile on background threads while the main thread
//!   gates candidates in order, then all gate-passing variants
//!   [`race`](crate::coordinator::measure::race) with interleaved
//!   repetitions and early termination.  Timing stays single-threaded —
//!   only compilation overlaps.  On a stable machine both modes select
//!   the same winner; the batched mode just pays far fewer timed
//!   repetitions ([`TuneStats`] records how many).
//!
//! The tuned result never regresses below baseline: if every variant
//! loses, the baseline itself is reported as the winner (speedup 1.0) —
//! the paper's annotations are semantics-preserving, so falling back to
//! the reference implementation is always available.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::measure::{
    measure, measure_with_outputs, race, MeasureConfig, Measurement,
};
use crate::coordinator::perfdb::{unix_now, DbEntry, PerfDb};
use crate::coordinator::platform::Fingerprint;
use crate::coordinator::search::{drive_batched, SearchStrategy};
use crate::coordinator::selection::{check_outputs, CorrectnessReport, Tolerance};
use crate::coordinator::spec::{Config, TuningSpec};
use crate::runtime::{Executable, Registry, TensorData};
use crate::util::stats::Summary;
use crate::workload;

/// One evaluated variant, as reported in a [`TuneOutcome`].
#[derive(Debug, Clone)]
pub struct VariantResult {
    /// The parameter assignment.
    pub config: Config,
    /// Stable config id.
    pub config_id: String,
    /// Timing (None when compilation or execution failed outright).
    pub measurement: Option<Measurement>,
    /// Gate outcome (None when the variant never executed).
    pub correctness: Option<CorrectnessReport>,
    /// Cost seen by the search (median seconds; +inf if gated/failed).
    pub cost: f64,
}

/// Cost accounting for one tuning run — what the tuning investment was
/// actually spent on.  Threaded into the CLI and the overhead bench so
/// the batched pipeline's savings are visible, not anecdotal.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TuneStats {
    /// Wall-clock spent in XLA compilation for this tune, summed across
    /// threads.  Under prefetch this can exceed the elapsed tuning time
    /// — that surplus is exactly the overlap won by background
    /// compilation.
    pub compile_ms: f64,
    /// Wall-clock spent inside the timed measurement harness.
    pub measure_ms: f64,
    /// Timed repetitions executed (baseline + variants).
    pub reps_timed: u64,
    /// Repetitions avoided: racing cutoffs plus gate-failure skips
    /// (lower bound — skipped adaptive extensions are not counted).
    pub reps_saved: u64,
    /// XLA compilations performed on behalf of this tune.
    pub compiles: u64,
    /// Executable loads served from the compile cache.
    pub cache_hits: u64,
    /// Candidate batches evaluated (0 in serial mode).
    pub batches: u64,
    /// Race lanes stopped early by the cutoff.
    pub pruned: u64,
    /// Variants rejected by the correctness gate; they cost one gate
    /// execution each, never a full measurement.
    pub gated: u64,
}

impl TuneStats {
    /// One-line human rendering for the CLI.
    pub fn render(&self) -> String {
        format!(
            "compile {:.1} ms ({} compiles, {} cache hits) | measure {:.1} ms | \
             reps {} timed, {} saved | {} batches, {} pruned, {} gated",
            self.compile_ms,
            self.compiles,
            self.cache_hits,
            self.measure_ms,
            self.reps_timed,
            self.reps_saved,
            self.batches,
            self.pruned,
            self.gated
        )
    }
}

/// The result of tuning one (kernel, workload).
///
/// Two comparators, matching the paper's experimental setup:
/// * `default` — the **un-annotated schedule** (Figure 1's "no pragmas,
///   just -O3" baseline): the same kernel with the naive parameter
///   choice a programmer writes down,
/// * `reference` — the pure-XLA lowering of the reference program: the
///   vendor-library-grade comparator (the cuSPARSE/CUSP role in the
///   paper's refs [1][2]) and the source of reference outputs for the
///   correctness gate.
#[derive(Debug)]
pub struct TuneOutcome {
    /// Kernel family tuned.
    pub kernel: String,
    /// Workload tag tuned.
    pub tag: String,
    /// Search strategy that drove the run.
    pub strategy: String,
    /// The platform the measurements were taken on.
    pub platform: Fingerprint,
    /// Pure-XLA reference artifact timing.
    pub reference: Measurement,
    /// The default (un-annotated) schedule's evaluation, when the
    /// manifest declares one.
    pub default: Option<VariantResult>,
    /// Best correct variant (None ⇒ nothing passed the gate).
    pub best: Option<VariantResult>,
    /// Every unique evaluation, in search order.
    pub evaluated: Vec<VariantResult>,
    /// Where the tuning time went (compile/measure/reps accounting).
    pub stats: TuneStats,
    /// Flop count of the workload (for roofline reporting).
    pub flops: u64,
    /// Bytes moved by the workload (for roofline reporting).
    pub bytes: u64,
}

impl TuneOutcome {
    /// The paper's baseline time: the un-annotated default schedule
    /// (falls back to the XLA reference when no default is declared).
    pub fn baseline_time(&self) -> f64 {
        match &self.default {
            Some(d) if d.cost.is_finite() => d.cost,
            _ => self.reference.cost(),
        }
    }

    /// The best wall time achieved (tuned, never worse than baseline —
    /// the baseline schedule is itself in the search space).
    pub fn best_time(&self) -> f64 {
        match &self.best {
            Some(b) if b.cost.is_finite() => b.cost.min(self.baseline_time()),
            _ => self.baseline_time(),
        }
    }

    /// Figure 1's headline: autotuned speedup over the un-annotated
    /// baseline (1.0 when the default is already optimal).
    pub fn speedup(&self) -> f64 {
        let best = self.best_time();
        if best > 0.0 {
            self.baseline_time() / best
        } else {
            1.0
        }
    }

    /// Paper Figure 1's bar: time reduction in percent.
    pub fn time_reduction_pct(&self) -> f64 {
        (1.0 - self.best_time() / self.baseline_time()) * 100.0
    }

    /// Autotuned time relative to the vendor-grade XLA reference
    /// (< 1.0 ⇒ the tuned generic kernel beats the library path, the
    /// refs-[1][2] result; ≈ 1.0 ⇒ tuning recovered library-level
    /// performance from a generic kernel).
    pub fn vs_reference(&self) -> f64 {
        let r = self.reference.cost();
        if r > 0.0 {
            self.best_time() / r
        } else {
            f64::INFINITY
        }
    }

    /// Number of unique variant evaluations the search performed.
    pub fn evaluations(&self) -> usize {
        self.evaluated.len()
    }
}

/// Tuning driver bound to a registry.
pub struct Tuner<'a> {
    registry: &'a Registry,
    /// Timing-harness parameters for every measurement in the run.
    pub measure_cfg: MeasureConfig,
    /// Correctness-gate tolerance vs the reference outputs.
    pub tolerance: Tolerance,
    /// Seed for deterministic workload-input generation.
    pub input_seed: u64,
    /// Optional fixed candidate list evaluated before the strategy runs
    /// (perf-DB warm start).
    pub warm_start: Vec<Config>,
    /// Candidates proposed/evaluated per round.  1 = serial pipeline
    /// (strategy-driven, full measurement per variant); > 1 engages the
    /// batched pipeline — overlapped compilation + raced measurement —
    /// for strategies that support batch proposal.
    pub batch: usize,
}

impl<'a> Tuner<'a> {
    /// A tuner with default measurement, tolerance, and serial drive.
    pub fn new(registry: &'a Registry) -> Tuner<'a> {
        Tuner {
            registry,
            measure_cfg: MeasureConfig::default(),
            tolerance: Tolerance::default(),
            input_seed: 0x5EED,
            warm_start: Vec::new(),
            batch: 1,
        }
    }

    /// Builder: replace the measurement config.
    pub fn with_measure_cfg(mut self, cfg: MeasureConfig) -> Self {
        self.measure_cfg = cfg;
        self
    }

    /// Builder: set the warm-start candidate list.
    pub fn with_warm_start(mut self, candidates: Vec<Config>) -> Self {
        self.warm_start = candidates;
        self
    }

    /// Builder: set the per-round candidate batch size (min 1).
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }

    /// Build the searchable spec for a (kernel, workload).
    pub fn spec(&self, kernel: &str, tag: &str) -> Result<TuningSpec> {
        let (entry, wl) = self.registry.find(kernel, tag)?;
        TuningSpec::from_manifest(entry, wl)
    }

    /// Deterministic inputs for a (kernel, workload).
    pub fn inputs(&self, kernel: &str, tag: &str) -> Result<Vec<TensorData>> {
        let (_, wl) = self.registry.find(kernel, tag)?;
        workload::inputs_for(kernel, wl, self.input_seed)
    }

    /// Measure the baseline artifact and capture reference outputs from
    /// its first warmup execution (the baseline used to pay one full
    /// extra untimed execution per tune just to read its outputs).
    pub fn measure_baseline(
        &self,
        kernel: &str,
        tag: &str,
        inputs: &[TensorData],
    ) -> Result<(Measurement, Vec<f32>)> {
        let (_, wl) = self.registry.find(kernel, tag)?;
        let exe = self.registry.load(&wl.baseline)?;
        measure_with_outputs(&exe, inputs, &self.measure_cfg).context("measuring baseline")
    }

    /// Full tuning pipeline (see module docs).
    pub fn tune(
        &self,
        kernel: &str,
        tag: &str,
        strategy: &mut dyn SearchStrategy,
        budget: usize,
    ) -> Result<TuneOutcome> {
        let (entry, wl) = self.registry.find(kernel, tag)?;
        let spec = TuningSpec::from_manifest(entry, wl)?;
        let inputs = workload::inputs_for(kernel, wl, self.input_seed)?;

        // Registry-level counters are deltas over the whole tune so
        // prefetch-thread compilation is attributed correctly.
        let compiles0 = self.registry.compile_count();
        let compile_ms0 = self.registry.compile_ms();
        let hits0 = self.registry.cache_hits();

        let mut stats = TuneStats::default();
        let baseline_exe = self.registry.load(&wl.baseline)?;
        let t0 = Instant::now();
        let (reference, ref_outputs) =
            measure_with_outputs(&baseline_exe, &inputs, &self.measure_cfg)
                .context("measuring baseline")?;
        stats.measure_ms += t0.elapsed().as_secs_f64() * 1e3;
        stats.reps_timed += reference.samples.len() as u64;
        drop(baseline_exe);

        // Variant path lookup keyed by the id derived from the config —
        // manifest variant ids pass through `spec.config_id` on the
        // python side, so both sides agree by construction.
        let paths: BTreeMap<String, String> = wl
            .variants
            .iter()
            .map(|v| (v.id.clone(), v.path.clone()))
            .collect();

        let mut state = EvalState {
            tuner: self,
            spec: &spec,
            paths,
            inputs: &inputs,
            ref_outputs: &ref_outputs,
            seen: BTreeMap::new(),
            evaluated: Vec::new(),
            incumbent: None,
            stats,
        };

        // The un-annotated default schedule is always evaluated first —
        // it is Figure 1's baseline series and must appear in every
        // outcome regardless of where the search wanders.  Its identity
        // is DERIVED from its parameters (`spec.config_id`), not read
        // from the manifest id string, so a manifest id drift can't
        // silently drop the baseline series.
        let default_config = wl
            .default
            .as_deref()
            .and_then(|id| wl.variant(id))
            .map(|v| v.params.clone());
        let default_id = default_config.as_ref().map(|c| spec.config_id(c));
        if let Some(cfg) = &default_config {
            if spec.is_valid(cfg) {
                state.eval_one(cfg);
            }
        }

        // Warm-start candidates (perf-DB transfer) are evaluated next,
        // outside the strategy's budget accounting but inside ours.
        for cand in &self.warm_start {
            if spec.is_valid(cand) {
                state.eval_one(cand);
            }
        }

        // Drive the search: batched when both sides can, serial
        // otherwise.  Result history is retained via `evaluated`.
        if self.batch > 1 && strategy.supports_batch() {
            let preseeded: Vec<(Config, f64)> = state
                .evaluated
                .iter()
                .map(|v| (v.config.clone(), v.cost))
                .collect();
            let state_ref = &mut state;
            let mut eval_batch = |batch: &[Config]| state_ref.eval_batch(batch);
            let _ = drive_batched(
                strategy,
                &spec,
                budget,
                self.batch,
                &preseeded,
                &mut eval_batch,
            );
        } else {
            let state_ref = &mut state;
            let mut eval = |config: &Config| state_ref.eval_one(config);
            let _ = strategy.run(&spec, budget, &mut eval);
        }

        let EvalState { evaluated, mut stats, .. } = state;
        stats.compiles = self.registry.compile_count() - compiles0;
        stats.compile_ms = self.registry.compile_ms() - compile_ms0;
        stats.cache_hits = self.registry.cache_hits() - hits0;

        let default = default_id
            .and_then(|id| evaluated.iter().find(|v| v.config_id == id).cloned());

        // Pick the best correct evaluation across default + warm start +
        // search.
        let best = evaluated
            .iter()
            .filter(|v| v.cost.is_finite())
            .min_by(|a, b| a.cost.total_cmp(&b.cost))
            .cloned();

        Ok(TuneOutcome {
            kernel: kernel.to_string(),
            tag: tag.to_string(),
            strategy: strategy.name().to_string(),
            platform: Fingerprint::detect(),
            reference,
            default,
            best,
            evaluated,
            stats,
            flops: wl.flops,
            bytes: wl.bytes,
        })
    }

    /// The DB entry an outcome persists as — shared by the legacy
    /// single-file path ([`record`](Self::record)) and the daemon's
    /// shard store (`ShardedDb::record` in the serve re-tune worker).
    pub fn entry_for(&self, outcome: &TuneOutcome) -> DbEntry {
        let (config, config_id, best_time) = match &outcome.best {
            Some(b) if b.cost.is_finite() => {
                (b.config.clone(), b.config_id.clone(), b.cost)
            }
            _ => (Config::new(), "baseline".to_string(), outcome.baseline_time()),
        };
        DbEntry {
            platform_key: outcome.platform.key(),
            kernel: outcome.kernel.clone(),
            tag: outcome.tag.clone(),
            best_params: config,
            best_config_id: config_id,
            best_time_s: best_time,
            baseline_time_s: outcome.baseline_time(),
            reference_time_s: outcome.reference.cost(),
            evaluations: outcome.evaluations() as u64,
            strategy: outcome.strategy.clone(),
            recorded_at: unix_now(),
        }
    }

    /// Persist an outcome into a performance database.
    pub fn record(&self, db: &mut PerfDb, outcome: &TuneOutcome) {
        db.record(self.entry_for(outcome));
    }

    /// Seed the warm start from transfer-ranked candidates (nearest
    /// platform first — `service::transfer::rank_candidates` order).
    /// Order is preserved, duplicate configs collapse, and the list is
    /// capped: the warm start is a seeding heuristic, and evaluating an
    /// unbounded transfer set would turn it back into a search.
    pub fn seed_warm_start(
        &mut self,
        ranked: impl IntoIterator<Item = Config>,
        cap: usize,
    ) -> usize {
        let mut seen = std::collections::BTreeSet::new();
        self.warm_start = ranked
            .into_iter()
            .filter(|c| {
                let key: Vec<(String, i64)> =
                    c.iter().map(|(k, v)| (k.clone(), *v)).collect();
                seen.insert(key)
            })
            .take(cap)
            .collect();
        self.warm_start.len()
    }

    /// Deploy path: answer "which artifact should production run?" from
    /// the DB without any measurement.  Falls back to baseline when the
    /// platform has no record.
    pub fn deployed_artifact(&self, db: &PerfDb, kernel: &str, tag: &str) -> Result<String> {
        let (_, wl) = self.registry.find(kernel, tag)?;
        let key = Fingerprint::detect().key();
        match db.lookup(&key, kernel, tag) {
            Some(e) if e.best_config_id != "baseline" => wl
                .variant(&e.best_config_id)
                .map(|v| v.path.clone())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "perf DB references variant {} absent from artifacts",
                        e.best_config_id
                    )
                }),
            _ => Ok(wl.baseline.clone()),
        }
    }
}

/// One candidate's gate outcome inside a batch.
struct Gated {
    batch_index: usize,
    exe: Arc<Executable>,
    correctness: CorrectnessReport,
}

/// Mutable evaluation context shared by the serial and batched drives:
/// tuner-level dedupe (forced default / warm-start evals run outside
/// the strategy's own accounting, so repeats must be served from here),
/// the evaluation log, the racing incumbent, and cost accounting.
struct EvalState<'b, 'a> {
    tuner: &'b Tuner<'a>,
    spec: &'b TuningSpec,
    /// config id → artifact path.
    paths: BTreeMap<String, String>,
    inputs: &'b [TensorData],
    ref_outputs: &'b [f32],
    seen: BTreeMap<String, f64>,
    evaluated: Vec<VariantResult>,
    /// Best finite cost so far — the racing cutoff's external bar.
    incumbent: Option<f64>,
    stats: TuneStats,
}

impl EvalState<'_, '_> {
    fn record(&mut self, vr: VariantResult) -> f64 {
        let cost = vr.cost;
        if cost.is_finite() {
            self.incumbent = Some(self.incumbent.map_or(cost, |b| b.min(cost)));
        }
        self.seen.insert(vr.config_id.clone(), cost);
        self.evaluated.push(vr);
        cost
    }

    fn failed(config: &Config, config_id: String) -> VariantResult {
        VariantResult {
            config: config.clone(),
            config_id,
            measurement: None,
            correctness: None,
            cost: f64::INFINITY,
        }
    }

    /// Load + execute-for-outputs + gate one variant.  The gate
    /// execution is timed so rejected variants still show how fast the
    /// wrong answer was, and doubles as warmup #1 for measurement.
    fn gate(&mut self, config_id: &str) -> Result<(Arc<Executable>, CorrectnessReport, f64)> {
        let path = self
            .paths
            .get(config_id)
            .ok_or_else(|| anyhow::anyhow!("no pre-lowered artifact for variant {config_id}"))?
            .clone();
        let exe = self.tuner.registry.load(&path)?;
        let t0 = Instant::now();
        let outputs = exe.run(self.inputs)?;
        let gate_dt = t0.elapsed().as_secs_f64();
        let correctness = check_outputs(&outputs, self.ref_outputs, self.tuner.tolerance);
        Ok((exe, correctness, gate_dt))
    }

    /// Gate-failure result: one timed gate sample, infinite cost, and
    /// the full measurement the seed pipeline would have paid is
    /// recorded as saved.
    fn gated_result(
        &mut self,
        config: &Config,
        config_id: String,
        correctness: CorrectnessReport,
        gate_dt: f64,
    ) -> VariantResult {
        self.stats.gated += 1;
        self.stats.reps_saved += self.tuner.measure_cfg.reps as u64;
        let summary = Summary::from_samples(&[gate_dt]).expect("single gate sample");
        VariantResult {
            config: config.clone(),
            config_id,
            measurement: Some(Measurement { summary, samples: vec![gate_dt] }),
            correctness: Some(correctness),
            cost: f64::INFINITY,
        }
    }

    /// Measurement config for post-gate sampling: the gate execution
    /// already served as warmup #1.
    fn post_gate_cfg(&self) -> MeasureConfig {
        let mut cfg = self.tuner.measure_cfg.clone();
        cfg.warmup = cfg.warmup.saturating_sub(1);
        cfg
    }

    /// Serial evaluation of one config (compile → gate → full measure).
    fn eval_one(&mut self, config: &Config) -> f64 {
        let config_id = self.spec.config_id(config);
        if let Some(&cost) = self.seen.get(&config_id) {
            return cost;
        }
        let vr = match self.gate(&config_id) {
            Ok((exe, correctness, gate_dt)) => {
                if !correctness.ok {
                    self.gated_result(config, config_id, correctness, gate_dt)
                } else {
                    let cfg = self.post_gate_cfg();
                    let t0 = Instant::now();
                    match measure(&exe, self.inputs, &cfg) {
                        Ok(m) => {
                            self.stats.measure_ms += t0.elapsed().as_secs_f64() * 1e3;
                            self.stats.reps_timed += m.samples.len() as u64;
                            VariantResult {
                                config: config.clone(),
                                config_id,
                                measurement: Some(m.clone()),
                                correctness: Some(correctness),
                                cost: m.cost(),
                            }
                        }
                        Err(_) => Self::failed(config, config_id),
                    }
                }
            }
            Err(_) => Self::failed(config, config_id),
        };
        self.record(vr)
    }

    /// Batched evaluation: prefetch the batch's artifacts on background
    /// threads, gate candidates in order on the main thread (overlapping
    /// the later candidates' compilation), then race every gate-passing
    /// variant with interleaved timing and early termination.
    fn eval_batch(&mut self, batch: &[Config]) -> Vec<f64> {
        self.stats.batches += 1;
        let ids: Vec<String> = batch.iter().map(|c| self.spec.config_id(c)).collect();
        let fetch: Vec<String> =
            ids.iter().filter_map(|id| self.paths.get(id).cloned()).collect();
        let prefetch = self.tuner.registry.prefetch(&fetch);

        // Gate pass: each `load` waits only for its own artifact while
        // the pool keeps compiling the rest behind it.
        let mut results: Vec<Option<VariantResult>> = vec![None; batch.len()];
        let mut racers: Vec<Gated> = Vec::new();
        for (i, (config, config_id)) in batch.iter().zip(&ids).enumerate() {
            match self.gate(config_id) {
                Ok((exe, correctness, gate_dt)) => {
                    if !correctness.ok {
                        results[i] = Some(self.gated_result(
                            config,
                            config_id.clone(),
                            correctness,
                            gate_dt,
                        ));
                    } else {
                        racers.push(Gated { batch_index: i, exe, correctness });
                    }
                }
                Err(_) => results[i] = Some(Self::failed(config, config_id.clone())),
            }
        }
        // Quiesce the pool before timing anything: racing against live
        // compile threads would corrupt the measurements.
        prefetch.wait();

        if !racers.is_empty() {
            let cfg = self.post_gate_cfg();
            let exe_refs: Vec<&Executable> =
                racers.iter().map(|g| g.exe.as_ref()).collect();
            let t0 = Instant::now();
            match race(&exe_refs, self.inputs, &cfg, self.incumbent) {
                Ok(out) => {
                    self.stats.measure_ms += t0.elapsed().as_secs_f64() * 1e3;
                    self.stats.reps_timed += out.reps_timed;
                    self.stats.reps_saved += out.reps_saved;
                    self.stats.pruned += out.pruned;
                    for (lane, g) in racers.iter().enumerate() {
                        let i = g.batch_index;
                        let errored = out.lanes[lane].errored;
                        let m = out.measurements[lane].clone();
                        let cost = match (&m, errored) {
                            (Some(m), false) => m.cost(),
                            _ => f64::INFINITY,
                        };
                        results[i] = Some(VariantResult {
                            config: batch[i].clone(),
                            config_id: ids[i].clone(),
                            measurement: m,
                            correctness: Some(g.correctness.clone()),
                            cost,
                        });
                    }
                }
                Err(_) => {
                    for g in &racers {
                        let i = g.batch_index;
                        results[i] = Some(Self::failed(&batch[i], ids[i].clone()));
                    }
                }
            }
        }

        results
            .into_iter()
            .enumerate()
            .map(|(i, vr)| {
                let vr = vr.unwrap_or_else(|| Self::failed(&batch[i], ids[i].clone()));
                self.record(vr)
            })
            .collect()
    }
}
