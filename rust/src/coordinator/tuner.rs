//! The tuning orchestrator: the paper's §2 pipeline end to end.
//!
//! For one (kernel, workload):
//!   1. generate deterministic inputs (workload module),
//!   2. compile + measure the **baseline** artifact (the un-annotated
//!      reference program) and capture its outputs as reference results,
//!   3. drive a search strategy over the variant space; each evaluation
//!      compiles the pre-lowered variant artifact, checks its outputs
//!      against the reference (gate), and measures it,
//!   4. select the best correct variant; optionally persist to the
//!      performance DB keyed by the platform fingerprint.
//!
//! The tuned result never regresses below baseline: if every variant
//! loses, the baseline itself is reported as the winner (speedup 1.0) —
//! the paper's annotations are semantics-preserving, so falling back to
//! the reference implementation is always available.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::coordinator::measure::{measure, MeasureConfig, Measurement};
use crate::coordinator::perfdb::{unix_now, DbEntry, PerfDb};
use crate::coordinator::platform::Fingerprint;
use crate::coordinator::search::{SearchResult, SearchStrategy};
use crate::coordinator::selection::{check_outputs, CorrectnessReport, Tolerance};
use crate::coordinator::spec::{Config, TuningSpec};
use crate::runtime::{Registry, TensorData};
use crate::workload;

/// One evaluated variant, as reported in a [`TuneOutcome`].
#[derive(Debug, Clone)]
pub struct VariantResult {
    pub config: Config,
    pub config_id: String,
    pub measurement: Option<Measurement>,
    pub correctness: Option<CorrectnessReport>,
    /// Cost seen by the search (median seconds; +inf if gated/failed).
    pub cost: f64,
}

/// The result of tuning one (kernel, workload).
///
/// Two comparators, matching the paper's experimental setup:
/// * `default` — the **un-annotated schedule** (Figure 1's "no pragmas,
///   just -O3" baseline): the same kernel with the naive parameter
///   choice a programmer writes down,
/// * `reference` — the pure-XLA lowering of the reference program: the
///   vendor-library-grade comparator (the cuSPARSE/CUSP role in the
///   paper's refs [1][2]) and the source of reference outputs for the
///   correctness gate.
#[derive(Debug)]
pub struct TuneOutcome {
    pub kernel: String,
    pub tag: String,
    pub strategy: String,
    pub platform: Fingerprint,
    /// Pure-XLA reference artifact timing.
    pub reference: Measurement,
    /// The default (un-annotated) schedule's evaluation, when the
    /// manifest declares one.
    pub default: Option<VariantResult>,
    /// Best correct variant (None ⇒ nothing passed the gate).
    pub best: Option<VariantResult>,
    /// Every unique evaluation, in search order.
    pub evaluated: Vec<VariantResult>,
    /// flops/bytes of the workload (for roofline reporting).
    pub flops: u64,
    pub bytes: u64,
}

impl TuneOutcome {
    /// The paper's baseline time: the un-annotated default schedule
    /// (falls back to the XLA reference when no default is declared).
    pub fn baseline_time(&self) -> f64 {
        match &self.default {
            Some(d) if d.cost.is_finite() => d.cost,
            _ => self.reference.cost(),
        }
    }

    /// The best wall time achieved (tuned, never worse than baseline —
    /// the baseline schedule is itself in the search space).
    pub fn best_time(&self) -> f64 {
        match &self.best {
            Some(b) if b.cost.is_finite() => b.cost.min(self.baseline_time()),
            _ => self.baseline_time(),
        }
    }

    /// Figure 1's headline: autotuned speedup over the un-annotated
    /// baseline (1.0 when the default is already optimal).
    pub fn speedup(&self) -> f64 {
        let best = self.best_time();
        if best > 0.0 {
            self.baseline_time() / best
        } else {
            1.0
        }
    }

    /// Paper Figure 1's bar: time reduction in percent.
    pub fn time_reduction_pct(&self) -> f64 {
        (1.0 - self.best_time() / self.baseline_time()) * 100.0
    }

    /// Autotuned time relative to the vendor-grade XLA reference
    /// (< 1.0 ⇒ the tuned generic kernel beats the library path, the
    /// refs-[1][2] result; ≈ 1.0 ⇒ tuning recovered library-level
    /// performance from a generic kernel).
    pub fn vs_reference(&self) -> f64 {
        let r = self.reference.cost();
        if r > 0.0 {
            self.best_time() / r
        } else {
            f64::INFINITY
        }
    }

    pub fn evaluations(&self) -> usize {
        self.evaluated.len()
    }
}

/// Tuning driver bound to a registry.
pub struct Tuner<'a> {
    registry: &'a Registry,
    pub measure_cfg: MeasureConfig,
    pub tolerance: Tolerance,
    pub input_seed: u64,
    /// Optional fixed candidate list evaluated before the strategy runs
    /// (perf-DB warm start).
    pub warm_start: Vec<Config>,
}

impl<'a> Tuner<'a> {
    pub fn new(registry: &'a Registry) -> Tuner<'a> {
        Tuner {
            registry,
            measure_cfg: MeasureConfig::default(),
            tolerance: Tolerance::default(),
            input_seed: 0x5EED,
            warm_start: Vec::new(),
        }
    }

    pub fn with_measure_cfg(mut self, cfg: MeasureConfig) -> Self {
        self.measure_cfg = cfg;
        self
    }

    pub fn with_warm_start(mut self, candidates: Vec<Config>) -> Self {
        self.warm_start = candidates;
        self
    }

    /// Build the searchable spec for a (kernel, workload).
    pub fn spec(&self, kernel: &str, tag: &str) -> Result<TuningSpec> {
        let (entry, wl) = self.registry.find(kernel, tag)?;
        TuningSpec::from_manifest(entry, wl)
    }

    /// Deterministic inputs for a (kernel, workload).
    pub fn inputs(&self, kernel: &str, tag: &str) -> Result<Vec<TensorData>> {
        let (_, wl) = self.registry.find(kernel, tag)?;
        workload::inputs_for(kernel, wl, self.input_seed)
    }

    /// Measure the baseline artifact and capture reference outputs.
    pub fn measure_baseline(
        &self,
        kernel: &str,
        tag: &str,
        inputs: &[TensorData],
    ) -> Result<(Measurement, Vec<f32>)> {
        let (_, wl) = self.registry.find(kernel, tag)?;
        let exe = self.registry.load(&wl.baseline)?;
        let reference = exe.run(inputs).context("running baseline")?;
        let m = measure(&exe, inputs, &self.measure_cfg)?;
        Ok((m, reference))
    }

    /// Full tuning pipeline (see module docs).
    pub fn tune(
        &self,
        kernel: &str,
        tag: &str,
        strategy: &mut dyn SearchStrategy,
        budget: usize,
    ) -> Result<TuneOutcome> {
        let (entry, wl) = self.registry.find(kernel, tag)?;
        let spec = TuningSpec::from_manifest(entry, wl)?;
        let inputs = workload::inputs_for(kernel, wl, self.input_seed)?;
        let (reference, ref_outputs) = self.measure_baseline(kernel, tag, &inputs)?;

        // Variant path lookup by config id.
        let paths: BTreeMap<&str, &str> = wl
            .variants
            .iter()
            .map(|v| (v.id.as_str(), v.path.as_str()))
            .collect();

        // Tuner-level dedupe: the forced default / warm-start evals run
        // outside the strategy's own budget cache, so repeats must be
        // served from here — `evaluated` holds unique measurements only.
        let mut seen: BTreeMap<String, f64> = BTreeMap::new();
        let mut evaluated: Vec<VariantResult> = Vec::new();
        let mut eval = |config: &Config| -> f64 {
            let config_id = spec.config_id(config);
            if let Some(&cost) = seen.get(&config_id) {
                return cost;
            }
            let result = self.evaluate_variant(
                &config_id,
                &paths,
                &inputs,
                &ref_outputs,
            );
            let vr = match result {
                Ok((m, c)) => {
                    let cost = if c.ok { m.cost() } else { f64::INFINITY };
                    VariantResult {
                        config: config.clone(),
                        config_id,
                        measurement: Some(m),
                        correctness: Some(c),
                        cost,
                    }
                }
                Err(_) => VariantResult {
                    config: config.clone(),
                    config_id,
                    measurement: None,
                    correctness: None,
                    cost: f64::INFINITY,
                },
            };
            let cost = vr.cost;
            seen.insert(vr.config_id.clone(), cost);
            evaluated.push(vr);
            cost
        };

        // The un-annotated default schedule is always evaluated first —
        // it is Figure 1's baseline series and must appear in every
        // outcome regardless of where the search wanders.
        let default_config = wl
            .default
            .as_deref()
            .and_then(|id| wl.variant(id))
            .map(|v| v.params.clone());
        if let Some(cfg) = &default_config {
            if spec.is_valid(cfg) {
                eval(cfg);
            }
        }

        // Warm-start candidates (perf-DB transfer) are evaluated next,
        // outside the strategy's budget accounting but inside ours.
        for cand in &self.warm_start {
            if spec.is_valid(cand) {
                eval(cand);
            }
        }

        let result: SearchResult = strategy.run(&spec, budget, &mut eval);
        drop(eval);
        let _ = result; // history retained via `evaluated`

        let default = wl.default.as_deref().and_then(|id| {
            evaluated.iter().find(|v| v.config_id == id).cloned()
        });

        // Pick the best correct evaluation across default + warm start +
        // search.
        let best = evaluated
            .iter()
            .filter(|v| v.cost.is_finite())
            .min_by(|a, b| a.cost.total_cmp(&b.cost))
            .cloned();

        Ok(TuneOutcome {
            kernel: kernel.to_string(),
            tag: tag.to_string(),
            strategy: strategy.name().to_string(),
            platform: Fingerprint::detect(),
            reference,
            default,
            best,
            evaluated,
            flops: wl.flops,
            bytes: wl.bytes,
        })
    }

    fn evaluate_variant(
        &self,
        config_id: &str,
        paths: &BTreeMap<&str, &str>,
        inputs: &[TensorData],
        reference: &[f32],
    ) -> Result<(Measurement, CorrectnessReport)> {
        let path = paths
            .get(config_id)
            .ok_or_else(|| anyhow::anyhow!("no pre-lowered artifact for variant {config_id}"))?;
        let exe = self.registry.load(path)?;
        let outputs = exe.run(inputs)?;
        let correctness = check_outputs(&outputs, reference, self.tolerance);
        // Measure even gated variants (cheap at quick profiles; the
        // report shows *why* a fast-but-wrong variant was rejected).
        let measurement = measure(&exe, inputs, &self.measure_cfg)?;
        Ok((measurement, correctness))
    }

    /// Persist an outcome into a performance database.
    pub fn record(&self, db: &mut PerfDb, outcome: &TuneOutcome) {
        let (config, config_id, best_time) = match &outcome.best {
            Some(b) if b.cost.is_finite() => {
                (b.config.clone(), b.config_id.clone(), b.cost)
            }
            _ => (Config::new(), "baseline".to_string(), outcome.baseline_time()),
        };
        db.record(DbEntry {
            platform_key: outcome.platform.key(),
            kernel: outcome.kernel.clone(),
            tag: outcome.tag.clone(),
            best_params: config,
            best_config_id: config_id,
            best_time_s: best_time,
            baseline_time_s: outcome.baseline_time(),
            reference_time_s: outcome.reference.cost(),
            evaluations: outcome.evaluations() as u64,
            strategy: outcome.strategy.clone(),
            recorded_at: unix_now(),
        });
    }

    /// Deploy path: answer "which artifact should production run?" from
    /// the DB without any measurement.  Falls back to baseline when the
    /// platform has no record.
    pub fn deployed_artifact(&self, db: &PerfDb, kernel: &str, tag: &str) -> Result<String> {
        let (_, wl) = self.registry.find(kernel, tag)?;
        let key = Fingerprint::detect().key();
        match db.lookup(&key, kernel, tag) {
            Some(e) if e.best_config_id != "baseline" => wl
                .variant(&e.best_config_id)
                .map(|v| v.path.clone())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "perf DB references variant {} absent from artifacts",
                        e.best_config_id
                    )
                }),
            _ => Ok(wl.baseline.clone()),
        }
    }
}
