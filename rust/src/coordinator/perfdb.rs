//! Persistent performance database — what makes tuning *sustainable*.
//!
//! Every completed tuning run records (platform key, kernel, workload) →
//! best configuration + timings.  On a known platform the deployment
//! path skips search entirely; on a new platform, entries from other
//! platforms seed the search (warm start), which the portability
//! experiment (A3) shows reaches near-optimum in a handful of
//! evaluations.  The paper: "specialization of programs to platforms ...
//! across various systems and system changes."
//!
//! Two storage formats coexist:
//!
//! * **v1 (legacy)** — [`PerfDb`]: a single JSON document, written
//!   atomically (tmp + rename).  Saves now *merge* with the on-disk
//!   document under a lock file instead of last-writer-wins, so two
//!   processes tuning concurrently cannot erase each other's records.
//! * **v2 (sharded)** — [`ShardedDb`]: one shard file per platform key
//!   in a directory, each holding the platform's [`Fingerprint`] (for
//!   the transfer engine) and the full per-(kernel, workload) *history*
//!   of entries rather than only the newest.  Writes are
//!   lock-file-guarded read-merge-rename, so any number of concurrent
//!   writers (threads or processes) lose nothing.  `portatune serve`
//!   is backed by this store; `ShardedDb::import_legacy` migrates a v1
//!   file into shards.
//!
//! **Crash safety (v2).**  New shard files carry a one-line content
//! checksum header over the document body, so a torn write (power
//! loss, ENOSPC, a crashed writer) is *detected* rather than parsed
//! into garbage.  A shard that fails the checksum — or fails to parse
//! at all — is quarantined to `<shard>.corrupt` and treated as absent:
//! reads degrade to a miss, and the next write rebuilds the shard
//! from the merge path instead of erroring forever.  Acknowledged
//! records are never lost to this: the commit protocol writes a tmp
//! file and renames, so a crash mid-write leaves the published shard
//! untouched (and the writer unacknowledged).  Headerless files
//! written by older versions still parse — the checksum is only
//! verified when the header is present.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::ledger::{Ledger, LedgerDelta};
use crate::coordinator::platform::Fingerprint;
use crate::coordinator::portfolio::Portfolio;
use crate::coordinator::spec::Config;
use crate::util::json::{self, Json};

/// One tuning record.
#[derive(Debug, Clone, PartialEq)]
pub struct DbEntry {
    /// Platform key the measurements were taken under.
    pub platform_key: String,
    /// Kernel family.
    pub kernel: String,
    /// Workload tag.
    pub tag: String,
    /// Winning parameter assignment.
    pub best_params: Config,
    /// Winning config id (`"baseline"` when nothing beat it).
    pub best_config_id: String,
    /// Median seconds of the winning variant.
    pub best_time_s: f64,
    /// Median seconds of the un-annotated default schedule (Figure 1's
    /// baseline) on the same inputs.
    pub baseline_time_s: f64,
    /// Median seconds of the pure-XLA reference artifact.
    pub reference_time_s: f64,
    /// Unique (compile+measure) evaluations the search spent.
    pub evaluations: u64,
    /// Strategy name that produced this entry.
    pub strategy: String,
    /// Unix seconds when recorded.
    pub recorded_at: u64,
}

impl DbEntry {
    /// Baseline time over best time (1.0 when degenerate).
    pub fn speedup(&self) -> f64 {
        if self.best_time_s > 0.0 {
            self.baseline_time_s / self.best_time_s
        } else {
            0.0
        }
    }

    /// The replacement key for v1 semantics (newest per triple wins).
    pub fn triple_key(&self) -> String {
        joined_key(&[&self.platform_key, &self.kernel, &self.tag])
    }

    /// Identity inside a shard's history: two entries are the same
    /// observation iff platform, kernel, workload, winning config,
    /// strategy, and timestamp all coincide.  History merges dedupe on
    /// this, never on the triple alone.
    pub fn identity(&self) -> String {
        let ts = self.recorded_at.to_string();
        joined_key(&[
            &self.platform_key,
            &self.kernel,
            &self.tag,
            &self.best_config_id,
            &self.strategy,
            &ts,
        ])
    }

    /// JSON view (also the wire form used by the serve protocol).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("platform_key", json::s(&self.platform_key)),
            ("kernel", json::s(&self.kernel)),
            ("tag", json::s(&self.tag)),
            (
                "best_params",
                Json::Obj(
                    self.best_params
                        .iter()
                        .map(|(k, v)| (k.clone(), json::int(*v)))
                        .collect(),
                ),
            ),
            ("best_config_id", json::s(&self.best_config_id)),
            ("best_time_s", json::num(self.best_time_s)),
            ("baseline_time_s", json::num(self.baseline_time_s)),
            ("reference_time_s", json::num(self.reference_time_s)),
            ("evaluations", json::int(self.evaluations as i64)),
            ("strategy", json::s(&self.strategy)),
            ("recorded_at", json::int(self.recorded_at as i64)),
        ])
    }

    /// Parse the [`to_json`](Self::to_json) form.
    pub fn from_json(v: &Json) -> Result<DbEntry> {
        let gs = |k: &str| -> Result<String> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("perfdb entry missing {k}"))
        };
        let gn = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("perfdb entry missing {k}"))
        };
        let params = v
            .get("best_params")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("perfdb entry missing best_params"))?
            .iter()
            .map(|(k, val)| {
                val.as_i64()
                    .map(|x| (k.clone(), x))
                    .ok_or_else(|| anyhow::anyhow!("non-int param {k}"))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(DbEntry {
            platform_key: gs("platform_key")?,
            kernel: gs("kernel")?,
            tag: gs("tag")?,
            best_params: params,
            best_config_id: gs("best_config_id")?,
            best_time_s: gn("best_time_s")?,
            baseline_time_s: gn("baseline_time_s")?,
            reference_time_s: v.get("reference_time_s").and_then(Json::as_f64).unwrap_or(0.0),
            evaluations: gn("evaluations")? as u64,
            strategy: gs("strategy")?,
            recorded_at: gn("recorded_at")? as u64,
        })
    }
}

/// The database: in-memory entries + a backing file.
#[derive(Debug)]
pub struct PerfDb {
    path: PathBuf,
    entries: Vec<DbEntry>,
}

impl PerfDb {
    /// Open (or create-on-save) a DB at `path`.
    pub fn open(path: impl AsRef<Path>) -> Result<PerfDb> {
        let path = path.as_ref().to_path_buf();
        let entries = if path.exists() {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading perf DB {path:?}"))?;
            Self::parse(&text)?
        } else {
            Vec::new()
        };
        Ok(PerfDb { path, entries })
    }

    fn parse(text: &str) -> Result<Vec<DbEntry>> {
        let root = json::parse(text).context("parsing perf DB json")?;
        let version = root.get("version").and_then(Json::as_i64).unwrap_or(0);
        if version != 1 {
            return Err(anyhow::anyhow!("unsupported perf DB version {version}"));
        }
        root.get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("perf DB missing entries"))?
            .iter()
            .map(DbEntry::from_json)
            .collect()
    }

    /// Serialize the whole DB.
    pub fn to_json_text(&self) -> String {
        json::obj(vec![
            ("version", json::int(1)),
            ("entries", Json::Arr(self.entries.iter().map(DbEntry::to_json).collect())),
        ])
        .pretty()
    }

    /// Atomic save: lock, reload the on-disk document, merge (newest
    /// `recorded_at` per (platform, kernel, workload) wins, in-memory
    /// wins ties), tmp + rename.  Two processes tuning concurrently
    /// both keep their records; the old implementation let the last
    /// writer silently erase the first's.
    pub fn save(&self) -> Result<()> {
        if let Some(parent) = self.path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).context("creating perf DB dir")?;
            }
        }
        locked_commit(&self.path, self.path.with_extension("json.lock"), || {
            let mut merged: BTreeMap<String, DbEntry> = BTreeMap::new();
            // Best-effort reload: a corrupt on-disk document cannot hold
            // the save hostage (the pre-merge behavior overwrote it
            // anyway).
            if let Ok(text) = std::fs::read_to_string(&self.path) {
                if let Ok(disk) = Self::parse(&text) {
                    for e in disk {
                        merged.insert(e.triple_key(), e);
                    }
                }
            }
            for e in &self.entries {
                match merged.get(&e.triple_key()) {
                    Some(existing) if existing.recorded_at > e.recorded_at => {}
                    _ => {
                        merged.insert(e.triple_key(), e.clone());
                    }
                }
            }
            Ok(json::obj(vec![
                ("version", json::int(1)),
                (
                    "entries",
                    Json::Arr(merged.values().map(DbEntry::to_json).collect()),
                ),
            ])
            .pretty())
        })
    }

    /// Every in-memory entry.
    pub fn entries(&self) -> &[DbEntry] {
        &self.entries
    }

    /// Number of in-memory entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the DB holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Exact lookup: tuned result for this platform+kernel+workload.
    pub fn lookup(&self, platform_key: &str, kernel: &str, tag: &str) -> Option<&DbEntry> {
        self.entries
            .iter()
            .filter(|e| e.platform_key == platform_key && e.kernel == kernel && e.tag == tag)
            .max_by_key(|e| e.recorded_at)
    }

    /// Insert or replace (same platform+kernel+tag keeps newest only).
    pub fn record(&mut self, entry: DbEntry) {
        self.entries.retain(|e| {
            !(e.platform_key == entry.platform_key
                && e.kernel == entry.kernel
                && e.tag == entry.tag)
        });
        self.entries.push(entry);
    }

    /// Warm-start candidates for a kernel+workload on an *unknown*
    /// platform: best configs recorded on other platforms (deduped,
    /// best-speedup first), then same-kernel other-workload configs —
    /// the portability transfer set.
    pub fn warm_start(&self, kernel: &str, tag: &str, exclude_platform: &str) -> Vec<Config> {
        let mut scored: Vec<(&DbEntry, u8)> = self
            .entries
            .iter()
            .filter(|e| e.kernel == kernel && e.platform_key != exclude_platform)
            .map(|e| (e, if e.tag == tag { 0u8 } else { 1u8 }))
            .collect();
        scored.sort_by(|(a, ra), (b, rb)| {
            ra.cmp(rb).then(b.speedup().total_cmp(&a.speedup()))
        });
        let mut seen = std::collections::HashSet::new();
        scored
            .into_iter()
            .filter(|(e, _)| seen.insert(e.best_config_id.clone()))
            .map(|(e, _)| e.best_params.clone())
            .collect()
    }
}

/// Collision-proof join for map keys built from wire-supplied strings:
/// each segment is length-prefixed, so a `|` *inside* a segment cannot
/// make two distinct tuples produce the same key (e.g. kernel
/// `axpy|n4096` + tag `x` vs kernel `axpy` + tag `n4096|x`).
fn joined_key(parts: &[&str]) -> String {
    parts
        .iter()
        .map(|p| format!("{}:{p}", p.len()))
        .collect::<Vec<String>>()
        .join("|")
}

/// A per-writer-unique sibling tmp path for atomic rename commits.  A
/// shared tmp name would let a stolen-from lock loser's cleanup delete
/// the thief's freshly written tmp between its write and rename.
fn unique_tmp(path: &Path) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    path.with_extension(format!(
        "tmp.{}.{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// The steal-safe commit protocol shared by the legacy single-file DB
/// and the shard store: lock, `build` the merged document (the closure
/// re-reads on-disk state, so each retry merges fresh), write a
/// per-writer tmp, re-check lock ownership, atomic rename.  Retries
/// the whole cycle when the lock was stolen mid-merge (a holder that
/// stalled past [`STALE_LOCK`]): committing a pre-steal merge would
/// erase whatever the thief wrote.
fn locked_commit(
    path: &Path,
    lock_path: PathBuf,
    mut build: impl FnMut() -> Result<String>,
) -> Result<()> {
    for _attempt in 0..3 {
        let lock = FileLock::acquire(lock_path.clone())?;
        let doc = build()?;
        let tmp = unique_tmp(path);
        if crate::service::faults::hit(crate::service::faults::InjectionPoint::ShardTornWrite) {
            // Simulate a writer dying mid-write: half the document
            // lands in the tmp file and the rename never happens.  The
            // published shard is untouched and the caller gets an
            // error, so nothing it was told succeeded is lost.
            let _ = std::fs::write(&tmp, &doc.as_bytes()[..doc.len() / 2]);
            anyhow::bail!(
                "fault-injected: torn write to {} (crashed before rename)",
                path.display()
            );
        }
        std::fs::write(&tmp, doc)
            .with_context(|| format!("writing tmp for {}", path.display()))?;
        if !lock.still_owned() {
            let _ = std::fs::remove_file(&tmp);
            continue;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {}", path.display()))?;
        return Ok(());
    }
    Err(anyhow::anyhow!(
        "write to {} repeatedly lost its lock; giving up",
        path.display()
    ))
}

/// A cooperative advisory lock: a sibling file created with
/// `create_new` (O_EXCL), removed on drop.  Waiters spin with a short
/// sleep; a lock older than [`STALE_LOCK`] is presumed abandoned by a
/// crashed holder and stolen.  This is the only coordination the shard
/// store needs — writes themselves stay atomic via tmp + rename, the
/// lock only serializes the read-merge-write cycle.
struct FileLock {
    path: PathBuf,
}

/// How long a lock file may exist before waiters treat it as abandoned.
const STALE_LOCK: Duration = Duration::from_secs(10);

/// How long `acquire` waits before giving up.
const LOCK_TIMEOUT: Duration = Duration::from_secs(10);

/// How many timestamped `.corrupt.<ts>` quarantine files are kept per
/// shard; older ones are pruned so a shard corrupted in a crash loop
/// cannot fill the disk with corpses.
const MAX_QUARANTINES_PER_SHARD: usize = 3;

/// Process-wide count of abandoned lock files removed — by the
/// in-band steal path in [`FileLock::acquire`] and by the periodic
/// sweep ([`reap_stale_locks`]).  Global because lock stealing happens
/// in free functions with no handle to thread a counter through;
/// surfaced as `stale_locks_reaped` in the daemon's `stats` op.
static STALE_LOCKS_REAPED: AtomicU64 = AtomicU64::new(0);

/// Total abandoned lock files this process has reaped or stolen.
pub fn stale_locks_reaped() -> u64 {
    STALE_LOCKS_REAPED.load(Ordering::Relaxed)
}

/// Remove lock files under `dir` whose mtime is older than `ttl` — the
/// corpses of writers that died between `create_new` and `Drop`.  The
/// in-band steal in [`FileLock::acquire`] already unblocks *contended*
/// locks; this sweep is for the uncontended ones, which otherwise sit
/// forever and cost every future writer a [`STALE_LOCK`] wait on first
/// contact.  Removal goes through the same atomic rename-aside dance
/// as stealing, so a racing live writer's fresh lock is never deleted.
pub fn reap_stale_locks(dir: &Path, ttl: Duration) -> Result<usize> {
    let mut reaped = 0;
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("listing {} for stale locks", dir.display()))?
    {
        let path = entry?.path();
        let is_lock = path
            .file_name()
            .and_then(|n| n.to_str())
            .is_some_and(|n| n.ends_with(".lock"));
        if !is_lock {
            continue;
        }
        let stale = std::fs::metadata(&path)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|t| t.elapsed().ok())
            .is_some_and(|age| age > ttl);
        if !stale {
            continue;
        }
        let aside = path.with_extension(format!("stale.{}", std::process::id()));
        if std::fs::rename(&path, &aside).is_ok() {
            let _ = std::fs::remove_file(&aside);
            STALE_LOCKS_REAPED.fetch_add(1, Ordering::Relaxed);
            reaped += 1;
        }
    }
    Ok(reaped)
}

impl FileLock {
    /// The lock file's content: the owner's token.  Checked by `Drop`
    /// so a holder whose lock was stolen (after `STALE_LOCK`) cannot
    /// delete the thief's fresh lock.
    fn token() -> String {
        format!("{}:{:?}", std::process::id(), std::thread::current().id())
    }

    fn acquire(path: PathBuf) -> Result<FileLock> {
        let started = Instant::now();
        let deadline = started + LOCK_TIMEOUT;
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = write!(f, "{}", Self::token());
                    let _ = f.sync_all();
                    crate::obs::metrics()
                        .lock_wait_us
                        .record(started.elapsed().as_micros() as u64);
                    return Ok(FileLock { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = std::fs::metadata(&path)
                        .and_then(|m| m.modified())
                        .ok()
                        .and_then(|t| t.elapsed().ok())
                        .map(|age| age > STALE_LOCK)
                        .unwrap_or(false);
                    if stale {
                        // Steal via rename: atomic, so exactly one racer
                        // moves the abandoned file aside; the losers'
                        // renames fail (source gone) and they go back to
                        // waiting on create_new.  Plain remove_file here
                        // would let a loser delete the winner's *fresh*
                        // lock.
                        let aside = path.with_extension(format!(
                            "stale.{}",
                            std::process::id()
                        ));
                        if std::fs::rename(&path, &aside).is_ok() {
                            let _ = std::fs::remove_file(&aside);
                            STALE_LOCKS_REAPED.fetch_add(1, Ordering::Relaxed);
                        }
                        continue;
                    }
                    if Instant::now() >= deadline {
                        return Err(anyhow::anyhow!(
                            "timed out waiting for lock {}",
                            path.display()
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("creating lock {}", path.display()))
                }
            }
        }
    }
}

impl FileLock {
    /// Whether the lock file still names us as owner.  A holder that
    /// stalled past [`STALE_LOCK`] may have been stolen from; writers
    /// re-check this immediately before their commit rename and redo
    /// the merge cycle if ownership was lost, so a resumed pre-steal
    /// merge cannot overwrite the thief's records.  (Best-effort: the
    /// check-to-rename window is microseconds against a multi-second
    /// stall scenario; closing it entirely needs OS advisory locks the
    /// pinned std-only dependency set does not expose.)
    fn still_owned(&self) -> bool {
        std::fs::read_to_string(&self.path)
            .map(|content| content == Self::token())
            .unwrap_or(false)
    }
}

impl Drop for FileLock {
    fn drop(&mut self) {
        // Only delete the lock if it is still ours: after a steal the
        // path names someone else's live lock.
        if self.still_owned() {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// One platform's slice of the v2 store: its fingerprint (when known)
/// plus the full history of tuning records made on it.
#[derive(Debug, Clone, PartialEq)]
pub struct Shard {
    /// The platform this shard belongs to.
    pub platform_key: String,
    /// Recorded by the daemon / tuner when the platform was live;
    /// `None` for entries imported from a v1 file (the fingerprint was
    /// never stored there — such shards are excluded from similarity
    /// ranking but still serve exact lookups).
    pub fingerprint: Option<Fingerprint>,
    /// Every record ever made, not just the newest per key.
    pub entries: Vec<DbEntry>,
    /// Built variant portfolios, at most one per kernel (newest wins).
    /// Absent in pre-portfolio shard files; parsing defaults to empty.
    pub portfolios: Vec<Portfolio>,
    /// Core-hour ROI accounting per kernel (spend vs realized
    /// benefit).  Absent in pre-ledger shard files; parsing defaults
    /// to empty, exactly like `portfolios`.
    pub ledger: Ledger,
}

impl Shard {
    fn new(platform_key: &str) -> Shard {
        Shard {
            platform_key: platform_key.to_string(),
            fingerprint: None,
            entries: Vec::new(),
            portfolios: Vec::new(),
            ledger: Ledger::default(),
        }
    }

    /// The platform's portfolio for a kernel, if one was built.
    pub fn portfolio(&self, kernel: &str) -> Option<&Portfolio> {
        self.portfolios.iter().find(|p| p.kernel == kernel)
    }

    /// Newest entry for a (kernel, workload).
    pub fn latest(&self, kernel: &str, tag: &str) -> Option<&DbEntry> {
        self.entries
            .iter()
            .filter(|e| e.kernel == kernel && e.tag == tag)
            .max_by_key(|e| e.recorded_at)
    }

    /// Full history for a (kernel, workload), newest first.
    pub fn history(&self, kernel: &str, tag: &str) -> Vec<&DbEntry> {
        let mut hist: Vec<&DbEntry> = self
            .entries
            .iter()
            .filter(|e| e.kernel == kernel && e.tag == tag)
            .collect();
        hist.sort_by(|a, b| b.recorded_at.cmp(&a.recorded_at));
        hist
    }

    /// Newest entry per (kernel, workload) — the shard's deployable
    /// frontier (what v1 stored as its only view).  Ties on
    /// `recorded_at` keep the later list element, matching
    /// [`latest`](Self::latest)'s `max_by_key` (last maximal), so every
    /// view of the store names the same current entry.
    pub fn frontier(&self) -> Vec<&DbEntry> {
        let mut best: BTreeMap<(String, String), &DbEntry> = BTreeMap::new();
        for e in &self.entries {
            let k = (e.kernel.clone(), e.tag.clone());
            match best.get(&k) {
                Some(cur) if cur.recorded_at > e.recorded_at => {}
                _ => {
                    best.insert(k, e);
                }
            }
        }
        best.into_values().collect()
    }

    pub(crate) fn to_json_text(&self) -> String {
        let body = json::obj(vec![
            ("version", json::int(2)),
            ("platform_key", json::s(&self.platform_key)),
            (
                "fingerprint",
                self.fingerprint.as_ref().map(Fingerprint::to_json).unwrap_or(Json::Null),
            ),
            ("entries", Json::Arr(self.entries.iter().map(DbEntry::to_json).collect())),
            (
                "portfolios",
                Json::Arr(self.portfolios.iter().map(Portfolio::to_json).collect()),
            ),
            ("ledger", self.ledger.to_json()),
        ])
        .pretty();
        with_checksum(&body)
    }

    pub(crate) fn parse(text: &str) -> Result<Shard> {
        let text = verified_shard_body(text)?;
        let root = json::parse(text).context("parsing shard json")?;
        let version = root.get("version").and_then(Json::as_i64).unwrap_or(0);
        if version != 2 {
            return Err(anyhow::anyhow!("unsupported shard version {version}"));
        }
        let platform_key = root
            .get("platform_key")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("shard missing platform_key"))?
            .to_string();
        let fingerprint = match root.get("fingerprint") {
            Some(Json::Null) | None => None,
            Some(v) => Some(
                Fingerprint::from_json(v)
                    .ok_or_else(|| anyhow::anyhow!("shard fingerprint malformed"))?,
            ),
        };
        let entries = root
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("shard missing entries"))?
            .iter()
            .map(DbEntry::from_json)
            .collect::<Result<Vec<_>>>()?;
        // Optional for backward compatibility: shards written before
        // the portfolio subsystem simply have none.
        let portfolios = match root.get("portfolios") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(Portfolio::from_json)
                .collect::<Result<Vec<_>>>()?,
            _ => Vec::new(),
        };
        // Same back-compat posture: pre-ledger shards have no ROI
        // history yet.
        let ledger = match root.get("ledger") {
            Some(v @ Json::Obj(_)) => Ledger::from_json(v)?,
            _ => Ledger::default(),
        };
        Ok(Shard { platform_key, fingerprint, entries, portfolios, ledger })
    }
}

/// First line of a checksummed shard document.  Kept distinguishable
/// from a bare JSON document's `{` + newline-pretty body so headerless
/// legacy shards keep parsing.
const CHECKSUM_PREFIX: &str = "{\"shard_checksum\":\"";

/// Prepend the content-checksum header: one compact JSON line holding
/// the FNV-1a of the raw body bytes, then the body itself.
fn with_checksum(body: &str) -> String {
    let sum = crate::coordinator::platform::fnv1a(body);
    format!("{CHECKSUM_PREFIX}{sum:016x}\"}}\n{body}")
}

/// Split an optional checksum header off a shard document.  Headerless
/// text (a shard written before checksums) passes through unverified;
/// a present header must match the body or the document is corrupt
/// (torn write, truncation, bit rot).
fn verified_shard_body(text: &str) -> Result<&str> {
    if !text.starts_with(CHECKSUM_PREFIX) {
        return Ok(text);
    }
    let (header, body) = text
        .split_once('\n')
        .ok_or_else(|| anyhow::anyhow!("shard checksum header without a body"))?;
    let stated = json::parse(header)
        .ok()
        .and_then(|h| h.get("shard_checksum").and_then(Json::as_str).map(str::to_string))
        .ok_or_else(|| anyhow::anyhow!("malformed shard checksum header"))?;
    let stated = u64::from_str_radix(&stated, 16)
        .map_err(|_| anyhow::anyhow!("non-hex shard checksum {stated:?}"))?;
    let actual = crate::coordinator::platform::fnv1a(body);
    anyhow::ensure!(
        stated == actual,
        "shard checksum mismatch: header says {stated:016x}, body hashes to {actual:016x} \
         (torn or corrupt write)"
    );
    Ok(body)
}

/// The write path's view of the on-disk shard: parse it for merging,
/// or — when it is missing *or corrupt* — start from an empty shard so
/// the write rebuilds it (the corrupt original is quarantined first).
/// A shard whose contents belong to a *different* platform is neither:
/// that is a store-layout bug and errors loudly.
fn read_or_rebuild(path: &Path, platform_key: &str) -> Result<Shard> {
    if !path.exists() {
        return Ok(Shard::new(platform_key));
    }
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading shard {}", path.display()))?;
    match Shard::parse(&text) {
        Ok(shard) => {
            anyhow::ensure!(
                shard.platform_key == platform_key,
                "shard {} belongs to platform {:?}, not {:?}",
                path.display(),
                shard.platform_key,
                platform_key
            );
            Ok(shard)
        }
        Err(e) => {
            quarantine(path, &e);
            Ok(Shard::new(platform_key))
        }
    }
}

/// Move a corrupt shard file aside to `<shard>.corrupt.<unix_ts>` so
/// reads degrade to a miss and the next write rebuilds from the merge
/// path.  Timestamped names preserve forensic history when the same
/// shard corrupts repeatedly (the old single `.corrupt` name silently
/// overwrote the previous corpse); the per-shard corpse count is
/// bounded at [`MAX_QUARANTINES_PER_SHARD`] — oldest pruned first — so
/// a crash loop cannot fill the disk.  Best-effort: a failed rename
/// leaves the file in place (the caller already treats it as absent
/// either way).
fn quarantine(path: &Path, err: &anyhow::Error) {
    let ts = unix_now();
    let mut target = PathBuf::from({
        let mut s = path.as_os_str().to_os_string();
        s.push(format!(".corrupt.{ts}"));
        s
    });
    // Same-second repeat corruption: suffix a counter rather than
    // overwrite the earlier corpse.
    let mut n = 0;
    while target.exists() {
        n += 1;
        let mut s = path.as_os_str().to_os_string();
        s.push(format!(".corrupt.{ts}-{n}"));
        target = PathBuf::from(s);
    }
    match std::fs::rename(path, &target) {
        Ok(()) => {
            eprintln!(
                "warning: quarantined corrupt shard {} -> {} ({err:#})",
                path.display(),
                target.display()
            );
            prune_quarantines(path);
        }
        Err(rename_err) => eprintln!(
            "warning: corrupt shard {} could not be quarantined ({rename_err}); \
             original error: {err:#}",
            path.display()
        ),
    }
}

/// Keep only the newest [`MAX_QUARANTINES_PER_SHARD`] quarantine files
/// for the shard at `path` (names sort chronologically because the
/// suffix is a unix timestamp; a same-second `-n` counter suffix sorts
/// after the bare name, preserving arrival order).
fn prune_quarantines(path: &Path) {
    let (Some(dir), Some(name)) = (path.parent(), path.file_name().and_then(|n| n.to_str()))
    else {
        return;
    };
    let prefix = format!("{name}.corrupt.");
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    let mut corpses: Vec<PathBuf> = entries
        .flatten()
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with(&prefix))
        })
        .collect();
    if corpses.len() <= MAX_QUARANTINES_PER_SHARD {
        return;
    }
    corpses.sort();
    let excess = corpses.len() - MAX_QUARANTINES_PER_SHARD;
    for old in &corpses[..excess] {
        let _ = std::fs::remove_file(old);
    }
}

/// PerfDb v2: one shard file per platform key under a directory.
///
/// The handle is stateless — every operation reads and/or writes shard
/// files directly, so any number of `ShardedDb` values (across threads
/// and processes) may point at the same directory.  Caching is the
/// daemon's job ([`crate::service::server::Server`] layers an LRU over
/// this), not the store's.
#[derive(Debug, Clone)]
pub struct ShardedDb {
    dir: PathBuf,
}

impl ShardedDb {
    /// Open (creating the directory if needed).
    pub fn open(dir: impl AsRef<Path>) -> Result<ShardedDb> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating shard dir {}", dir.display()))?;
        Ok(ShardedDb { dir })
    }

    /// The shard directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Platform key → shard file.  The filename is a sanitized slug
    /// *plus a hash of the raw key*: keys arrive over the wire as
    /// arbitrary strings, and sanitization alone would map distinct
    /// keys (e.g. `p.1` / `p:1`) onto one file, cross-contaminating
    /// platforms.
    fn shard_path(&self, platform_key: &str) -> PathBuf {
        let mut safe: String = platform_key
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '-' })
            .collect();
        safe.truncate(64);
        let hash = crate::coordinator::platform::fnv1a(platform_key);
        self.dir.join(format!("{safe}.{hash:016x}.shard.json"))
    }

    /// Load one platform's shard (None if it has no records yet).
    ///
    /// A torn or corrupt shard file (bad checksum, truncated JSON,
    /// zero bytes) is quarantined to `<shard>.corrupt` and reported as
    /// absent — the daemon serves a miss instead of panicking, and the
    /// next write rebuilds the shard from the merge path.
    pub fn load(&self, platform_key: &str) -> Result<Option<Shard>> {
        let path = self.shard_path(platform_key);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading shard {}", path.display()))?;
        let shard = match Shard::parse(&text) {
            Ok(shard) => shard,
            Err(e) => {
                quarantine(&path, &e);
                return Ok(None);
            }
        };
        anyhow::ensure!(
            shard.platform_key == platform_key,
            "shard {} belongs to platform {:?}, not {:?}",
            path.display(),
            shard.platform_key,
            platform_key
        );
        Ok(Some(shard))
    }

    /// Every shard in the store (the transfer engine's candidate pool).
    ///
    /// Whole-store scans degrade instead of failing: an unreadable or
    /// corrupt shard file (ENOSPC truncation, foreign tool, hand edit)
    /// is quarantined to `<shard>.corrupt` and skipped with a warning,
    /// so one bad platform cannot take down every deploy miss,
    /// staleness scan, and warm start.  Targeted operations on the bad
    /// shard ([`load`](Self::load), [`record`](Self::record)) likewise
    /// quarantine and degrade — a miss, then a rebuild on next write.
    pub fn all_shards(&self) -> Result<Vec<Shard>> {
        let mut shards = Vec::new();
        for entry in std::fs::read_dir(&self.dir).context("listing shard dir")? {
            let path = entry?.path();
            if path.file_name().and_then(|n| n.to_str()).is_some_and(|n| {
                n.ends_with(".shard.json")
            }) {
                let parsed = std::fs::read_to_string(&path)
                    .map_err(anyhow::Error::from)
                    .and_then(|text| Shard::parse(&text));
                match parsed {
                    Ok(shard) => shards.push(shard),
                    Err(e) => quarantine(&path, &e),
                }
            }
        }
        shards.sort_by(|a, b| a.platform_key.cmp(&b.platform_key));
        Ok(shards)
    }

    /// Recorded platform keys, sorted.
    pub fn platforms(&self) -> Result<Vec<String>> {
        Ok(self.all_shards()?.into_iter().map(|s| s.platform_key).collect())
    }

    /// Append one record to its platform's shard: lock, reload the
    /// on-disk shard, union histories (dedupe by [`DbEntry::identity`]),
    /// tmp + rename.  Concurrent writers each re-merge, so no entry is
    /// ever lost.
    pub fn record(&self, fingerprint: Option<&Fingerprint>, entry: DbEntry) -> Result<()> {
        let key = entry.platform_key.clone();
        self.record_many(&key, fingerprint, vec![entry])
    }

    /// [`record`](Self::record) plus a core-hour ledger accrual,
    /// committed atomically with the entry under the same shard lock —
    /// the delta lands exactly once, so ledger sums stay exact no
    /// matter how writers interleave.
    pub fn record_with_ledger(
        &self,
        fingerprint: Option<&Fingerprint>,
        entry: DbEntry,
        delta: Option<LedgerDelta>,
    ) -> Result<()> {
        let key = entry.platform_key.clone();
        self.record_many_with_ledger(&key, fingerprint, vec![entry], delta.into_iter().collect())
    }

    /// Append a batch of same-platform records under one lock and one
    /// read-merge-rename cycle (the migration path's bulk write; per-
    /// entry `record` would rewrite the shard once per entry).
    pub fn record_many(
        &self,
        platform_key: &str,
        fingerprint: Option<&Fingerprint>,
        entries: Vec<DbEntry>,
    ) -> Result<()> {
        self.record_many_with_ledger(platform_key, fingerprint, entries, Vec::new())
    }

    /// [`record_many`](Self::record_many) with ledger accruals applied
    /// in the same locked commit.
    pub fn record_many_with_ledger(
        &self,
        platform_key: &str,
        fingerprint: Option<&Fingerprint>,
        entries: Vec<DbEntry>,
        deltas: Vec<LedgerDelta>,
    ) -> Result<()> {
        anyhow::ensure!(
            entries.iter().all(|e| e.platform_key == platform_key),
            "record_many entries must all belong to platform {platform_key:?}"
        );
        let path = self.shard_path(platform_key);
        locked_commit(&path, path.with_extension("lock"), || {
            let mut shard = read_or_rebuild(&path, platform_key)?;
            if let Some(fp) = fingerprint {
                shard.fingerprint = Some(fp.clone());
            }
            let mut known: std::collections::HashSet<String> =
                shard.entries.iter().map(DbEntry::identity).collect();
            for entry in &entries {
                if known.insert(entry.identity()) {
                    shard.entries.push(entry.clone());
                }
            }
            for delta in &deltas {
                shard.ledger.apply(delta);
            }
            Ok(shard.to_json_text())
        })
    }

    /// Accrue ledger deltas without recording any entry (spend-only
    /// accounting: a sweep or rebuild whose results ride separate
    /// records, or live invocation benefit reported on its own).
    pub fn apply_ledger(&self, platform_key: &str, deltas: Vec<LedgerDelta>) -> Result<()> {
        if deltas.is_empty() {
            return Ok(());
        }
        self.record_many_with_ledger(platform_key, None, Vec::new(), deltas)
    }

    /// Exact lookup: newest record for (platform, kernel, workload).
    pub fn lookup(&self, platform_key: &str, kernel: &str, tag: &str) -> Result<Option<DbEntry>> {
        Ok(self.load(platform_key)?.and_then(|s| s.latest(kernel, tag).cloned()))
    }

    /// Persist a built portfolio into its platform's shard (replacing
    /// any previous portfolio for the same kernel), under the same
    /// lock + read-merge-rename protocol as entry writes — concurrent
    /// entry recorders lose nothing.
    pub fn record_portfolio(
        &self,
        platform_key: &str,
        fingerprint: Option<&Fingerprint>,
        portfolio: Portfolio,
    ) -> Result<()> {
        self.record_portfolio_with_ledger(platform_key, fingerprint, portfolio, None)
    }

    /// [`record_portfolio`](Self::record_portfolio) plus an optional
    /// ledger accrual (the rebuild's core-hour spend) in the same
    /// locked commit.
    pub fn record_portfolio_with_ledger(
        &self,
        platform_key: &str,
        fingerprint: Option<&Fingerprint>,
        portfolio: Portfolio,
        delta: Option<LedgerDelta>,
    ) -> Result<()> {
        let path = self.shard_path(platform_key);
        locked_commit(&path, path.with_extension("lock"), || {
            let mut shard = read_or_rebuild(&path, platform_key)?;
            if let Some(fp) = fingerprint {
                shard.fingerprint = Some(fp.clone());
            }
            shard.portfolios.retain(|p| p.kernel != portfolio.kernel);
            shard.portfolios.push(portfolio.clone());
            shard.portfolios.sort_by(|a, b| a.kernel.cmp(&b.kernel));
            if let Some(delta) = &delta {
                shard.ledger.apply(delta);
            }
            Ok(shard.to_json_text())
        })
    }

    /// The stored portfolio for (platform, kernel), if any.
    pub fn portfolio(&self, platform_key: &str, kernel: &str) -> Result<Option<Portfolio>> {
        Ok(self.load(platform_key)?.and_then(|s| s.portfolio(kernel).cloned()))
    }

    /// One platform's shard *document* — the raw on-disk text, checksum
    /// header included — verified before return.  This is the bundle
    /// export path: shipping the verbatim document (instead of a
    /// re-serialization) is what makes export → import byte-identical.
    pub fn export_shard_text(&self, platform_key: &str) -> Result<Option<String>> {
        let path = self.shard_path(platform_key);
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading shard {}", path.display()))?;
        let shard = Shard::parse(&text)
            .with_context(|| format!("verifying shard {}", path.display()))?;
        anyhow::ensure!(
            shard.platform_key == platform_key,
            "shard {} belongs to platform {:?}, not {:?}",
            path.display(),
            shard.platform_key,
            platform_key
        );
        Ok(Some(text))
    }

    /// Install a shard document produced by
    /// [`export_shard_text`](Self::export_shard_text) — the bundle
    /// import path.  The document is verified first; a platform with no
    /// existing shard gets the text written verbatim (byte-identical
    /// round-trip), while an existing shard is merged through the
    /// normal record paths (identity-deduped entries, newest portfolio
    /// per kernel) so an import never erases local history.  Returns
    /// the platform key and its imported entry count.
    pub fn import_shard_text(&self, text: &str) -> Result<(String, usize)> {
        let shard = Shard::parse(text).context("verifying imported shard document")?;
        let key = shard.platform_key.clone();
        let count = shard.entries.len();
        let path = self.shard_path(&key);
        locked_commit(&path, path.with_extension("lock"), || {
            // Checked under the lock: a shard that appeared since the
            // caller looked is a concurrent writer's work and must be
            // merged, not clobbered by the verbatim fast path.
            if !path.exists() {
                return Ok(text.to_string());
            }
            let mut disk = read_or_rebuild(&path, &key)?;
            if let Some(fp) = &shard.fingerprint {
                disk.fingerprint = Some(fp.clone());
            }
            let mut known: std::collections::HashSet<String> =
                disk.entries.iter().map(DbEntry::identity).collect();
            for e in &shard.entries {
                if known.insert(e.identity()) {
                    disk.entries.push(e.clone());
                }
            }
            for p in &shard.portfolios {
                disk.portfolios.retain(|q| q.kernel != p.kernel);
                disk.portfolios.push(p.clone());
            }
            disk.portfolios.sort_by(|a, b| a.kernel.cmp(&b.kernel));
            // Ledger join is commutative/associative/idempotent, so
            // re-importing a bundle never double-counts core-seconds.
            disk.ledger.merge(&shard.ledger);
            Ok(disk.to_json_text())
        })?;
        Ok((key, count))
    }

    /// Migrate a v1 single-file DB into shards: one locked bulk write
    /// per platform (linear in the legacy file, not quadratic).
    /// Idempotent (identity dedupe) and additive (existing shard
    /// history is kept).  Returns the number of entries processed.
    pub fn import_legacy(&self, path: impl AsRef<Path>) -> Result<usize> {
        let legacy = PerfDb::open(path)?;
        let mut by_platform: BTreeMap<String, Vec<DbEntry>> = BTreeMap::new();
        for e in legacy.entries() {
            by_platform.entry(e.platform_key.clone()).or_default().push(e.clone());
        }
        let mut n = 0;
        for (key, entries) in by_platform {
            n += entries.len();
            self.record_many(&key, None, entries)?;
        }
        Ok(n)
    }

    /// Sweep the shard directory for lock files abandoned past
    /// [`STALE_LOCK`] (a writer that died between locking and
    /// committing) and remove them.  Returns how many were reaped; the
    /// running total is exported via [`stale_locks_reaped`].
    pub fn reap_stale_locks(&self) -> Result<usize> {
        reap_stale_locks(&self.dir, STALE_LOCK)
    }

    /// How many quarantined (`.corrupt.<ts>`) shard corpses currently
    /// sit in the store — a live gauge for the `stats` op, so an
    /// operator notices repeated corruption without grepping logs.
    pub fn quarantined_count(&self) -> Result<u64> {
        let mut n = 0;
        for entry in std::fs::read_dir(&self.dir).context("listing shard dir")? {
            let path = entry?.path();
            if path
                .file_name()
                .and_then(|f| f.to_str())
                .is_some_and(|f| f.contains(".corrupt."))
            {
                n += 1;
            }
        }
        Ok(n)
    }
}

/// Current unix time in seconds.
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(platform: &str, kernel: &str, tag: &str, id: &str, speedup: f64) -> DbEntry {
        DbEntry {
            platform_key: platform.into(),
            kernel: kernel.into(),
            tag: tag.into(),
            best_params: [("block_size".to_string(), 1024i64)].into_iter().collect(),
            best_config_id: id.into(),
            best_time_s: 1e-3,
            baseline_time_s: 1e-3 * speedup,
            reference_time_s: 9e-4,
            evaluations: 9,
            strategy: "exhaustive".into(),
            recorded_at: 1_700_000_000,
        }
    }

    #[test]
    fn record_and_lookup() {
        let mut db = PerfDb { path: PathBuf::from("/tmp/unused.json"), entries: vec![] };
        db.record(entry("p1", "axpy", "n4096", "b1024_u1", 1.3));
        assert_eq!(db.len(), 1);
        let e = db.lookup("p1", "axpy", "n4096").unwrap();
        assert_eq!(e.best_config_id, "b1024_u1");
        assert!(db.lookup("p2", "axpy", "n4096").is_none());
        assert!(db.lookup("p1", "dot", "n4096").is_none());
    }

    #[test]
    fn record_replaces_same_key() {
        let mut db = PerfDb { path: PathBuf::from("/tmp/unused.json"), entries: vec![] };
        db.record(entry("p1", "axpy", "n4096", "old", 1.1));
        db.record(entry("p1", "axpy", "n4096", "new", 1.5));
        assert_eq!(db.len(), 1);
        assert_eq!(db.lookup("p1", "axpy", "n4096").unwrap().best_config_id, "new");
    }

    #[test]
    fn speedup_math() {
        let e = entry("p", "k", "t", "c", 2.0);
        assert!((e.speedup() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn json_round_trip() {
        let mut db = PerfDb { path: PathBuf::from("/tmp/unused.json"), entries: vec![] };
        db.record(entry("p1", "axpy", "n4096", "b1024_u1", 1.3));
        db.record(entry("p2", "dot", "n65536", "b256_u4", 2.1));
        let text = db.to_json_text();
        let parsed = PerfDb::parse(&text).unwrap();
        assert_eq!(parsed, db.entries);
    }

    #[test]
    fn save_and_reopen() {
        let dir = std::env::temp_dir().join(format!("portatune-dbtest-{}", std::process::id()));
        let path = dir.join("perfdb.json");
        let mut db = PerfDb { path: path.clone(), entries: vec![] };
        db.record(entry("p1", "axpy", "n4096", "b1024_u1", 1.3));
        db.save().unwrap();
        let re = PerfDb::open(&path).unwrap();
        assert_eq!(re.entries(), db.entries());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_missing_is_empty() {
        let db = PerfDb::open("/nonexistent/dir/perfdb.json");
        // Missing file is fine (created on save) ...
        assert!(db.unwrap().is_empty());
    }

    #[test]
    fn open_corrupt_errors() {
        let dir = std::env::temp_dir().join(format!("portatune-dbbad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{not json").unwrap();
        assert!(PerfDb::open(&path).is_err());
        std::fs::write(&path, r#"{"version": 7, "entries": []}"#).unwrap();
        assert!(PerfDb::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_start_prefers_same_tag_and_dedupes() {
        let mut db = PerfDb { path: PathBuf::from("/tmp/unused.json"), entries: vec![] };
        db.record(entry("p1", "axpy", "n4096", "b256_u1", 1.2));
        db.record(entry("p2", "axpy", "n4096", "b1024_u4", 2.0));
        db.record(entry("p3", "axpy", "n65536", "b1024_u4", 3.0)); // dup config id
        db.record(entry("p4", "axpy", "n65536", "b4096_u2", 1.8));
        db.record(entry("p5", "dot", "n4096", "b64_u1", 9.9)); // wrong kernel
        let cands = db.warm_start("axpy", "n4096", "local");
        // Same-tag entries first (b1024_u4 speedup 2.0 > b256_u1 1.2),
        // then other tags, deduped by config id.
        assert_eq!(cands.len(), 3);
        assert!(db
            .entries()
            .iter()
            .filter(|e| e.kernel == "axpy")
            .count() >= 3);
    }

    #[test]
    fn warm_start_excludes_own_platform() {
        let mut db = PerfDb { path: PathBuf::from("/tmp/unused.json"), entries: vec![] };
        db.record(entry("local", "axpy", "n4096", "b256_u1", 1.2));
        assert!(db.warm_start("axpy", "n4096", "local").is_empty());
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("portatune-shards-{name}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        dir
    }

    #[test]
    fn shard_record_keeps_history_and_latest_wins() {
        let dir = tmp_dir("hist");
        let db = ShardedDb::open(&dir).unwrap();
        let mut old = entry("p1", "axpy", "n4096", "b256_u1", 1.1);
        old.recorded_at = 100;
        let mut new = entry("p1", "axpy", "n4096", "b1024_u4", 1.9);
        new.recorded_at = 200;
        db.record(None, old).unwrap();
        db.record(None, new).unwrap();
        let shard = db.load("p1").unwrap().unwrap();
        assert_eq!(shard.entries.len(), 2, "history is kept, not last-write-wins");
        assert_eq!(shard.latest("axpy", "n4096").unwrap().best_config_id, "b1024_u4");
        let hist = shard.history("axpy", "n4096");
        assert_eq!(hist.len(), 2);
        assert!(hist[0].recorded_at >= hist[1].recorded_at);
        assert_eq!(db.lookup("p1", "axpy", "n4096").unwrap().unwrap().best_config_id, "b1024_u4");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_record_is_idempotent_on_identity() {
        let dir = tmp_dir("idem");
        let db = ShardedDb::open(&dir).unwrap();
        let e = entry("p1", "axpy", "n4096", "b256_u1", 1.1);
        db.record(None, e.clone()).unwrap();
        db.record(None, e).unwrap();
        assert_eq!(db.load("p1").unwrap().unwrap().entries.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_stores_fingerprint_and_lists_platforms() {
        let dir = tmp_dir("fp");
        let db = ShardedDb::open(&dir).unwrap();
        let fp = Fingerprint {
            cpu_model: "Test CPU".into(),
            num_cpus: 4,
            simd: vec!["avx2".into()],
            cache_l1d_kb: 32,
            cache_l2_kb: 1024,
            cache_l3_kb: 8192,
            os: "linux".into(),
        };
        db.record(Some(&fp), entry("p1", "axpy", "n4096", "a", 1.0)).unwrap();
        db.record(None, entry("p2", "axpy", "n4096", "b", 1.0)).unwrap();
        assert_eq!(db.platforms().unwrap(), vec!["p1".to_string(), "p2".to_string()]);
        let shards = db.all_shards().unwrap();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].fingerprint.as_ref().unwrap(), &fp);
        assert!(shards[1].fingerprint.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_frontier_is_newest_per_key() {
        let mut shard = Shard::new("p1");
        let mut a = entry("p1", "axpy", "n4096", "old", 1.0);
        a.recorded_at = 1;
        let mut b = entry("p1", "axpy", "n4096", "new", 1.5);
        b.recorded_at = 2;
        let c = entry("p1", "dot", "n4096", "other", 1.2);
        shard.entries = vec![a, b, c];
        let frontier = shard.frontier();
        assert_eq!(frontier.len(), 2);
        assert!(frontier.iter().any(|e| e.best_config_id == "new"));
        assert!(!frontier.iter().any(|e| e.best_config_id == "old"));
    }

    #[test]
    fn import_legacy_migrates_v1_file() {
        let dir = tmp_dir("migrate");
        std::fs::create_dir_all(&dir).unwrap();
        let legacy_path = dir.join("perfdb.json");
        let mut legacy = PerfDb { path: legacy_path.clone(), entries: vec![] };
        legacy.record(entry("p1", "axpy", "n4096", "a", 1.3));
        legacy.record(entry("p2", "dot", "n65536", "b", 2.1));
        legacy.save().unwrap();

        let db = ShardedDb::open(dir.join("shards")).unwrap();
        assert_eq!(db.import_legacy(&legacy_path).unwrap(), 2);
        // Idempotent: re-import adds nothing.
        assert_eq!(db.import_legacy(&legacy_path).unwrap(), 2);
        assert_eq!(db.platforms().unwrap().len(), 2);
        assert_eq!(db.lookup("p1", "axpy", "n4096").unwrap().unwrap().best_config_id, "a");
        assert_eq!(db.load("p1").unwrap().unwrap().entries.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_save_merges_instead_of_clobbering() {
        let dir = tmp_dir("merge");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("perfdb.json");
        // Two writers open the same (empty) path, record different
        // platforms, and save in sequence: both records must survive.
        let mut w1 = PerfDb::open(&path).unwrap();
        let mut w2 = PerfDb::open(&path).unwrap();
        w1.record(entry("p1", "axpy", "n4096", "a", 1.3));
        w2.record(entry("p2", "axpy", "n4096", "b", 1.4));
        w1.save().unwrap();
        w2.save().unwrap();
        let merged = PerfDb::open(&path).unwrap();
        assert_eq!(merged.len(), 2, "second save must not erase the first writer's entry");
        assert!(merged.lookup("p1", "axpy", "n4096").is_some());
        assert!(merged.lookup("p2", "axpy", "n4096").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_save_same_key_newest_recorded_at_wins() {
        let dir = tmp_dir("newest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("perfdb.json");
        let mut newer = entry("p1", "axpy", "n4096", "newer", 1.5);
        newer.recorded_at = 2_000_000_000;
        let mut w1 = PerfDb::open(&path).unwrap();
        w1.record(newer);
        w1.save().unwrap();
        // A second writer holding an older observation of the same key
        // must not roll the on-disk record back.
        let mut older = entry("p1", "axpy", "n4096", "older", 1.2);
        older.recorded_at = 1_000_000_000;
        let mut w2 = PerfDb::open(std::path::Path::new("/nonexistent/none.json")).unwrap();
        w2.record(older);
        let w2 = PerfDb { path: path.clone(), entries: w2.entries().to_vec() };
        w2.save().unwrap();
        let on_disk = PerfDb::open(&path).unwrap();
        assert_eq!(on_disk.lookup("p1", "axpy", "n4096").unwrap().best_config_id, "newer");
        std::fs::remove_dir_all(&dir).ok();
    }

    fn test_portfolio(kernel: &str, id: &str) -> Portfolio {
        use crate::coordinator::portfolio::{PortfolioItem, FEATURE_NAMES};
        Portfolio {
            kernel: kernel.into(),
            strategy: "greedy-cover".into(),
            k_max: 4,
            retained: 0.95,
            built_at: 1_700_000_000,
            feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
            items: vec![PortfolioItem {
                config: [("tile_m".to_string(), 32i64)].into_iter().collect(),
                config_id: id.into(),
                centroid: vec![5.0, 5.0, 5.0, 1.0, -2.0],
                covered: vec!["m32n32k32".into()],
            }],
        }
    }

    #[test]
    fn shard_persists_portfolios_alongside_entries() {
        let dir = tmp_dir("portfolio");
        let db = ShardedDb::open(&dir).unwrap();
        db.record(None, entry("p1", "gemm", "m32n32k32", "o1_tm32_tn32_u4", 1.4)).unwrap();
        db.record_portfolio("p1", None, test_portfolio("gemm", "o1_tm32_tn32_u4")).unwrap();
        let shard = db.load("p1").unwrap().unwrap();
        assert_eq!(shard.entries.len(), 1, "entries survive a portfolio write");
        assert_eq!(shard.portfolio("gemm").unwrap().items[0].config_id, "o1_tm32_tn32_u4");
        assert!(shard.portfolio("axpy").is_none());
        let direct = db.portfolio("p1", "gemm").unwrap().unwrap();
        assert_eq!(direct.retained, 0.95);
        assert!(db.portfolio("p1", "axpy").unwrap().is_none());
        assert!(db.portfolio("nobody", "gemm").unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn portfolio_rewrite_replaces_same_kernel_only() {
        let dir = tmp_dir("portfolio-replace");
        let db = ShardedDb::open(&dir).unwrap();
        db.record_portfolio("p1", None, test_portfolio("gemm", "old")).unwrap();
        db.record_portfolio("p1", None, test_portfolio("axpy", "other")).unwrap();
        db.record_portfolio("p1", None, test_portfolio("gemm", "new")).unwrap();
        let shard = db.load("p1").unwrap().unwrap();
        assert_eq!(shard.portfolios.len(), 2, "one portfolio per kernel");
        assert_eq!(shard.portfolio("gemm").unwrap().items[0].config_id, "new");
        assert_eq!(shard.portfolio("axpy").unwrap().items[0].config_id, "other");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pre_portfolio_shard_files_still_parse() {
        let dir = tmp_dir("portfolio-compat");
        let db = ShardedDb::open(&dir).unwrap();
        db.record(None, entry("p1", "axpy", "n4096", "b256_u1", 1.1)).unwrap();
        // Strip the portfolios key AND the checksum header, simulating
        // a shard written by the pre-portfolio (pre-checksum) daemon.
        let path = db.shard_path("p1");
        let text = std::fs::read_to_string(&path).unwrap();
        let body = verified_shard_body(&text).unwrap();
        let mut root = json::parse(body).unwrap();
        if let Json::Obj(map) = &mut root {
            map.remove("portfolios");
        }
        std::fs::write(&path, root.pretty()).unwrap();
        let shard = db.load("p1").unwrap().unwrap();
        assert!(shard.portfolios.is_empty());
        assert_eq!(shard.entries.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_files_carry_a_verifiable_checksum() {
        let shard = Shard {
            platform_key: "p1".into(),
            fingerprint: None,
            entries: vec![entry("p1", "axpy", "n4096", "a", 1.2)],
            portfolios: vec![],
            ledger: Ledger::default(),
        };
        let text = shard.to_json_text();
        assert!(text.starts_with(CHECKSUM_PREFIX), "new shards lead with the header");
        assert_eq!(Shard::parse(&text).unwrap(), shard);
        // Headerless legacy documents pass through unverified.
        let body = verified_shard_body(&text).unwrap();
        assert_eq!(Shard::parse(body).unwrap(), shard);
        // Any body tampering breaks the checksum.
        let tampered = text.replace("axpy", "ypxa");
        assert!(Shard::parse(&tampered).is_err());
    }

    /// Satellite: truncated JSON, bad checksum, and zero-byte shard
    /// files must quarantine + recover, never panic.
    #[test]
    fn corrupt_shards_quarantine_and_recover() {
        let cases: [(&str, fn(&str) -> String); 3] = [
            ("truncated", |text| text[..text.len() / 2].to_string()),
            ("badsum", |text| text.replacen("axpy", "ypxa", 1)),
            ("zerobyte", |_| String::new()),
        ];
        for (name, corrupt) in cases {
            let dir = tmp_dir(&format!("corrupt-{name}"));
            let db = ShardedDb::open(&dir).unwrap();
            db.record(None, entry("p1", "axpy", "n4096", "b256_u1", 1.1)).unwrap();
            let path = db.shard_path("p1");
            let text = std::fs::read_to_string(&path).unwrap();
            std::fs::write(&path, corrupt(&text)).unwrap();

            // Reads degrade to a miss and quarantine the bad file
            // under a timestamped `.corrupt.<ts>` name.
            assert!(db.load("p1").unwrap().is_none(), "{name}: load must miss, not panic");
            assert_eq!(
                db.quarantined_count().unwrap(),
                1,
                "{name}: corrupt file must be quarantined"
            );
            assert!(!path.exists(), "{name}: the bad file is moved, not copied");
            assert!(db.all_shards().unwrap().is_empty());

            // The next write rebuilds the shard from scratch.
            db.record(None, entry("p1", "axpy", "n4096", "fresh", 1.3)).unwrap();
            let shard = db.load("p1").unwrap().unwrap();
            assert_eq!(shard.entries.len(), 1);
            assert_eq!(shard.latest("axpy", "n4096").unwrap().best_config_id, "fresh");
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn corrupt_shard_under_write_rebuilds_from_merge_path() {
        let dir = tmp_dir("corrupt-write");
        let db = ShardedDb::open(&dir).unwrap();
        db.record(None, entry("p1", "axpy", "n4096", "old", 1.1)).unwrap();
        std::fs::write(db.shard_path("p1"), "{definitely not a shard").unwrap();
        // The write-side merge quarantines and starts fresh instead of
        // failing forever.
        db.record(None, entry("p1", "dot", "n64", "new", 1.2)).unwrap();
        let shard = db.load("p1").unwrap().unwrap();
        assert_eq!(shard.entries.len(), 1);
        assert_eq!(shard.latest("dot", "n64").unwrap().best_config_id, "new");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn joined_key_is_collision_proof_across_segment_boundaries() {
        assert_ne!(joined_key(&["axpy|n4096", "x"]), joined_key(&["axpy", "n4096|x"]));
        assert_eq!(joined_key(&["a", "b"]), joined_key(&["a", "b"]));
        let mut a = entry("p", "axpy|n4096", "x", "c", 1.0);
        let b = entry("p", "axpy", "n4096|x", "c", 1.0);
        a.recorded_at = b.recorded_at;
        assert_ne!(a.triple_key(), b.triple_key());
        assert_ne!(a.identity(), b.identity());
    }

    #[test]
    fn file_lock_excludes_and_releases() {
        let dir = tmp_dir("lock");
        std::fs::create_dir_all(&dir).unwrap();
        let lock_path = dir.join("x.lock");
        {
            let _held = FileLock::acquire(lock_path.clone()).unwrap();
            assert!(lock_path.exists());
        }
        assert!(!lock_path.exists(), "lock is released on drop");
        // Re-acquirable after release.
        let _again = FileLock::acquire(lock_path.clone()).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_locks_are_reaped_but_fresh_ones_survive() {
        let dir = tmp_dir("reap");
        let db = ShardedDb::open(&dir).unwrap();
        // A pre-planted corpse: a writer that died holding the lock.
        let stale = dir.join("dead-writer.shard.lock");
        std::fs::write(&stale, "99999:ThreadId(99)").unwrap();
        let backdated = std::time::SystemTime::now() - Duration::from_secs(3600);
        std::fs::OpenOptions::new()
            .write(true)
            .open(&stale)
            .unwrap()
            .set_modified(backdated)
            .unwrap();
        // A live writer's fresh lock must not be touched.
        let fresh = dir.join("live-writer.shard.lock");
        std::fs::write(&fresh, "live").unwrap();
        let before = stale_locks_reaped();
        assert_eq!(db.reap_stale_locks().unwrap(), 1);
        assert!(!stale.exists(), "abandoned lock must be removed");
        assert!(fresh.exists(), "fresh lock must survive the sweep");
        assert!(stale_locks_reaped() >= before + 1, "reap must bump the counter");
        // Idempotent: nothing left to reap.
        assert_eq!(db.reap_stale_locks().unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn repeated_quarantines_are_timestamped_and_bounded() {
        let dir = tmp_dir("qbound");
        let db = ShardedDb::open(&dir).unwrap();
        for round in 0..(MAX_QUARANTINES_PER_SHARD + 3) {
            db.record(None, entry("p1", "axpy", "n4096", "cfg", 1.1)).unwrap();
            let path = db.shard_path("p1");
            std::fs::write(&path, format!("{{garbage round {round}")).unwrap();
            assert!(db.load("p1").unwrap().is_none());
        }
        let corpses = db.quarantined_count().unwrap();
        assert!(
            corpses as usize <= MAX_QUARANTINES_PER_SHARD,
            "quarantine count {corpses} exceeds the bound"
        );
        assert!(corpses >= 1, "at least the newest corpse is kept");
        std::fs::remove_dir_all(&dir).ok();
    }
}
