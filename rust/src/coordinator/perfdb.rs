//! Persistent performance database — what makes tuning *sustainable*.
//!
//! Every completed tuning run records (platform key, kernel, workload) →
//! best configuration + timings.  On a known platform the deployment
//! path skips search entirely; on a new platform, entries from other
//! platforms seed the search (warm start), which the portability
//! experiment (A3) shows reaches near-optimum in a handful of
//! evaluations.  The paper: "specialization of programs to platforms ...
//! across various systems and system changes."
//!
//! Format: a single JSON document, written atomically (tmp + rename).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::spec::Config;
use crate::util::json::{self, Json};

/// One tuning record.
#[derive(Debug, Clone, PartialEq)]
pub struct DbEntry {
    pub platform_key: String,
    pub kernel: String,
    pub tag: String,
    pub best_params: Config,
    pub best_config_id: String,
    /// Median seconds of the winning variant.
    pub best_time_s: f64,
    /// Median seconds of the un-annotated default schedule (Figure 1's
    /// baseline) on the same inputs.
    pub baseline_time_s: f64,
    /// Median seconds of the pure-XLA reference artifact.
    pub reference_time_s: f64,
    /// Unique (compile+measure) evaluations the search spent.
    pub evaluations: u64,
    /// Strategy name that produced this entry.
    pub strategy: String,
    /// Unix seconds when recorded.
    pub recorded_at: u64,
}

impl DbEntry {
    pub fn speedup(&self) -> f64 {
        if self.best_time_s > 0.0 {
            self.baseline_time_s / self.best_time_s
        } else {
            0.0
        }
    }

    fn to_json(&self) -> Json {
        json::obj(vec![
            ("platform_key", json::s(&self.platform_key)),
            ("kernel", json::s(&self.kernel)),
            ("tag", json::s(&self.tag)),
            (
                "best_params",
                Json::Obj(
                    self.best_params
                        .iter()
                        .map(|(k, v)| (k.clone(), json::int(*v)))
                        .collect(),
                ),
            ),
            ("best_config_id", json::s(&self.best_config_id)),
            ("best_time_s", json::num(self.best_time_s)),
            ("baseline_time_s", json::num(self.baseline_time_s)),
            ("reference_time_s", json::num(self.reference_time_s)),
            ("evaluations", json::int(self.evaluations as i64)),
            ("strategy", json::s(&self.strategy)),
            ("recorded_at", json::int(self.recorded_at as i64)),
        ])
    }

    fn from_json(v: &Json) -> Result<DbEntry> {
        let gs = |k: &str| -> Result<String> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("perfdb entry missing {k}"))
        };
        let gn = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("perfdb entry missing {k}"))
        };
        let params = v
            .get("best_params")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("perfdb entry missing best_params"))?
            .iter()
            .map(|(k, val)| {
                val.as_i64()
                    .map(|x| (k.clone(), x))
                    .ok_or_else(|| anyhow::anyhow!("non-int param {k}"))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(DbEntry {
            platform_key: gs("platform_key")?,
            kernel: gs("kernel")?,
            tag: gs("tag")?,
            best_params: params,
            best_config_id: gs("best_config_id")?,
            best_time_s: gn("best_time_s")?,
            baseline_time_s: gn("baseline_time_s")?,
            reference_time_s: v.get("reference_time_s").and_then(Json::as_f64).unwrap_or(0.0),
            evaluations: gn("evaluations")? as u64,
            strategy: gs("strategy")?,
            recorded_at: gn("recorded_at")? as u64,
        })
    }
}

/// The database: in-memory entries + a backing file.
#[derive(Debug)]
pub struct PerfDb {
    path: PathBuf,
    entries: Vec<DbEntry>,
}

impl PerfDb {
    /// Open (or create-on-save) a DB at `path`.
    pub fn open(path: impl AsRef<Path>) -> Result<PerfDb> {
        let path = path.as_ref().to_path_buf();
        let entries = if path.exists() {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading perf DB {path:?}"))?;
            Self::parse(&text)?
        } else {
            Vec::new()
        };
        Ok(PerfDb { path, entries })
    }

    fn parse(text: &str) -> Result<Vec<DbEntry>> {
        let root = json::parse(text).context("parsing perf DB json")?;
        let version = root.get("version").and_then(Json::as_i64).unwrap_or(0);
        if version != 1 {
            return Err(anyhow::anyhow!("unsupported perf DB version {version}"));
        }
        root.get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("perf DB missing entries"))?
            .iter()
            .map(DbEntry::from_json)
            .collect()
    }

    /// Serialize the whole DB.
    pub fn to_json_text(&self) -> String {
        json::obj(vec![
            ("version", json::int(1)),
            ("entries", Json::Arr(self.entries.iter().map(DbEntry::to_json).collect())),
        ])
        .pretty()
    }

    /// Atomic save (tmp + rename).
    pub fn save(&self) -> Result<()> {
        if let Some(parent) = self.path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).context("creating perf DB dir")?;
            }
        }
        let tmp = self.path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json_text()).context("writing perf DB tmp")?;
        std::fs::rename(&tmp, &self.path).context("renaming perf DB")?;
        Ok(())
    }

    pub fn entries(&self) -> &[DbEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Exact lookup: tuned result for this platform+kernel+workload.
    pub fn lookup(&self, platform_key: &str, kernel: &str, tag: &str) -> Option<&DbEntry> {
        self.entries
            .iter()
            .filter(|e| e.platform_key == platform_key && e.kernel == kernel && e.tag == tag)
            .max_by_key(|e| e.recorded_at)
    }

    /// Insert or replace (same platform+kernel+tag keeps newest only).
    pub fn record(&mut self, entry: DbEntry) {
        self.entries.retain(|e| {
            !(e.platform_key == entry.platform_key
                && e.kernel == entry.kernel
                && e.tag == entry.tag)
        });
        self.entries.push(entry);
    }

    /// Warm-start candidates for a kernel+workload on an *unknown*
    /// platform: best configs recorded on other platforms (deduped,
    /// best-speedup first), then same-kernel other-workload configs —
    /// the portability transfer set.
    pub fn warm_start(&self, kernel: &str, tag: &str, exclude_platform: &str) -> Vec<Config> {
        let mut scored: Vec<(&DbEntry, u8)> = self
            .entries
            .iter()
            .filter(|e| e.kernel == kernel && e.platform_key != exclude_platform)
            .map(|e| (e, if e.tag == tag { 0u8 } else { 1u8 }))
            .collect();
        scored.sort_by(|(a, ra), (b, rb)| {
            ra.cmp(rb).then(b.speedup().total_cmp(&a.speedup()))
        });
        let mut seen = std::collections::HashSet::new();
        scored
            .into_iter()
            .filter(|(e, _)| seen.insert(e.best_config_id.clone()))
            .map(|(e, _)| e.best_params.clone())
            .collect()
    }
}

/// Current unix time in seconds.
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(platform: &str, kernel: &str, tag: &str, id: &str, speedup: f64) -> DbEntry {
        DbEntry {
            platform_key: platform.into(),
            kernel: kernel.into(),
            tag: tag.into(),
            best_params: [("block_size".to_string(), 1024i64)].into_iter().collect(),
            best_config_id: id.into(),
            best_time_s: 1e-3,
            baseline_time_s: 1e-3 * speedup,
            reference_time_s: 9e-4,
            evaluations: 9,
            strategy: "exhaustive".into(),
            recorded_at: 1_700_000_000,
        }
    }

    #[test]
    fn record_and_lookup() {
        let mut db = PerfDb { path: PathBuf::from("/tmp/unused.json"), entries: vec![] };
        db.record(entry("p1", "axpy", "n4096", "b1024_u1", 1.3));
        assert_eq!(db.len(), 1);
        let e = db.lookup("p1", "axpy", "n4096").unwrap();
        assert_eq!(e.best_config_id, "b1024_u1");
        assert!(db.lookup("p2", "axpy", "n4096").is_none());
        assert!(db.lookup("p1", "dot", "n4096").is_none());
    }

    #[test]
    fn record_replaces_same_key() {
        let mut db = PerfDb { path: PathBuf::from("/tmp/unused.json"), entries: vec![] };
        db.record(entry("p1", "axpy", "n4096", "old", 1.1));
        db.record(entry("p1", "axpy", "n4096", "new", 1.5));
        assert_eq!(db.len(), 1);
        assert_eq!(db.lookup("p1", "axpy", "n4096").unwrap().best_config_id, "new");
    }

    #[test]
    fn speedup_math() {
        let e = entry("p", "k", "t", "c", 2.0);
        assert!((e.speedup() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn json_round_trip() {
        let mut db = PerfDb { path: PathBuf::from("/tmp/unused.json"), entries: vec![] };
        db.record(entry("p1", "axpy", "n4096", "b1024_u1", 1.3));
        db.record(entry("p2", "dot", "n65536", "b256_u4", 2.1));
        let text = db.to_json_text();
        let parsed = PerfDb::parse(&text).unwrap();
        assert_eq!(parsed, db.entries);
    }

    #[test]
    fn save_and_reopen() {
        let dir = std::env::temp_dir().join(format!("portatune-dbtest-{}", std::process::id()));
        let path = dir.join("perfdb.json");
        let mut db = PerfDb { path: path.clone(), entries: vec![] };
        db.record(entry("p1", "axpy", "n4096", "b1024_u1", 1.3));
        db.save().unwrap();
        let re = PerfDb::open(&path).unwrap();
        assert_eq!(re.entries(), db.entries());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_missing_is_empty() {
        let db = PerfDb::open("/nonexistent/dir/perfdb.json");
        // Missing file is fine (created on save) ...
        assert!(db.unwrap().is_empty());
    }

    #[test]
    fn open_corrupt_errors() {
        let dir = std::env::temp_dir().join(format!("portatune-dbbad-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{not json").unwrap();
        assert!(PerfDb::open(&path).is_err());
        std::fs::write(&path, r#"{"version": 7, "entries": []}"#).unwrap();
        assert!(PerfDb::open(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_start_prefers_same_tag_and_dedupes() {
        let mut db = PerfDb { path: PathBuf::from("/tmp/unused.json"), entries: vec![] };
        db.record(entry("p1", "axpy", "n4096", "b256_u1", 1.2));
        db.record(entry("p2", "axpy", "n4096", "b1024_u4", 2.0));
        db.record(entry("p3", "axpy", "n65536", "b1024_u4", 3.0)); // dup config id
        db.record(entry("p4", "axpy", "n65536", "b4096_u2", 1.8));
        db.record(entry("p5", "dot", "n4096", "b64_u1", 9.9)); // wrong kernel
        let cands = db.warm_start("axpy", "n4096", "local");
        // Same-tag entries first (b1024_u4 speedup 2.0 > b256_u1 1.2),
        // then other tags, deduped by config id.
        assert_eq!(cands.len(), 3);
        assert!(db
            .entries()
            .iter()
            .filter(|e| e.kernel == "axpy")
            .count() >= 3);
    }

    #[test]
    fn warm_start_excludes_own_platform() {
        let mut db = PerfDb { path: PathBuf::from("/tmp/unused.json"), entries: vec![] };
        db.record(entry("local", "axpy", "n4096", "b256_u1", 1.2));
        assert!(db.warm_start("axpy", "n4096", "local").is_empty());
    }
}
