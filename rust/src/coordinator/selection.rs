//! Correctness gating and variant ranking — the paper's "outputs ...
//! compared with reference results" stage.
//!
//! A variant that does not reproduce the reference outputs is discarded
//! regardless of its speed (its cost becomes +inf for the search).  The
//! tolerance is elementwise `|a - b| <= atol + rtol * |b|`, the numpy
//! `allclose` convention the python layer uses, so both layers gate
//! identically.

use crate::coordinator::measure::Measurement;
use crate::coordinator::spec::Config;

/// Elementwise tolerance.
#[derive(Debug, Clone, Copy)]
pub struct Tolerance {
    /// Relative tolerance (scaled by the reference magnitude).
    pub rtol: f64,
    /// Absolute tolerance floor.
    pub atol: f64,
}

impl Default for Tolerance {
    fn default() -> Self {
        // f32 kernels with re-associated reductions: 2e-4 relative
        // matches the python test suite's gate.
        Tolerance { rtol: 2e-4, atol: 1e-3 }
    }
}

/// Outcome of comparing one variant's outputs against the reference.
#[derive(Debug, Clone)]
pub struct CorrectnessReport {
    /// Did every element pass the tolerance?
    pub ok: bool,
    /// Largest absolute error observed.
    pub max_abs_err: f64,
    /// Largest relative error observed.
    pub max_rel_err: f64,
    /// Index of the worst element (for diagnostics).
    pub worst_index: usize,
    /// Number of elements outside tolerance.
    pub mismatched: usize,
}

/// Compare candidate vs reference outputs under a tolerance.
pub fn check_outputs(candidate: &[f32], reference: &[f32], tol: Tolerance) -> CorrectnessReport {
    if candidate.len() != reference.len() {
        return CorrectnessReport {
            ok: false,
            max_abs_err: f64::INFINITY,
            max_rel_err: f64::INFINITY,
            worst_index: 0,
            mismatched: candidate.len().max(reference.len()),
        };
    }
    let mut max_abs = 0.0f64;
    let mut max_rel = 0.0f64;
    let mut worst = 0usize;
    let mut mismatched = 0usize;
    for (i, (&c, &r)) in candidate.iter().zip(reference).enumerate() {
        let (c, r) = (c as f64, r as f64);
        if c.is_nan() || r.is_nan() {
            if c.is_nan() != r.is_nan() {
                mismatched += 1;
                max_abs = f64::INFINITY;
                worst = i;
            }
            continue;
        }
        let abs = (c - r).abs();
        let rel = if r != 0.0 { abs / r.abs() } else { 0.0 };
        if abs > max_abs {
            max_abs = abs;
            worst = i;
        }
        if rel > max_rel {
            max_rel = rel;
        }
        if abs > tol.atol + tol.rtol * r.abs() {
            mismatched += 1;
        }
    }
    CorrectnessReport {
        ok: mismatched == 0,
        max_abs_err: max_abs,
        max_rel_err: max_rel,
        worst_index: worst,
        mismatched,
    }
}

/// A fully evaluated variant: identity, timing, correctness.
#[derive(Debug, Clone)]
pub struct RankedVariant {
    /// The parameter assignment.
    pub config: Config,
    /// Stable config id.
    pub config_id: String,
    /// Timing result.
    pub measurement: Measurement,
    /// Gate outcome vs the reference outputs.
    pub correctness: CorrectnessReport,
}

impl RankedVariant {
    /// Search cost: median seconds, or +inf when gated out.
    pub fn cost(&self) -> f64 {
        if self.correctness.ok {
            self.measurement.cost()
        } else {
            f64::INFINITY
        }
    }
}

/// Sort correct variants fastest-first; gated-out variants go last
/// (stable within each class).
pub fn rank(mut variants: Vec<RankedVariant>) -> Vec<RankedVariant> {
    variants.sort_by(|a, b| a.cost().total_cmp(&b.cost()));
    variants
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn meas(median: f64) -> Measurement {
        Measurement {
            summary: Summary::from_samples(&[median, median, median]).unwrap(),
            samples: vec![median; 3],
        }
    }

    fn ok_report() -> CorrectnessReport {
        check_outputs(&[1.0], &[1.0], Tolerance::default())
    }

    #[test]
    fn exact_match_passes() {
        let r = check_outputs(&[1.0, -2.0, 0.0], &[1.0, -2.0, 0.0], Tolerance::default());
        assert!(r.ok);
        assert_eq!(r.max_abs_err, 0.0);
        assert_eq!(r.mismatched, 0);
    }

    #[test]
    fn small_error_within_tolerance() {
        let r = check_outputs(&[1.0001], &[1.0], Tolerance { rtol: 1e-3, atol: 0.0 });
        assert!(r.ok);
        assert!(r.max_rel_err > 0.0);
    }

    #[test]
    fn large_error_fails_with_location() {
        let r = check_outputs(
            &[1.0, 5.0, 1.0],
            &[1.0, 1.0, 1.0],
            Tolerance { rtol: 1e-3, atol: 1e-6 },
        );
        assert!(!r.ok);
        assert_eq!(r.worst_index, 1);
        assert_eq!(r.mismatched, 1);
        assert!((r.max_abs_err - 4.0).abs() < 1e-12);
    }

    #[test]
    fn length_mismatch_fails() {
        let r = check_outputs(&[1.0, 2.0], &[1.0], Tolerance::default());
        assert!(!r.ok);
        assert_eq!(r.max_abs_err, f64::INFINITY);
    }

    #[test]
    fn nan_disagreement_fails_nan_agreement_passes() {
        let t = Tolerance::default();
        assert!(!check_outputs(&[f32::NAN], &[1.0], t).ok);
        assert!(!check_outputs(&[1.0], &[f32::NAN], t).ok);
        assert!(check_outputs(&[f32::NAN], &[f32::NAN], t).ok);
    }

    #[test]
    fn zero_reference_uses_atol() {
        let t = Tolerance { rtol: 1e-6, atol: 1e-3 };
        assert!(check_outputs(&[5e-4], &[0.0], t).ok);
        assert!(!check_outputs(&[5e-2], &[0.0], t).ok);
    }

    #[test]
    fn gated_variants_rank_last() {
        let fast_wrong = RankedVariant {
            config: Config::new(),
            config_id: "fast_wrong".into(),
            measurement: meas(1e-6),
            correctness: check_outputs(&[9.0], &[1.0], Tolerance::default()),
        };
        let slow_right = RankedVariant {
            config: Config::new(),
            config_id: "slow_right".into(),
            measurement: meas(1e-3),
            correctness: ok_report(),
        };
        let fast_right = RankedVariant {
            config: Config::new(),
            config_id: "fast_right".into(),
            measurement: meas(1e-5),
            correctness: ok_report(),
        };
        let ranked = rank(vec![fast_wrong, slow_right, fast_right]);
        assert_eq!(ranked[0].config_id, "fast_right");
        assert_eq!(ranked[1].config_id, "slow_right");
        assert_eq!(ranked[2].config_id, "fast_wrong");
        assert_eq!(ranked[2].cost(), f64::INFINITY);
    }
}
