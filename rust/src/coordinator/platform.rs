//! Platform fingerprinting — the "given hardware platform" the paper
//! specializes code for.
//!
//! The fingerprint keys the performance database: a tuned configuration
//! is only reused on a platform whose fingerprint matches, which is
//! exactly the paper's performance-portability story (re-tune on new
//! hardware, reuse on known hardware).  Sources: /proc/cpuinfo for the
//! model and ISA feature flags, sysfs for cache geometry.  All fields
//! degrade gracefully to "unknown" off-Linux.

use std::fmt::Write as _;
use std::path::Path;

use crate::util::json::{self, Json};

/// A platform's identity for tuning purposes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fingerprint {
    /// CPU model string from /proc/cpuinfo (or "unknown").
    pub cpu_model: String,
    /// Logical processor count (min 1).
    pub num_cpus: usize,
    /// SIMD ISA levels present (subset of sse2/sse4_2/avx/avx2/avx512f).
    pub simd: Vec<String>,
    /// L1d/L2/L3 sizes in KiB (0 = unknown).
    pub cache_l1d_kb: u64,
    /// L2 size in KiB (0 = unknown).
    pub cache_l2_kb: u64,
    /// L3 size in KiB (0 = unknown).
    pub cache_l3_kb: u64,
    /// Operating system (`std::env::consts::OS`).
    pub os: String,
}

impl Fingerprint {
    /// Detect the current host.
    pub fn detect() -> Fingerprint {
        Self::detect_from(Path::new("/proc/cpuinfo"), Path::new("/sys/devices/system/cpu"))
    }

    /// Detection with injectable roots (unit tests use fixture files).
    pub fn detect_from(cpuinfo_path: &Path, sysfs_cpu: &Path) -> Fingerprint {
        let cpuinfo = std::fs::read_to_string(cpuinfo_path).unwrap_or_default();
        let cpu_model = cpuinfo
            .lines()
            .find(|l| l.starts_with("model name"))
            .and_then(|l| l.split(':').nth(1))
            .map(|s| s.trim().to_string())
            .unwrap_or_else(|| "unknown".to_string());
        let num_cpus = cpuinfo
            .lines()
            .filter(|l| l.starts_with("processor"))
            .count()
            .max(1);
        // x86 /proc/cpuinfo lists ISA extensions on a `flags` line; ARM
        // uses `Features` (arm64 calls NEON `asimd`, arm32 says `neon`).
        let flags_line = cpuinfo
            .lines()
            .find(|l| l.starts_with("flags") || l.starts_with("Features"))
            .and_then(|l| l.split(':').nth(1))
            .unwrap_or("");
        let interesting = ["sse2", "sse4_2", "avx", "avx2", "avx512f", "fma", "neon", "sve"];
        let mut flagset: std::collections::HashSet<&str> =
            flags_line.split_whitespace().collect();
        if flagset.contains("asimd") {
            flagset.insert("neon");
        }
        let simd = interesting
            .iter()
            .filter(|f| flagset.contains(**f))
            .map(|f| f.to_string())
            .collect();

        let cache = |index: usize| -> u64 {
            let p = sysfs_cpu.join(format!("cpu0/cache/index{index}/size"));
            std::fs::read_to_string(p)
                .ok()
                .and_then(|s| parse_cache_size_kb(s.trim()))
                .unwrap_or(0)
        };
        // index0=L1d, index1=L1i, index2=L2, index3=L3 on common layouts;
        // verify level files when present.
        let level_of = |index: usize| -> u64 {
            let p = sysfs_cpu.join(format!("cpu0/cache/index{index}/level"));
            std::fs::read_to_string(p)
                .ok()
                .and_then(|s| s.trim().parse().ok())
                .unwrap_or(0)
        };
        let type_of = |index: usize| -> String {
            let p = sysfs_cpu.join(format!("cpu0/cache/index{index}/type"));
            std::fs::read_to_string(p)
                .map(|s| s.trim().to_string())
                .unwrap_or_default()
        };
        let mut l1d = 0;
        let mut l2 = 0;
        let mut l3 = 0;
        for i in 0..6 {
            match (level_of(i), type_of(i).as_str()) {
                (1, "Data") => l1d = cache(i),
                (2, _) => l2 = cache(i),
                (3, _) => l3 = cache(i),
                _ => {}
            }
        }

        Fingerprint {
            cpu_model,
            num_cpus,
            simd,
            cache_l1d_kb: l1d,
            cache_l2_kb: l2,
            cache_l3_kb: l3,
            os: std::env::consts::OS.to_string(),
        }
    }

    /// Stable short key for the perf DB (model + ISA + cache geometry).
    pub fn key(&self) -> String {
        let mut material = String::new();
        let _ = write!(
            material,
            "{}|{}|{}|{}|{}|{}",
            self.cpu_model,
            self.simd.join("+"),
            self.cache_l1d_kb,
            self.cache_l2_kb,
            self.cache_l3_kb,
            self.os,
        );
        format!("{}-{:016x}", sanitize(&self.cpu_model), fnv1a(&material))
    }

    /// Similarity to another platform in [0, 1] — the transfer engine's
    /// core metric.  A weighted mean of four symmetric components:
    ///
    /// * SIMD ISA overlap (Jaccard index of the feature sets) — weight 5,
    /// * cache geometry (per-level min/max size ratio, L1d/L2/L3) — weight 3,
    /// * core count (min/max ratio) — weight 1,
    /// * OS equality — weight 1.
    ///
    /// Every component is exactly 1.0 when the fingerprints are equal,
    /// so `a.similarity(&a) == 1.0` and [`distance`](Self::distance) is
    /// exactly 0.0; every component is order-independent, so the metric
    /// is symmetric.
    pub fn similarity(&self, other: &Fingerprint) -> f64 {
        let simd = jaccard(&self.simd, &other.simd);
        let cache = (ratio_sim(self.cache_l1d_kb, other.cache_l1d_kb)
            + ratio_sim(self.cache_l2_kb, other.cache_l2_kb)
            + ratio_sim(self.cache_l3_kb, other.cache_l3_kb))
            / 3.0;
        let cores = ratio_sim(self.num_cpus.max(1) as u64, other.num_cpus.max(1) as u64);
        let os = if self.os == other.os { 1.0 } else { 0.0 };
        (5.0 * simd + 3.0 * cache + cores + os) / 10.0
    }

    /// Distance = 1 − similarity (0 for identical fingerprints).
    pub fn distance(&self, other: &Fingerprint) -> f64 {
        1.0 - self.similarity(other)
    }

    /// JSON view, stored in perf-DB shards so the transfer engine can
    /// score similarity against platforms it has never seen live.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("cpu_model", json::s(&self.cpu_model)),
            ("num_cpus", json::int(self.num_cpus as i64)),
            ("simd", Json::Arr(self.simd.iter().map(|f| json::s(f)).collect())),
            ("cache_l1d_kb", json::int(self.cache_l1d_kb as i64)),
            ("cache_l2_kb", json::int(self.cache_l2_kb as i64)),
            ("cache_l3_kb", json::int(self.cache_l3_kb as i64)),
            ("os", json::s(&self.os)),
        ])
    }

    /// Parse the [`to_json`](Self::to_json) form; `None` on shape errors.
    pub fn from_json(v: &Json) -> Option<Fingerprint> {
        Some(Fingerprint {
            cpu_model: v.get("cpu_model")?.as_str()?.to_string(),
            num_cpus: v.get("num_cpus")?.as_u64()? as usize,
            simd: v
                .get("simd")?
                .as_arr()?
                .iter()
                .map(|f| f.as_str().map(str::to_string))
                .collect::<Option<Vec<String>>>()?,
            cache_l1d_kb: v.get("cache_l1d_kb")?.as_u64()?,
            cache_l2_kb: v.get("cache_l2_kb")?.as_u64()?,
            cache_l3_kb: v.get("cache_l3_kb")?.as_u64()?,
            os: v.get("os")?.as_str()?.to_string(),
        })
    }

    /// Human-oriented description block.
    pub fn describe(&self) -> String {
        format!(
            "cpu: {}\ncores: {}\nsimd: {}\ncaches: L1d={} KiB, L2={} KiB, L3={} KiB\nos: {}\nkey: {}",
            self.cpu_model,
            self.num_cpus,
            if self.simd.is_empty() { "(none detected)".to_string() } else { self.simd.join(", ") },
            self.cache_l1d_kb,
            self.cache_l2_kb,
            self.cache_l3_kb,
            self.os,
            self.key(),
        )
    }
}

/// Jaccard index of two feature lists (1.0 when both are empty: two
/// platforms that report no SIMD at all are alike, not alien).
fn jaccard(a: &[String], b: &[String]) -> f64 {
    if a.is_empty() && b.is_empty() {
        return 1.0;
    }
    let sa: std::collections::HashSet<&str> = a.iter().map(String::as_str).collect();
    let sb: std::collections::HashSet<&str> = b.iter().map(String::as_str).collect();
    let inter = sa.intersection(&sb).count() as f64;
    let union = sa.union(&sb).count() as f64;
    inter / union
}

/// min/max ratio in [0, 1]; both-unknown (0) is a perfect match, one
/// unknown is a half match (we can't refute similarity, only not
/// confirm it).
fn ratio_sim(a: u64, b: u64) -> f64 {
    match (a, b) {
        (0, 0) => 1.0,
        (0, _) | (_, 0) => 0.5,
        (a, b) => a.min(b) as f64 / a.max(b) as f64,
    }
}

/// Slug used as the prefix of derived platform keys (also consulted by
/// the staleness scheduler to decide drift eligibility).
pub(crate) fn sanitize(s: &str) -> String {
    let mut out: String = s
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
        .collect();
    out.truncate(32);
    while out.contains("--") {
        out = out.replace("--", "-");
    }
    out.trim_matches('-').to_string()
}

/// FNV-1a: stable, dependency-free content hash (also used by the
/// shard store to collision-proof shard filenames).
pub(crate) fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Parse "32K" / "1024K" / "8M" → KiB.
fn parse_cache_size_kb(s: &str) -> Option<u64> {
    if let Some(num) = s.strip_suffix(['K', 'k']) {
        num.trim().parse().ok()
    } else if let Some(num) = s.strip_suffix(['M', 'm']) {
        num.trim().parse::<u64>().ok().map(|m| m * 1024)
    } else {
        s.parse().ok().map(|b: u64| b / 1024)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_cache_sizes() {
        assert_eq!(parse_cache_size_kb("32K"), Some(32));
        assert_eq!(parse_cache_size_kb("8M"), Some(8192));
        assert_eq!(parse_cache_size_kb("49152"), Some(48));
        assert_eq!(parse_cache_size_kb("junk"), None);
    }

    #[test]
    fn detect_never_panics_on_missing_paths() {
        let fp = Fingerprint::detect_from(
            Path::new("/nonexistent/cpuinfo"),
            Path::new("/nonexistent/sys"),
        );
        assert_eq!(fp.cpu_model, "unknown");
        assert_eq!(fp.num_cpus, 1);
        assert!(!fp.key().is_empty());
    }

    #[test]
    fn detect_real_host() {
        let fp = Fingerprint::detect();
        assert!(fp.num_cpus >= 1);
        assert!(!fp.key().is_empty());
        assert!(fp.describe().contains("cpu:"));
    }

    #[test]
    fn key_is_stable_and_discriminating() {
        let a = Fingerprint {
            cpu_model: "Intel(R) Xeon(R) @ 2.10GHz".into(),
            num_cpus: 4,
            simd: vec!["avx".into(), "avx2".into()],
            cache_l1d_kb: 32,
            cache_l2_kb: 1024,
            cache_l3_kb: 33792,
            os: "linux".into(),
        };
        assert_eq!(a.key(), a.key());
        let mut b = a.clone();
        b.simd = vec!["avx".into()];
        assert_ne!(a.key(), b.key());
        let mut c = a.clone();
        c.cache_l2_kb = 512;
        assert_ne!(a.key(), c.key());
        // num_cpus intentionally NOT in the key: the schedule space is
        // single-core; core count doesn't change the optimum.
        let mut d = a.clone();
        d.num_cpus = 64;
        assert_eq!(a.key(), d.key());
    }

    #[test]
    fn sanitize_produces_clean_slugs() {
        assert_eq!(sanitize("Intel(R) Xeon(R) @ 2.10GHz"), "intel-r-xeon-r-2-10ghz");
        assert_eq!(sanitize("!!!"), "");
    }

    /// ARM /proc/cpuinfo fixture: `Features` line, `asimd` spelling.
    #[test]
    fn detects_arm_neon_from_features_line() {
        let dir = std::env::temp_dir().join(format!("portatune-armfix-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cpuinfo = dir.join("cpuinfo");
        std::fs::write(
            &cpuinfo,
            "processor\t: 0\nBogoMIPS\t: 50.00\n\
             Features\t: fp asimd evtstrm aes pmull sha1 sha2 crc32 atomics sve\n\
             CPU implementer\t: 0x41\nCPU part\t: 0xd0c\n\
             processor\t: 1\n\
             Features\t: fp asimd evtstrm aes pmull sha1 sha2 crc32 atomics sve\n",
        )
        .unwrap();
        let fp = Fingerprint::detect_from(&cpuinfo, Path::new("/nonexistent/sys"));
        assert!(fp.simd.contains(&"neon".to_string()), "asimd must imply neon: {:?}", fp.simd);
        assert!(fp.simd.contains(&"sve".to_string()));
        assert_eq!(fp.num_cpus, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn detects_arm32_neon_flag() {
        let dir = std::env::temp_dir().join(format!("portatune-arm32-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cpuinfo = dir.join("cpuinfo");
        std::fs::write(
            &cpuinfo,
            "processor\t: 0\nmodel name\t: ARMv7 Processor rev 4 (v7l)\n\
             Features\t: half thumb fastmult vfp edsp neon vfpv3\n",
        )
        .unwrap();
        let fp = Fingerprint::detect_from(&cpuinfo, Path::new("/nonexistent/sys"));
        assert!(fp.simd.contains(&"neon".to_string()));
        assert_eq!(fp.cpu_model, "ARMv7 Processor rev 4 (v7l)");
        std::fs::remove_dir_all(&dir).ok();
    }

    fn fp(simd: &[&str], l1: u64, l2: u64, l3: u64, cores: usize) -> Fingerprint {
        Fingerprint {
            cpu_model: "test".into(),
            num_cpus: cores,
            simd: simd.iter().map(|s| s.to_string()).collect(),
            cache_l1d_kb: l1,
            cache_l2_kb: l2,
            cache_l3_kb: l3,
            os: "linux".into(),
        }
    }

    #[test]
    fn similarity_identity_and_symmetry() {
        let a = fp(&["avx", "avx2", "fma"], 32, 1024, 33792, 8);
        let b = fp(&["neon"], 64, 512, 0, 4);
        assert_eq!(a.similarity(&a), 1.0);
        assert_eq!(a.distance(&a), 0.0);
        assert_eq!(a.similarity(&b), b.similarity(&a));
        assert!(a.similarity(&b) < 1.0);
    }

    #[test]
    fn similarity_orders_near_before_far() {
        let target = fp(&["sse2", "avx", "avx2"], 32, 1024, 33792, 8);
        let near = fp(&["sse2", "avx", "avx2"], 32, 512, 33792, 8);
        let far = fp(&["neon"], 128, 4096, 0, 64);
        assert!(target.similarity(&near) > target.similarity(&far));
    }

    #[test]
    fn fingerprint_json_round_trips() {
        let a = fp(&["avx2", "fma"], 32, 1024, 33792, 8);
        let text = a.to_json().compact();
        let parsed = json::parse(&text).unwrap();
        let back = Fingerprint::from_json(&parsed).unwrap();
        assert_eq!(back, a);
        assert_eq!(back.key(), a.key());
        assert!(Fingerprint::from_json(&json::parse("{}").unwrap()).is_none());
    }
}
