//! Platform fingerprinting — the "given hardware platform" the paper
//! specializes code for.
//!
//! The fingerprint keys the performance database: a tuned configuration
//! is only reused on a platform whose fingerprint matches, which is
//! exactly the paper's performance-portability story (re-tune on new
//! hardware, reuse on known hardware).  Sources: /proc/cpuinfo for the
//! model and ISA feature flags, sysfs for cache geometry.  All fields
//! degrade gracefully to "unknown" off-Linux.

use std::fmt::Write as _;
use std::path::Path;

/// A platform's identity for tuning purposes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fingerprint {
    pub cpu_model: String,
    pub num_cpus: usize,
    /// SIMD ISA levels present (subset of sse2/sse4_2/avx/avx2/avx512f).
    pub simd: Vec<String>,
    /// L1d/L2/L3 sizes in KiB (0 = unknown).
    pub cache_l1d_kb: u64,
    pub cache_l2_kb: u64,
    pub cache_l3_kb: u64,
    pub os: String,
}

impl Fingerprint {
    /// Detect the current host.
    pub fn detect() -> Fingerprint {
        Self::detect_from(Path::new("/proc/cpuinfo"), Path::new("/sys/devices/system/cpu"))
    }

    /// Detection with injectable roots (unit tests use fixture files).
    pub fn detect_from(cpuinfo_path: &Path, sysfs_cpu: &Path) -> Fingerprint {
        let cpuinfo = std::fs::read_to_string(cpuinfo_path).unwrap_or_default();
        let cpu_model = cpuinfo
            .lines()
            .find(|l| l.starts_with("model name"))
            .and_then(|l| l.split(':').nth(1))
            .map(|s| s.trim().to_string())
            .unwrap_or_else(|| "unknown".to_string());
        let num_cpus = cpuinfo
            .lines()
            .filter(|l| l.starts_with("processor"))
            .count()
            .max(1);
        let flags_line = cpuinfo
            .lines()
            .find(|l| l.starts_with("flags"))
            .and_then(|l| l.split(':').nth(1))
            .unwrap_or("");
        let interesting = ["sse2", "sse4_2", "avx", "avx2", "avx512f", "fma", "neon"];
        let flagset: std::collections::HashSet<&str> =
            flags_line.split_whitespace().collect();
        let simd = interesting
            .iter()
            .filter(|f| flagset.contains(**f))
            .map(|f| f.to_string())
            .collect();

        let cache = |index: usize| -> u64 {
            let p = sysfs_cpu.join(format!("cpu0/cache/index{index}/size"));
            std::fs::read_to_string(p)
                .ok()
                .and_then(|s| parse_cache_size_kb(s.trim()))
                .unwrap_or(0)
        };
        // index0=L1d, index1=L1i, index2=L2, index3=L3 on common layouts;
        // verify level files when present.
        let level_of = |index: usize| -> u64 {
            let p = sysfs_cpu.join(format!("cpu0/cache/index{index}/level"));
            std::fs::read_to_string(p)
                .ok()
                .and_then(|s| s.trim().parse().ok())
                .unwrap_or(0)
        };
        let type_of = |index: usize| -> String {
            let p = sysfs_cpu.join(format!("cpu0/cache/index{index}/type"));
            std::fs::read_to_string(p)
                .map(|s| s.trim().to_string())
                .unwrap_or_default()
        };
        let mut l1d = 0;
        let mut l2 = 0;
        let mut l3 = 0;
        for i in 0..6 {
            match (level_of(i), type_of(i).as_str()) {
                (1, "Data") => l1d = cache(i),
                (2, _) => l2 = cache(i),
                (3, _) => l3 = cache(i),
                _ => {}
            }
        }

        Fingerprint {
            cpu_model,
            num_cpus,
            simd,
            cache_l1d_kb: l1d,
            cache_l2_kb: l2,
            cache_l3_kb: l3,
            os: std::env::consts::OS.to_string(),
        }
    }

    /// Stable short key for the perf DB (model + ISA + cache geometry).
    pub fn key(&self) -> String {
        let mut material = String::new();
        let _ = write!(
            material,
            "{}|{}|{}|{}|{}|{}",
            self.cpu_model,
            self.simd.join("+"),
            self.cache_l1d_kb,
            self.cache_l2_kb,
            self.cache_l3_kb,
            self.os,
        );
        format!("{}-{:016x}", sanitize(&self.cpu_model), fnv1a(&material))
    }

    /// Human-oriented description block.
    pub fn describe(&self) -> String {
        format!(
            "cpu: {}\ncores: {}\nsimd: {}\ncaches: L1d={} KiB, L2={} KiB, L3={} KiB\nos: {}\nkey: {}",
            self.cpu_model,
            self.num_cpus,
            if self.simd.is_empty() { "(none detected)".to_string() } else { self.simd.join(", ") },
            self.cache_l1d_kb,
            self.cache_l2_kb,
            self.cache_l3_kb,
            self.os,
            self.key(),
        )
    }
}

fn sanitize(s: &str) -> String {
    let mut out: String = s
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
        .collect();
    out.truncate(32);
    while out.contains("--") {
        out = out.replace("--", "-");
    }
    out.trim_matches('-').to_string()
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Parse "32K" / "1024K" / "8M" → KiB.
fn parse_cache_size_kb(s: &str) -> Option<u64> {
    if let Some(num) = s.strip_suffix(['K', 'k']) {
        num.trim().parse().ok()
    } else if let Some(num) = s.strip_suffix(['M', 'm']) {
        num.trim().parse::<u64>().ok().map(|m| m * 1024)
    } else {
        s.parse().ok().map(|b: u64| b / 1024)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_cache_sizes() {
        assert_eq!(parse_cache_size_kb("32K"), Some(32));
        assert_eq!(parse_cache_size_kb("8M"), Some(8192));
        assert_eq!(parse_cache_size_kb("49152"), Some(48));
        assert_eq!(parse_cache_size_kb("junk"), None);
    }

    #[test]
    fn detect_never_panics_on_missing_paths() {
        let fp = Fingerprint::detect_from(
            Path::new("/nonexistent/cpuinfo"),
            Path::new("/nonexistent/sys"),
        );
        assert_eq!(fp.cpu_model, "unknown");
        assert_eq!(fp.num_cpus, 1);
        assert!(!fp.key().is_empty());
    }

    #[test]
    fn detect_real_host() {
        let fp = Fingerprint::detect();
        assert!(fp.num_cpus >= 1);
        assert!(!fp.key().is_empty());
        assert!(fp.describe().contains("cpu:"));
    }

    #[test]
    fn key_is_stable_and_discriminating() {
        let a = Fingerprint {
            cpu_model: "Intel(R) Xeon(R) @ 2.10GHz".into(),
            num_cpus: 4,
            simd: vec!["avx".into(), "avx2".into()],
            cache_l1d_kb: 32,
            cache_l2_kb: 1024,
            cache_l3_kb: 33792,
            os: "linux".into(),
        };
        assert_eq!(a.key(), a.key());
        let mut b = a.clone();
        b.simd = vec!["avx".into()];
        assert_ne!(a.key(), b.key());
        let mut c = a.clone();
        c.cache_l2_kb = 512;
        assert_ne!(a.key(), c.key());
        // num_cpus intentionally NOT in the key: the schedule space is
        // single-core; core count doesn't change the optimum.
        let mut d = a.clone();
        d.num_cpus = 64;
        assert_eq!(a.key(), d.key());
    }

    #[test]
    fn sanitize_produces_clean_slugs() {
        assert_eq!(sanitize("Intel(R) Xeon(R) @ 2.10GHz"), "intel-r-xeon-r-2-10ghz");
        assert_eq!(sanitize("!!!"), "");
    }
}
