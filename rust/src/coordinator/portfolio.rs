//! Variant portfolios — the "A Few Fit Most" result as a subsystem.
//!
//! Per-shape tuning finds the best schedule for every problem shape,
//! but shipping one tuned config per shape is operationally heavy:
//! Hochgraf & Pai (2025) show a *small portfolio* of tuned variants
//! covers most workload shapes nearly as well as per-shape tuning.
//! This module turns a tuning sweep into that portfolio:
//!
//! 1. **Sweep** ([`sweep_gemm`]) — measure every schedule config on
//!    every shape of a sweep (correctness-gated against the naive
//!    reference), producing a [`CostMatrix`];
//! 2. **Build** ([`CostMatrix::build_portfolio`]) — greedy set-cover:
//!    add the config that most improves mean retained performance
//!    (per-shape-best time ÷ portfolio-best time) until the target
//!    retention is reached or `k_max` configs are chosen;
//! 3. **Select** ([`Portfolio::select`]) — at deploy time, pick the
//!    portfolio member whose covered-shape feature centroid (log dims,
//!    density, footprint-vs-cache pressure) is nearest the incoming
//!    workload's features.
//!
//! Portfolios persist in the perf-DB shards
//! ([`crate::coordinator::perfdb::ShardedDb::record_portfolio`]) and
//! are served (and transfer-ranked for unseen platforms) by the
//! `portfolio` op of the serve protocol.
//!
//! By construction the portfolio can never *beat* per-shape tuning:
//! every retained ratio divides the per-shape minimum by a cost drawn
//! from the same measured matrix, so `retained <= 1.0` always — the
//! property test in `tests/prop_portfolio.rs` pins this down.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::coordinator::measure::{measure_host, MeasureConfig};
use crate::coordinator::perfdb::{unix_now, DbEntry};
use crate::coordinator::platform::Fingerprint;
use crate::coordinator::selection::{check_outputs, Tolerance};
use crate::coordinator::spec::Config;
use crate::util::json::{self, Json};
use crate::workload::gemm::{self, GemmShape};

/// Names of the workload-feature vector components, in order.  Stored
/// with every portfolio so build-time and deploy-time feature vectors
/// can never silently disagree.
pub const FEATURE_NAMES: [&str; 5] =
    ["log_m", "log_n", "log_k", "density", "cache_pressure"];

/// Feature vector for a dense workload: log2 of the m/n/k dims, the
/// nonzero density (1.0 for dense GEMM), and cache pressure — log2 of
/// the operand footprint relative to the platform's total cache.  The
/// last component is what lets selection distinguish "fits in L2" from
/// "streams through memory" shapes on the *deploying* machine.
pub fn features_for(dims: &BTreeMap<String, i64>, density: f64, fp: &Fingerprint) -> Vec<f64> {
    let dim = |name: &str| dims.get(name).copied().unwrap_or(1).max(1) as f64;
    let (m, n, k) = (dim("m"), dim("n"), dim("k"));
    let footprint = 4.0 * (m * k + k * n + m * n);
    let cache_kb = (fp.cache_l1d_kb + fp.cache_l2_kb + fp.cache_l3_kb).max(1) as f64;
    vec![
        m.log2(),
        n.log2(),
        k.log2(),
        density,
        (footprint / (cache_kb * 1024.0)).log2(),
    ]
}

/// One shape of a sweep: identity, dims, flop count, and its feature
/// vector (computed against the build platform's cache geometry).
#[derive(Debug, Clone, PartialEq)]
pub struct ShapePoint {
    /// Workload tag (perf-DB key), e.g. `m128n128k64`.
    pub tag: String,
    /// Dimension map (`m`/`n`/`k` for GEMM).
    pub dims: BTreeMap<String, i64>,
    /// Flop count of one execution (for GFLOP/s reporting).
    pub flops: u64,
    /// Feature vector in [`FEATURE_NAMES`] order.
    pub features: Vec<f64>,
}

/// The measured (shape × config) cost matrix a sweep produces — the
/// tuning history the portfolio builder clusters.
#[derive(Debug, Clone)]
pub struct CostMatrix {
    /// Kernel family the matrix was measured for.
    pub kernel: String,
    /// Swept shapes, row order.
    pub shapes: Vec<ShapePoint>,
    /// Schedule configs, column order.
    pub configs: Vec<Config>,
    /// Config ids matching [`configs`](Self::configs).
    pub config_ids: Vec<String>,
    /// `costs[shape][config]` median seconds; `f64::INFINITY` marks a
    /// gate failure or measurement error.
    pub costs: Vec<Vec<f64>>,
}

impl CostMatrix {
    /// Index and cost of the per-shape winner (`None` if every config
    /// failed on that shape).
    pub fn best_for_shape(&self, shape_idx: usize) -> Option<(usize, f64)> {
        self.costs[shape_idx]
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_finite())
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, c)| (i, *c))
    }

    /// Mean retained performance of a candidate portfolio (config
    /// column indices): for each shape, per-shape-best time divided by
    /// the best time any member achieves, averaged over shapes.  1.0 ⇒
    /// the portfolio matches per-shape tuning everywhere.
    pub fn retained_with(&self, members: &[usize]) -> f64 {
        if self.shapes.is_empty() || members.is_empty() {
            return 0.0;
        }
        let mut total = 0.0;
        for s in 0..self.shapes.len() {
            let Some((_, best)) = self.best_for_shape(s) else { continue };
            let member_best = members
                .iter()
                .map(|&c| self.costs[s][c])
                .fold(f64::INFINITY, f64::min);
            total += if member_best.is_finite() { best / member_best } else { 0.0 };
        }
        total / self.shapes.len() as f64
    }

    /// Greedy set-cover portfolio construction (see module docs).
    /// Stops as soon as mean retention reaches `target` or `k_max`
    /// members are chosen.  Errors when the matrix is empty or no
    /// config is finite anywhere.
    pub fn build_portfolio(&self, k_max: usize, target: f64) -> Result<Portfolio> {
        anyhow::ensure!(k_max >= 1, "portfolio needs k_max >= 1");
        anyhow::ensure!(!self.shapes.is_empty(), "cannot build a portfolio from zero shapes");
        anyhow::ensure!(
            (0..self.shapes.len()).any(|s| self.best_for_shape(s).is_some()),
            "every config failed on every shape"
        );

        let mut members: Vec<usize> = Vec::new();
        while members.len() < k_max {
            let current = self.retained_with(&members);
            // Pick the config whose addition maximizes retention.
            let next = (0..self.configs.len())
                .filter(|c| !members.contains(c))
                .map(|c| {
                    let mut trial = members.clone();
                    trial.push(c);
                    (c, self.retained_with(&trial))
                })
                .max_by(|a, b| a.1.total_cmp(&b.1));
            let Some((c, gained)) = next else { break };
            if !members.is_empty() && gained <= current {
                break; // no config improves coverage further
            }
            members.push(c);
            if gained >= target {
                break;
            }
        }

        // Assign each shape to its best member (its "cluster"), then
        // summarize each member by the feature centroid of the shapes
        // it covers.  Members covering nothing are dropped.
        let mut covered: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for s in 0..self.shapes.len() {
            let winner = members
                .iter()
                .copied()
                .filter(|&c| self.costs[s][c].is_finite())
                .min_by(|&x, &y| self.costs[s][x].total_cmp(&self.costs[s][y]));
            if let Some(c) = winner {
                covered.entry(c).or_default().push(s);
            }
        }
        let items: Vec<PortfolioItem> = covered
            .iter()
            .map(|(&c, shape_idxs)| {
                let dim = self.shapes[shape_idxs[0]].features.len();
                let mut centroid = vec![0.0; dim];
                for &s in shape_idxs {
                    for (acc, f) in centroid.iter_mut().zip(&self.shapes[s].features) {
                        *acc += f;
                    }
                }
                for f in centroid.iter_mut() {
                    *f /= shape_idxs.len() as f64;
                }
                PortfolioItem {
                    config: self.configs[c].clone(),
                    config_id: self.config_ids[c].clone(),
                    centroid,
                    covered: shape_idxs.iter().map(|&s| self.shapes[s].tag.clone()).collect(),
                }
            })
            .collect();
        let final_members: Vec<usize> = covered.keys().copied().collect();
        Ok(Portfolio {
            kernel: self.kernel.clone(),
            strategy: "greedy-cover".to_string(),
            k_max,
            retained: self.retained_with(&final_members),
            built_at: unix_now(),
            feature_names: FEATURE_NAMES.iter().map(|s| s.to_string()).collect(),
            items,
        })
    }
}

/// One member of a portfolio: a schedule config plus the feature
/// centroid of the sweep shapes it won, which is its selector at
/// deploy time.
#[derive(Debug, Clone, PartialEq)]
pub struct PortfolioItem {
    /// The schedule parameters.
    pub config: Config,
    /// Stable config id (`o1_tm32_tn128_u4` style).
    pub config_id: String,
    /// Mean feature vector of the shapes this member covers.
    pub centroid: Vec<f64>,
    /// Tags of the sweep shapes this member won.
    pub covered: Vec<String>,
}

/// A built portfolio: K ≤ `k_max` schedule configs that together
/// retain `retained` of per-shape-tuned performance over the sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct Portfolio {
    /// Kernel family this portfolio serves.
    pub kernel: String,
    /// Construction algorithm (`greedy-cover`).
    pub strategy: String,
    /// The size cap the builder ran with.
    pub k_max: usize,
    /// Mean retained fraction of per-shape-tuned performance over the
    /// build sweep (≤ 1.0 by construction).
    pub retained: f64,
    /// Unix seconds when built.
    pub built_at: u64,
    /// Feature-vector component names (build/deploy contract).
    pub feature_names: Vec<String>,
    /// The members, in config-enumeration order.
    pub items: Vec<PortfolioItem>,
}

impl Portfolio {
    /// Number of members.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Whether the portfolio has no members.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Deploy-time selection: the member whose centroid is nearest (in
    /// Euclidean feature distance) to the workload's feature vector.
    pub fn select(&self, features: &[f64]) -> Option<&PortfolioItem> {
        self.items
            .iter()
            .min_by(|a, b| {
                dist2(&a.centroid, features).total_cmp(&dist2(&b.centroid, features))
            })
    }

    /// Selection by raw dims: computes the feature vector against the
    /// deploying platform's cache geometry first.  Returns `None` when
    /// the portfolio's stored [`feature_names`](Self::feature_names)
    /// disagree with this build's [`FEATURE_NAMES`] — comparing
    /// centroids component-by-component against a differently-defined
    /// feature vector would silently select the wrong member.
    pub fn select_for_dims(
        &self,
        dims: &BTreeMap<String, i64>,
        fp: &Fingerprint,
    ) -> Option<&PortfolioItem> {
        if !self.feature_names.iter().map(String::as_str).eq(FEATURE_NAMES) {
            return None;
        }
        self.select(&features_for(dims, 1.0, fp))
    }

    /// JSON view (shard storage and the serve protocol's wire form).
    pub fn to_json(&self) -> Json {
        let items: Vec<Json> = self
            .items
            .iter()
            .map(|item| {
                json::obj(vec![
                    ("config_id", json::s(&item.config_id)),
                    (
                        "params",
                        Json::Obj(
                            item.config
                                .iter()
                                .map(|(k, v)| (k.clone(), json::int(*v)))
                                .collect(),
                        ),
                    ),
                    (
                        "centroid",
                        Json::Arr(item.centroid.iter().map(|&f| json::num(f)).collect()),
                    ),
                    (
                        "covered",
                        Json::Arr(item.covered.iter().map(|t| json::s(t)).collect()),
                    ),
                ])
            })
            .collect();
        json::obj(vec![
            ("kernel", json::s(&self.kernel)),
            ("strategy", json::s(&self.strategy)),
            ("k_max", json::int(self.k_max as i64)),
            ("retained", json::num(self.retained)),
            ("built_at", json::int(self.built_at as i64)),
            (
                "feature_names",
                Json::Arr(self.feature_names.iter().map(|n| json::s(n)).collect()),
            ),
            ("items", Json::Arr(items)),
        ])
    }

    /// Parse the [`to_json`](Self::to_json) form.
    pub fn from_json(v: &Json) -> Result<Portfolio> {
        let gs = |k: &str| -> Result<String> {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("portfolio missing {k}"))
        };
        let items = v
            .get("items")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("portfolio missing items"))?
            .iter()
            .map(|item| {
                let config = item
                    .get("params")
                    .and_then(Json::as_obj)
                    .ok_or_else(|| anyhow::anyhow!("portfolio item missing params"))?
                    .iter()
                    .map(|(k, val)| {
                        val.as_i64()
                            .map(|x| (k.clone(), x))
                            .ok_or_else(|| anyhow::anyhow!("non-int param {k}"))
                    })
                    .collect::<Result<BTreeMap<_, _>>>()?;
                let centroid = item
                    .get("centroid")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("portfolio item missing centroid"))?
                    .iter()
                    .map(|f| f.as_f64().ok_or_else(|| anyhow::anyhow!("non-num centroid")))
                    .collect::<Result<Vec<_>>>()?;
                let covered = item
                    .get("covered")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(Json::as_str)
                            .map(str::to_string)
                            .collect::<Vec<_>>()
                    })
                    .unwrap_or_default();
                Ok(PortfolioItem {
                    config,
                    config_id: item
                        .get("config_id")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow::anyhow!("portfolio item missing config_id"))?
                        .to_string(),
                    centroid,
                    covered,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Portfolio {
            kernel: gs("kernel")?,
            strategy: gs("strategy")?,
            k_max: v.get("k_max").and_then(Json::as_u64).unwrap_or(4) as usize,
            retained: v
                .get("retained")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("portfolio missing retained"))?,
            built_at: v.get("built_at").and_then(Json::as_u64).unwrap_or(0),
            // No default on absence: `to_json` always writes the field,
            // so a portfolio without it was built under an UNKNOWN
            // feature definition — assuming the current one would let
            // `select_for_dims` compare centroids across contracts.
            feature_names: v
                .get("feature_names")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(Json::as_str)
                        .map(str::to_string)
                        .collect::<Vec<_>>()
                })
                .ok_or_else(|| anyhow::anyhow!("portfolio missing feature_names"))?,
            items,
        })
    }
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>()
        + (a.len() as f64 - b.len() as f64).abs() * 1e9 // length mismatch = far
}

/// The result of a native GEMM sweep: the cost matrix plus the
/// per-shape naive-reference timings and the default-schedule column.
#[derive(Debug, Clone)]
pub struct GemmSweep {
    /// Measured (shape × config) costs.
    pub matrix: CostMatrix,
    /// Median seconds of the naive reference per shape (row order).
    pub reference_s: Vec<f64>,
    /// Column index of [`gemm::default_config`] in the matrix.
    pub default_index: usize,
}

impl GemmSweep {
    /// Per-shape [`DbEntry`] records (the tuning history the serve
    /// daemon answers lookups from): best config per shape, with the
    /// default schedule as the baseline comparator and the naive
    /// reference as the reference timing.
    pub fn entries(&self, platform_key: &str, strategy: &str) -> Vec<DbEntry> {
        let now = unix_now();
        self.matrix
            .shapes
            .iter()
            .enumerate()
            .filter_map(|(s, shape)| {
                let (best_idx, best_cost) = self.matrix.best_for_shape(s)?;
                let default_cost = self.matrix.costs[s][self.default_index];
                let baseline = if default_cost.is_finite() {
                    default_cost
                } else {
                    self.reference_s[s]
                };
                Some(DbEntry {
                    platform_key: platform_key.to_string(),
                    kernel: self.matrix.kernel.clone(),
                    tag: shape.tag.clone(),
                    best_params: self.matrix.configs[best_idx].clone(),
                    best_config_id: self.matrix.config_ids[best_idx].clone(),
                    best_time_s: best_cost,
                    baseline_time_s: baseline,
                    reference_time_s: self.reference_s[s],
                    evaluations: self.matrix.configs.len() as u64,
                    strategy: strategy.to_string(),
                    recorded_at: now,
                })
            })
            .collect()
    }
}

/// Measurement profile for native sweeps: lighter than artifact tuning
/// (the matrix is shapes × configs measurements) but still median-of-3
/// with outlier rejection; `quick` drops to the smoke profile.
pub fn sweep_measure_cfg(quick: bool) -> MeasureConfig {
    if quick {
        MeasureConfig::quick()
    } else {
        MeasureConfig {
            warmup: 1,
            reps: 3,
            target_rel_spread: 0.5,
            max_reps: 5,
            outlier_k: 5.0,
            race_min_reps: 2,
        }
    }
}

/// One-call native sweep for a kernel family: the standard shape sweep
/// (`quick` selects the smoke-sized one), the standard measurement
/// profile, default tolerance.  This is the execution path shared by
/// `portatune tune --sweep`, `portatune portfolio build`, and the
/// worker fleet's sweep / portfolio-rebuild tasks — errors (rather
/// than panics) on kernels with no native implementation, so a worker
/// can `task-fail` an unsupported task.
pub fn sweep_native(kernel: &str, quick: bool, seed: u64, fp: &Fingerprint) -> Result<GemmSweep> {
    anyhow::ensure!(
        kernel == gemm::KERNEL,
        "no native sweep for kernel {kernel:?} (only {:?} runs host-side)",
        gemm::KERNEL
    );
    let shapes = if quick { gemm::quick_sweep() } else { gemm::default_sweep() };
    sweep_gemm(&shapes, &sweep_measure_cfg(quick), Tolerance::default(), seed, fp)
}

/// Measure the full GEMM schedule space over a shape sweep (see module
/// docs).  Every config is gated against the naive reference before
/// timing; gate failures and measurement errors record `INFINITY` and
/// never poison the portfolio.  Deterministic inputs per (shape, seed).
pub fn sweep_gemm(
    shapes: &[GemmShape],
    measure_cfg: &MeasureConfig,
    tolerance: Tolerance,
    seed: u64,
    fp: &Fingerprint,
) -> Result<GemmSweep> {
    anyhow::ensure!(!shapes.is_empty(), "sweep needs at least one shape");
    let spec = gemm::space();
    let configs = spec.enumerate();
    let config_ids: Vec<String> = configs.iter().map(|c| spec.config_id(c)).collect();
    let default_id = spec.config_id(&gemm::default_config());
    let default_index = config_ids
        .iter()
        .position(|id| *id == default_id)
        .context("default config missing from the gemm space")?;

    // The untimed gate/oracle executions double as warmup #1, exactly
    // like the artifact pipeline's gate run (no work is executed just
    // to be thrown away).
    let post_gate = MeasureConfig {
        warmup: measure_cfg.warmup.saturating_sub(1),
        ..measure_cfg.clone()
    };

    let mut shape_points = Vec::with_capacity(shapes.len());
    let mut costs = Vec::with_capacity(shapes.len());
    let mut reference_s = Vec::with_capacity(shapes.len());
    for &shape in shapes {
        let (a, b) = gemm::inputs(shape, seed);
        // The oracle computation is also the reference's first warmup.
        let want = gemm::reference(&a, &b, shape);
        let reference = measure_host(
            &mut || {
                let out = gemm::reference(&a, &b, shape);
                std::hint::black_box(&out);
                Ok(())
            },
            &post_gate,
        )?;
        reference_s.push(reference.cost());

        let mut row = Vec::with_capacity(configs.len());
        for config in &configs {
            // Gate first: a wrong answer is infinitely expensive.  The
            // gate execution is warmup #1 for the measurement below.
            let got = gemm::run_config(&a, &b, shape, config);
            if !check_outputs(&got, &want, tolerance).ok {
                row.push(f64::INFINITY);
                continue;
            }
            let measured = measure_host(
                &mut || {
                    let out = gemm::run_config(&a, &b, shape, config);
                    std::hint::black_box(&out);
                    Ok(())
                },
                &post_gate,
            );
            row.push(measured.map(|m| m.cost()).unwrap_or(f64::INFINITY));
        }
        costs.push(row);
        shape_points.push(ShapePoint {
            tag: shape.tag(),
            dims: shape.dims(),
            flops: shape.flops(),
            features: features_for(&shape.dims(), 1.0, fp),
        });
    }

    Ok(GemmSweep {
        matrix: CostMatrix {
            kernel: gemm::KERNEL.to_string(),
            shapes: shape_points,
            configs,
            config_ids,
            costs,
        },
        reference_s,
        default_index,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp() -> Fingerprint {
        Fingerprint {
            cpu_model: "Port CPU".into(),
            num_cpus: 8,
            simd: vec!["avx2".into()],
            cache_l1d_kb: 32,
            cache_l2_kb: 1024,
            cache_l3_kb: 8192,
            os: "linux".into(),
        }
    }

    /// A synthetic 4-shape × 3-config matrix with known structure:
    /// config 0 wins shapes 0/1, config 1 wins shapes 2/3, config 2 is
    /// uniformly mediocre.
    fn matrix() -> CostMatrix {
        let shape = |tag: &str, m: i64, n: i64, k: i64| ShapePoint {
            tag: tag.into(),
            dims: [("m".to_string(), m), ("n".to_string(), n), ("k".to_string(), k)]
                .into_iter()
                .collect(),
            flops: (2 * m * n * k) as u64,
            features: features_for(
                &[("m".to_string(), m), ("n".to_string(), n), ("k".to_string(), k)]
                    .into_iter()
                    .collect(),
                1.0,
                &fp(),
            ),
        };
        let cfg = |o: i64| -> Config {
            [
                ("loop_order".to_string(), o),
                ("tile_m".to_string(), 32i64),
                ("tile_n".to_string(), 32i64),
                ("unroll".to_string(), 1i64),
            ]
            .into_iter()
            .collect()
        };
        CostMatrix {
            kernel: "gemm".into(),
            shapes: vec![
                shape("m16n16k16", 16, 16, 16),
                shape("m32n32k32", 32, 32, 32),
                shape("m256n256k256", 256, 256, 256),
                shape("m512n512k64", 512, 512, 64),
            ],
            configs: vec![cfg(0), cfg(1), cfg(2)],
            config_ids: vec!["c0".into(), "c1".into(), "c2".into()],
            costs: vec![
                vec![1.0, 2.0, 1.5],
                vec![1.0, 3.0, 1.5],
                vec![4.0, 2.0, 3.0],
                vec![5.0, 2.5, 4.0],
            ],
        }
    }

    #[test]
    fn greedy_builder_covers_both_regimes() {
        let m = matrix();
        let p = m.build_portfolio(2, 1.0).unwrap();
        assert_eq!(p.len(), 2);
        let ids: Vec<&str> = p.items.iter().map(|i| i.config_id.as_str()).collect();
        assert!(ids.contains(&"c0") && ids.contains(&"c1"), "{ids:?}");
        assert!((p.retained - 1.0).abs() < 1e-12, "both regimes covered exactly");
        // Small shapes cluster under c0, large under c1.
        let c0 = p.items.iter().find(|i| i.config_id == "c0").unwrap();
        assert_eq!(c0.covered, vec!["m16n16k16".to_string(), "m32n32k32".to_string()]);
    }

    #[test]
    fn k1_portfolio_picks_the_best_single_cover() {
        let m = matrix();
        let p = m.build_portfolio(1, 1.0).unwrap();
        assert_eq!(p.len(), 1);
        // c1 retention: (1/2 + 1/3 + 2/2 + 2.5/2.5)/4 = 0.7083;
        // c0: (1 + 1 + 2/4 + 2.5/5)/4 = 0.75; c2: (2/3 + 2/3 + 2/3 + 2.5/4)/4 < 0.7.
        assert_eq!(p.items[0].config_id, "c0");
        assert!(p.retained <= 1.0 + 1e-12);
    }

    #[test]
    fn retention_is_monotone_in_k_and_bounded() {
        let m = matrix();
        let r1 = m.build_portfolio(1, 1.0).unwrap().retained;
        let r2 = m.build_portfolio(2, 1.0).unwrap().retained;
        let r3 = m.build_portfolio(3, 1.0).unwrap().retained;
        assert!(r1 <= r2 + 1e-12 && r2 <= r3 + 1e-12);
        assert!(r3 <= 1.0 + 1e-12);
    }

    #[test]
    fn target_stops_growth_early() {
        let m = matrix();
        let p = m.build_portfolio(3, 0.5).unwrap();
        assert_eq!(p.len(), 1, "0.5 retention is reachable with one config");
    }

    #[test]
    fn selection_routes_shapes_to_their_cluster() {
        let m = matrix();
        let p = m.build_portfolio(2, 1.0).unwrap();
        // A small workload selects the small-shape member.
        let small = p.select(&m.shapes[0].features).unwrap();
        assert_eq!(small.config_id, "c0");
        let large = p.select(&m.shapes[2].features).unwrap();
        assert_eq!(large.config_id, "c1");
        // Dims-based selection agrees (same fingerprint).
        let via_dims = p.select_for_dims(&m.shapes[2].dims, &fp()).unwrap();
        assert_eq!(via_dims.config_id, "c1");
    }

    #[test]
    fn foreign_feature_contract_refuses_dims_selection() {
        let mut p = matrix().build_portfolio(2, 1.0).unwrap();
        assert!(p.select_for_dims(&GemmShape::new(16, 16, 16).dims(), &fp()).is_some());
        p.feature_names = vec!["log_m".into(), "something_else".into()];
        assert!(
            p.select_for_dims(&GemmShape::new(16, 16, 16).dims(), &fp()).is_none(),
            "a portfolio built under a different feature contract must not select"
        );
        // Raw-feature selection stays available for callers that bring
        // their own contract handling.
        assert!(p.select(&[1.0; 5]).is_some());
    }

    #[test]
    fn infinite_columns_are_never_selected_into_coverage() {
        let mut m = matrix();
        for row in m.costs.iter_mut() {
            row[0] = f64::INFINITY; // c0 fails everywhere
        }
        let p = m.build_portfolio(2, 1.0).unwrap();
        assert!(p.items.iter().all(|i| i.config_id != "c0"));
        assert!(p.retained > 0.0);
    }

    #[test]
    fn empty_and_degenerate_matrices_error() {
        let mut m = matrix();
        m.shapes.clear();
        m.costs.clear();
        assert!(m.build_portfolio(2, 0.9).is_err());
        let mut dead = matrix();
        for row in dead.costs.iter_mut() {
            for c in row.iter_mut() {
                *c = f64::INFINITY;
            }
        }
        assert!(dead.build_portfolio(2, 0.9).is_err());
        assert!(matrix().build_portfolio(0, 0.9).is_err());
    }

    #[test]
    fn portfolio_json_round_trips() {
        let p = matrix().build_portfolio(2, 1.0).unwrap();
        let text = p.to_json().compact();
        let back = Portfolio::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, p);
        assert!(Portfolio::from_json(&json::parse("{}").unwrap()).is_err());
        // A portfolio without its feature contract was built under an
        // unknown feature definition — refusing beats guessing.
        let mut stripped = json::parse(&text).unwrap();
        if let Json::Obj(map) = &mut stripped {
            map.remove("feature_names");
        }
        assert!(Portfolio::from_json(&stripped).is_err());
    }

    #[test]
    fn features_track_dims_and_cache_pressure() {
        let f = fp();
        let small = features_for(&GemmShape::new(16, 16, 16).dims(), 1.0, &f);
        let large = features_for(&GemmShape::new(1024, 1024, 1024).dims(), 1.0, &f);
        assert_eq!(small.len(), FEATURE_NAMES.len());
        assert!(large[0] > small[0] && large[4] > small[4]);
        let mut tiny_cache = f.clone();
        tiny_cache.cache_l2_kb = 1;
        tiny_cache.cache_l3_kb = 0;
        tiny_cache.cache_l1d_kb = 1;
        let pressured = features_for(&GemmShape::new(16, 16, 16).dims(), 1.0, &tiny_cache);
        assert!(pressured[4] > small[4], "smaller cache raises pressure");
    }

    #[test]
    fn sweep_native_refuses_non_native_kernels() {
        let err = sweep_native("axpy", true, 7, &fp()).unwrap_err();
        assert!(err.to_string().contains("no native sweep"), "{err:#}");
    }

    #[test]
    fn quick_sweep_end_to_end_builds_a_valid_portfolio() {
        let shapes = [GemmShape::new(12, 12, 12), GemmShape::new(24, 8, 16)];
        let sweep = sweep_gemm(
            &shapes,
            &MeasureConfig::quick(),
            Tolerance::default(),
            7,
            &fp(),
        )
        .unwrap();
        assert_eq!(sweep.matrix.shapes.len(), 2);
        assert_eq!(sweep.matrix.configs.len(), gemm::configs().len());
        // Gates pass: at least one finite cost per shape.
        for s in 0..2 {
            assert!(sweep.matrix.best_for_shape(s).is_some());
        }
        let p = sweep.matrix.build_portfolio(4, 0.9).unwrap();
        assert!(p.len() <= 4 && !p.is_empty());
        assert!(p.retained <= 1.0 + 1e-12);
        let entries = sweep.entries("test-platform", "sweep");
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].kernel, "gemm");
        assert!(entries[0].best_time_s.is_finite());
        assert!(entries[0].baseline_time_s >= entries[0].best_time_s);
    }
}
