//! Orio-style annotation language.
//!
//! The paper's §2: "Tuning is accomplished by annotating existing code
//! with performance directives in the form of source code pragmas."  The
//! annotation does not change program semantics — it *describes the
//! variant space* and how to search it.  We keep the same shape: an
//! annotation block embedded in any text file (C, rust, python, .tune
//! files — the parser only looks at `/*@ ... @*/` spans):
//!
//! ```text
//! /*@ tune kernel=axpy workload=n65536
//!     param block_size as b [256, 1024, 4096, 16384]
//!     param unroll as u [1, 2, 4]
//!     constraint block_size <= n
//!     constraint block_size % unroll == 0
//!     search anneal budget=20 seed=42
//! @*/
//! ```
//!
//! `as <abbrev>` is optional (defaults to the name's first letter); the
//! `search` line is optional (defaults to exhaustive with unlimited
//! budget).  Constraint expressions use the shared grammar of
//! [`super::constraint`].

use std::collections::BTreeMap;

use anyhow::Result;

use crate::runtime::registry::ParamDef;

use super::constraint::Expr;
use super::spec::TuningSpec;

/// A parsed `tune` annotation block.
#[derive(Debug, Clone, PartialEq)]
pub struct Annotation {
    /// Kernel family the block tunes.
    pub kernel: String,
    /// Optional workload tag the block binds to (`None` = any workload).
    pub workload: Option<String>,
    /// Declared parameter domains.
    pub params: Vec<ParamDef>,
    /// Constraint strings over params and dims.
    pub constraints: Vec<String>,
    /// Requested search strategy name (exhaustive/random/hillclimb/anneal/genetic).
    pub search: Option<String>,
    /// Free-form `key=value` options from the search line (budget, seed...).
    pub options: BTreeMap<String, String>,
}

/// Find all `/*@ ... @*/` spans in a source file (content between the
/// markers, exclusive).
pub fn extract_blocks(source: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = source;
    while let Some(start) = rest.find("/*@") {
        let after = &rest[start + 3..];
        match after.find("@*/") {
            Some(end) => {
                out.push(after[..end].to_string());
                rest = &after[end + 3..];
            }
            None => break,
        }
    }
    out
}

impl Annotation {
    /// Parse one annotation block (the text between `/*@` and `@*/`).
    pub fn parse(block: &str) -> Result<Annotation> {
        let mut lines = block
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        let head = lines
            .next()
            .ok_or_else(|| anyhow::anyhow!("empty annotation block"))?;
        let head_rest = head
            .strip_prefix("tune")
            .ok_or_else(|| anyhow::anyhow!("annotation must start with 'tune', got: {head}"))?;
        let mut kernel = None;
        let mut workload = None;
        for kv in head_rest.split_whitespace() {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad tune header item: {kv}"))?;
            match k {
                "kernel" => kernel = Some(v.to_string()),
                "workload" => workload = Some(v.to_string()),
                other => return Err(anyhow::anyhow!("unknown tune header key: {other}")),
            }
        }
        let kernel = kernel.ok_or_else(|| anyhow::anyhow!("tune header missing kernel="))?;

        let mut params = Vec::new();
        let mut constraints = Vec::new();
        let mut search = None;
        let mut options = BTreeMap::new();

        for line in lines {
            if let Some(rest) = line.strip_prefix("param ") {
                params.push(parse_param(rest)?);
            } else if let Some(rest) = line.strip_prefix("constraint ") {
                let src = rest.trim().to_string();
                // Validate the expression grammar eagerly.
                Expr::parse(&src).map_err(|e| anyhow::anyhow!("constraint `{src}`: {e}"))?;
                constraints.push(src);
            } else if let Some(rest) = line.strip_prefix("search ") {
                let mut items = rest.split_whitespace();
                search = items.next().map(str::to_string);
                for kv in items {
                    let (k, v) = kv
                        .split_once('=')
                        .ok_or_else(|| anyhow::anyhow!("bad search option: {kv}"))?;
                    options.insert(k.to_string(), v.to_string());
                }
            } else {
                return Err(anyhow::anyhow!("unknown annotation line: {line}"));
            }
        }
        if params.is_empty() {
            return Err(anyhow::anyhow!("annotation declares no params"));
        }
        // Reject duplicate param names/abbrevs (ambiguous variant ids).
        let mut names: Vec<&str> = params.iter().map(|p| p.name.as_str()).collect();
        names.sort();
        names.dedup();
        if names.len() != params.len() {
            return Err(anyhow::anyhow!("duplicate param names in annotation"));
        }
        let mut abbrevs: Vec<&str> = params.iter().map(|p| p.abbrev.as_str()).collect();
        abbrevs.sort();
        abbrevs.dedup();
        if abbrevs.len() != params.len() {
            return Err(anyhow::anyhow!(
                "duplicate param abbreviations; disambiguate with `param <name> as <abbrev>`"
            ));
        }
        Ok(Annotation { kernel, workload, params, constraints, search, options })
    }

    /// Build the searchable spec, supplying workload dims.
    pub fn to_spec(&self, tag: &str, dims: BTreeMap<String, i64>) -> Result<TuningSpec> {
        TuningSpec::new(
            self.kernel.clone(),
            tag,
            self.params.clone(),
            &self.constraints,
            dims,
        )
    }

    /// Canonical rendering (parse → render → parse is identity).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("/*@ tune kernel=");
        out.push_str(&self.kernel);
        if let Some(w) = &self.workload {
            out.push_str(" workload=");
            out.push_str(w);
        }
        out.push('\n');
        for p in &self.params {
            let vals: Vec<String> = p.values.iter().map(|v| v.to_string()).collect();
            out.push_str(&format!(
                "    param {} as {} [{}]\n",
                p.name,
                p.abbrev,
                vals.join(", ")
            ));
        }
        for c in &self.constraints {
            out.push_str(&format!("    constraint {c}\n"));
        }
        if let Some(s) = &self.search {
            out.push_str(&format!("    search {s}"));
            for (k, v) in &self.options {
                out.push_str(&format!(" {k}={v}"));
            }
            out.push('\n');
        }
        out.push_str("@*/\n");
        out
    }
}

/// `block_size as b [256, 1024]` or `unroll [1,2,4]`.
fn parse_param(rest: &str) -> Result<ParamDef> {
    let open = rest
        .find('[')
        .ok_or_else(|| anyhow::anyhow!("param missing value list: {rest}"))?;
    let close = rest
        .rfind(']')
        .filter(|&c| c > open)
        .ok_or_else(|| anyhow::anyhow!("param missing ']': {rest}"))?;
    let header = rest[..open].trim();
    let values = rest[open + 1..close]
        .split(',')
        .map(|v| {
            v.trim()
                .parse::<i64>()
                .map_err(|_| anyhow::anyhow!("bad param value `{}` in: {rest}", v.trim()))
        })
        .collect::<Result<Vec<_>>>()?;
    if values.is_empty() {
        return Err(anyhow::anyhow!("param has empty domain: {rest}"));
    }
    let (name, abbrev) = match header.split_once(" as ") {
        Some((n, a)) => (n.trim().to_string(), a.trim().to_string()),
        None => {
            let name = header.to_string();
            if name.is_empty() || name.contains(char::is_whitespace) {
                return Err(anyhow::anyhow!("bad param name: {header}"));
            }
            let abbrev = name.chars().take(1).collect();
            (name, abbrev)
        }
    };
    Ok(ParamDef { name, abbrev, values })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        some C code here...
        /*@ tune kernel=axpy workload=n65536
            param block_size as b [256, 1024, 4096, 16384]
            param unroll as u [1, 2, 4]
            constraint block_size <= n
            constraint block_size % unroll == 0
            search anneal budget=20 seed=42
        @*/
        more code...
    "#;

    #[test]
    fn extracts_blocks() {
        let blocks = extract_blocks(SAMPLE);
        assert_eq!(blocks.len(), 1);
        assert!(blocks[0].contains("tune kernel=axpy"));
        assert!(extract_blocks("no annotations").is_empty());
        assert_eq!(extract_blocks("/*@ a @*/ x /*@ b @*/").len(), 2);
        // Unterminated block ignored.
        assert!(extract_blocks("/*@ dangling").is_empty());
    }

    #[test]
    fn parses_full_block() {
        let ann = Annotation::parse(&extract_blocks(SAMPLE)[0]).unwrap();
        assert_eq!(ann.kernel, "axpy");
        assert_eq!(ann.workload.as_deref(), Some("n65536"));
        assert_eq!(ann.params.len(), 2);
        assert_eq!(ann.params[0].name, "block_size");
        assert_eq!(ann.params[0].abbrev, "b");
        assert_eq!(ann.params[0].values, vec![256, 1024, 4096, 16384]);
        assert_eq!(ann.constraints.len(), 2);
        assert_eq!(ann.search.as_deref(), Some("anneal"));
        assert_eq!(ann.options["budget"], "20");
        assert_eq!(ann.options["seed"], "42");
    }

    #[test]
    fn default_abbrev_is_first_letter() {
        let ann = Annotation::parse("tune kernel=k\nparam unroll [1, 2]").unwrap();
        assert_eq!(ann.params[0].abbrev, "u");
    }

    #[test]
    fn duplicate_abbrevs_rejected() {
        let block = "tune kernel=k\nparam tile_m [8]\nparam tile_n [8]";
        let err = Annotation::parse(block).unwrap_err();
        assert!(err.to_string().contains("abbrev"));
        let ok = "tune kernel=k\nparam tile_m as tm [8]\nparam tile_n as tn [8]";
        assert!(Annotation::parse(ok).is_ok());
    }

    #[test]
    fn rejects_malformed_blocks() {
        assert!(Annotation::parse("").is_err());
        assert!(Annotation::parse("tune").is_err()); // no kernel
        assert!(Annotation::parse("tune kernel=k").is_err()); // no params
        assert!(Annotation::parse("tune kernel=k\nparam p []").is_err());
        assert!(Annotation::parse("tune kernel=k\nparam p [1,x]").is_err());
        assert!(Annotation::parse("tune kernel=k\nparam p [1]\nbogus line").is_err());
        assert!(Annotation::parse("tune kernel=k\nparam p [1]\nconstraint p <").is_err());
        assert!(Annotation::parse("tune bogus=1 kernel=k\nparam p [1]").is_err());
    }

    #[test]
    fn to_spec_builds_searchable_space() {
        let ann = Annotation::parse(&extract_blocks(SAMPLE)[0]).unwrap();
        let dims = [("n".to_string(), 65536i64)].into_iter().collect();
        let spec = ann.to_spec("n65536", dims).unwrap();
        let all = spec.enumerate();
        assert_eq!(all.len(), 12); // all blocks <= 65536, all unrolls divide
        assert_eq!(spec.config_id(&all[0]), "b256_u1");
    }

    #[test]
    fn render_round_trips() {
        let ann = Annotation::parse(&extract_blocks(SAMPLE)[0]).unwrap();
        let text = ann.render();
        let blocks = extract_blocks(&text);
        assert_eq!(blocks.len(), 1);
        let re = Annotation::parse(&blocks[0]).unwrap();
        assert_eq!(re, ann);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let block = "tune kernel=k\n\n# a comment\nparam p [1, 2]\n";
        let ann = Annotation::parse(block).unwrap();
        assert_eq!(ann.params.len(), 1);
    }
}
